"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408/expert,
vocab=102400, 64 routed experts top-6 + 2 shared, fine-grained
[arXiv:2401.06066]. Router: softmax -> top-k (deepseek convention).

Deviation noted in DESIGN.md: all layers MoE (reference model keeps layer 0
dense) to keep a single scanned body."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        num_layers=28, d_model=2048, d_ff=1408, vocab_size=102_400,
        num_heads=16, num_kv_heads=16,
        n_experts=64, n_shared_experts=2, top_k=6,
        router_norm="softmax_topk",
        block="attn", gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=32, vocab_size=97,
        num_heads=4, num_kv_heads=4, n_experts=8, n_shared_experts=1,
        top_k=2, vocab_pad_multiple=8, gen_feature_dim=8, remat=False)
