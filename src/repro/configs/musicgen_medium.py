"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284].

Modality stub: the EnCodec frontend is external; ``input_specs`` provides
token ids over the codec vocabulary (single-stream; the 4-codebook delay
pattern is out of scope per the task statement)."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        num_layers=48, d_model=1536, d_ff=6144, vocab_size=2048,
        num_heads=24, num_kv_heads=24,
        block="attn", modality="audio",
        vocab_pad_multiple=256, gen_feature_dim=16,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=128, vocab_size=101,
        num_heads=4, num_kv_heads=4, vocab_pad_multiple=8,
        gen_feature_dim=8, remat=False)
