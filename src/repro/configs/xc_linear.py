"""The paper's own model (§5): affine-linear extreme classifier
xi_y(x) = w_y^T x + b_y over XML-CNN-style features.

Dataset scales mirror Table 1 (Wikipedia-500K: N=1.6M, C=217240, K=512;
Amazon-670K: N=490k, C=213874, K=512); the synthetic generator in
repro.data.synthetic reproduces the hierarchical-cluster structure the
paper's adversarial argument relies on. Auxiliary tree: k=16,
lambda_n=0.1 (paper's hyperparameters)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class XCLinearConfig:
    name: str = "xc_linear"
    feature_dim: int = 512        # K
    num_labels: int = 217_240     # C (Wikipedia-500K after preprocessing)
    gen_feature_dim: int = 16     # k (paper §5)
    gen_reg: float = 0.1          # lambda_n (paper §5)
    head_reg: float = 0.001       # lambda   (paper Table 1)
    learning_rate: float = 0.01   # rho      (paper Table 1, Adagrad)
    n_neg: int = 1


def config() -> XCLinearConfig:
    return XCLinearConfig()


def reduced() -> XCLinearConfig:
    return XCLinearConfig(feature_dim=32, num_labels=128, gen_feature_dim=8)
