"""deepseek-7b [dense]: 30L d_model=4096 32H (kv=32) d_ff=11008
vocab=102400 — llama-arch [arXiv:2401.02954]."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        num_layers=30, d_model=4096, d_ff=11_008, vocab_size=102_400,
        num_heads=32, num_kv_heads=32,
        block="attn", gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=172, vocab_size=129,
        num_heads=4, num_kv_heads=4, vocab_pad_multiple=8,
        gen_feature_dim=8, remat=False)
