"""gemma2-27b [dense]: 46L d_model=4608 32H (kv=16) d_ff=36864
vocab=256000 — local+global alternating SWA(4096), attn softcap 50,
final logit softcap 30 [arXiv:2408.00118].

Deviations noted in DESIGN.md: pre-norm only (no sandwich post-norms),
untied embeddings (vocab-sharded head table)."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        num_layers=46, d_model=4608, d_ff=36_864, vocab_size=256_000,
        num_heads=32, num_kv_heads=16, head_dim=128,
        window_size=4096, window_pattern=2,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        block="attn", gen_feature_dim=64,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=192, vocab_size=203,
        num_heads=4, num_kv_heads=2, head_dim=16, window_size=8,
        vocab_pad_multiple=8, gen_feature_dim=8, remat=False)
