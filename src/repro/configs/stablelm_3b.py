"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b family]."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        num_layers=32, d_model=2560, d_ff=6912, vocab_size=50_304,
        num_heads=32, num_kv_heads=32,
        block="attn", gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=160, vocab_size=97,
        num_heads=4, num_kv_heads=4, vocab_pad_multiple=8,
        gen_feature_dim=8, remat=False)
