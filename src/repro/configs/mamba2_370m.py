"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        num_layers=48, d_model=1024, d_ff=0, vocab_size=50_280,
        block="ssm", ssm_state=128, ssm_expand=2, ssm_head_dim=64,
        ssm_chunk=256,
        num_heads=0, num_kv_heads=0,
        gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, vocab_size=97, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, vocab_pad_multiple=8,
        gen_feature_dim=8, remat=False)
