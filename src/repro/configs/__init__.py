"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

One module per assigned architecture (exact configs from the task sheet,
sources cited in each file) plus the paper's own linear extreme-classifier
(`xc_linear`). ``reduced_config(name)`` gives the CPU-smoke-test shrink of
the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCHS = (
    "mamba2-370m", "musicgen-medium", "stablelm-3b", "deepseek-7b",
    "gemma2-27b", "h2o-danube-3-4b", "qwen2-vl-7b", "deepseek-moe-16b",
    "mixtral-8x22b", "hymba-1.5b",
)

# Shape suite shared by every LM arch: (seq_len, global_batch, mode).
SHAPES: Dict[str, tuple] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# long_500k requires sub-quadratic attention / bounded state (DESIGN.md §5).
LONG_CONTEXT_OK = {
    "mamba2-370m": True, "hymba-1.5b": True, "h2o-danube-3-4b": True,
    "mixtral-8x22b": True, "gemma2-27b": True,
    "stablelm-3b": False, "deepseek-7b": False, "qwen2-vl-7b": False,
    "deepseek-moe-16b": False, "musicgen-medium": False,
}


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module("repro.configs." + mod)


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def reduced_config(name: str) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    return _module(name).reduced()


def shape_cells(name: str):
    """The (shape_name -> spec) cells assigned to this arch, with skips."""
    cells = {}
    for shape, (seq, batch, mode) in SHAPES.items():
        if shape == "long_500k" and not LONG_CONTEXT_OK[name]:
            cells[shape] = None   # recorded as skipped
        else:
            cells[shape] = {"seq_len": seq, "global_batch": batch,
                            "mode": mode}
    return cells


def _shrink(cfg: ModelConfig, **over) -> ModelConfig:
    return dataclasses.replace(cfg, **over)
