"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads in every
block, outputs mean-fused [arXiv:2411.13676]. Attention is sliding-window
(the reference keeps 3 global layers; we window all layers and note the
deviation in DESIGN.md)."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        num_layers=32, d_model=1600, d_ff=5504, vocab_size=32_001,
        num_heads=25, num_kv_heads=5, head_dim=64,
        window_size=1024, window_pattern=1,
        block="hybrid", ssm_state=16, ssm_expand=2, ssm_head_dim=64,
        ssm_chunk=256,
        gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=128, vocab_size=97,
        num_heads=5, num_kv_heads=1, head_dim=16, window_size=8,
        ssm_state=8, ssm_head_dim=16, ssm_chunk=8, vocab_pad_multiple=8,
        gen_feature_dim=8, remat=False)
