"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191].

Modality stub: the ViT frontend is external; ``input_specs`` provides
precomputed patch embeddings (B, n_vis, d_model) prepended to the text
stream. M-RoPE uses 3 position streams over head-dim sections (16, 24, 24)."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        num_layers=28, d_model=3584, d_ff=18_944, vocab_size=152_064,
        num_heads=28, num_kv_heads=4,
        mrope_sections=(16, 24, 24),
        block="attn", modality="vision", num_vision_tokens=1024,
        gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=128, vocab_size=97,
        num_heads=4, num_kv_heads=2, mrope_sections=(4, 2, 2),
        num_vision_tokens=4, vocab_pad_multiple=8, gen_feature_dim=8,
        remat=False)
