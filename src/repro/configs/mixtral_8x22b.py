"""mixtral-8x22b [moe]: 56L d_model=6144 48H (kv=8) d_ff=16384/expert,
vocab=32768, 8 experts top-2, SWA [arXiv:2401.04088].
Router: top-k -> softmax (mistral convention)."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        num_layers=56, d_model=6144, d_ff=16_384, vocab_size=32_768,
        num_heads=48, num_kv_heads=8,
        window_size=4096, window_pattern=1,
        n_experts=8, n_shared_experts=0, top_k=2,
        router_norm="topk_softmax",
        block="attn", gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=96, vocab_size=97,
        num_heads=4, num_kv_heads=2, window_size=8, n_experts=4, top_k=2,
        vocab_pad_multiple=8, gen_feature_dim=8, remat=False)
