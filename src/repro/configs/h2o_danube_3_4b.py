"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818]."""
import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        num_layers=24, d_model=3840, d_ff=10_240, vocab_size=32_000,
        num_heads=32, num_kv_heads=8,
        window_size=4096, window_pattern=1,
        block="attn", gen_feature_dim=32,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_ff=160, vocab_size=97,
        num_heads=4, num_kv_heads=2, window_size=8, vocab_pad_multiple=8,
        gen_feature_dim=8, remat=False)
