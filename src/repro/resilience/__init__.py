"""repro.resilience — deterministic fault injection + degradation policies.

``faults`` is the injection substrate (DESIGN.md §13): named sites,
seeded (site, nth-call) → raise/hang/corrupt/delay schedules, context-
manager scoped, null-cost when disabled. The graceful-degradation
policies themselves live where the state lives — skip/rollback in
``train.loop``, retry/keep-stale in ``genfit.refresh``, shed/deadline/
poison-isolation in ``serve.engine``, verify-and-fall-back in
``checkpoint`` — this package only provides the levers that let the
chaos suite prove they work.
"""
from repro.resilience.faults import (Fault, FaultPlan, FaultRegistry,
                                     InjectedFault, active, fire, inject,
                                     install, poison, random_plan)

__all__ = ["Fault", "FaultPlan", "FaultRegistry", "InjectedFault",
           "active", "fire", "inject", "install", "poison", "random_plan"]
