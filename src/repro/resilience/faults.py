"""Deterministic fault injection (DESIGN.md §13).

A *fault plan* is a finite schedule mapping ``(site, nth)`` — a named
injection site and the 0-based index of a call to it — to an action:

    raise    raise :class:`InjectedFault` at the call site
    hang     sleep ``seconds`` (models a stuck worker; watchdogs must
             recover without waiting for it)
    delay    sleep ``seconds`` and continue (models stragglers / slow IO)
    corrupt  transform the payload passed to :func:`inject` (NaN-poison
             float arrays; models bad batches / flipped bits)

Sites are plain strings (``"train/batch"``, ``"checkpoint/write"``, ...);
the registry of wired sites lives in DESIGN.md §13. Call sites are
one-liners::

    faults.fire("serve/prefill")           # may raise / sleep
    batch = faults.inject("train/batch", batch)   # may also corrupt

Determinism: a plan fires as a pure function of the per-site invocation
counter, never of wall time or thread identity, so a replayed run sees
exactly the same faults at exactly the same calls — and a *re*-run of a
recovered region (rollback-replay) sees fresh invocation indices, i.e.
the fault does not re-fire. That is what makes "recoverable schedule ⇒
bit-equal to fault-free" a testable invariant rather than a hope.

Cost when disabled: module-level ``_ACTIVE`` is ``None`` and both entry
points return after a single attribute check — the same
null-singleton discipline as ``obs.NULL_REGISTRY``, safe to leave in
hot paths permanently.

Scoping: ``with faults.install(plan) as reg:`` activates a plan for the
dynamic extent (threads started inside see it too — the registry is
process-global, counters lock-protected). Subprocesses inherit plans via
the ``REPRO_FAULT_PLAN`` environment variable (JSON, read at import),
which is how the kill-mid-checkpoint test delays the writer from outside.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

_ACTIONS = ("raise", "hang", "delay", "corrupt")
_ENV_VAR = "REPRO_FAULT_PLAN"


class InjectedFault(RuntimeError):
    """Raised by a ``raise``-action fault. Carries (site, nth) so handlers
    and test assertions can tell injected failures from organic ones."""

    def __init__(self, site: str, nth: int):
        super().__init__(f"injected fault at {site}[{nth}]")
        self.site = site
        self.nth = nth


@dataclass(frozen=True)
class Fault:
    """One scheduled action: fire at the ``nth`` call to ``site``."""
    site: str
    nth: int
    action: str          # one of _ACTIONS
    seconds: float = 0.0  # hang / delay duration

    def __post_init__(self):
        assert self.action in _ACTIONS, self.action
        assert self.nth >= 0, self.nth


class FaultPlan:
    """Immutable schedule of :class:`Fault`s, keyed by (site, nth).

    Duplicate keys keep the first entry (hypothesis-generated schedules
    need not dedupe). JSON round-trip via :meth:`to_json` /
    :meth:`from_json` supports the env-var subprocess install.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        by_key: Dict[Tuple[str, int], Fault] = {}
        for f in faults:
            by_key.setdefault((f.site, f.nth), f)
        self._by_key = by_key

    @property
    def faults(self) -> Tuple[Fault, ...]:
        return tuple(self._by_key.values())

    def get(self, site: str, nth: int) -> Optional[Fault]:
        return self._by_key.get((site, nth))

    def sites(self) -> Tuple[str, ...]:
        return tuple(sorted({s for s, _ in self._by_key}))

    def to_json(self) -> str:
        return json.dumps([{"site": f.site, "nth": f.nth,
                            "action": f.action, "seconds": f.seconds}
                           for f in self.faults])

    @classmethod
    def from_json(cls, spec: str) -> "FaultPlan":
        return cls([Fault(**d) for d in json.loads(spec)])


class FaultRegistry:
    """Live counters + fired-fault log for one installed plan."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._counts: Dict[str, int] = {}
        self.fired: List[Fault] = []
        self._lock = threading.Lock()

    def next_fault(self, site: str) -> Optional[Fault]:
        """Advance ``site``'s invocation counter; return the scheduled
        fault for this call, if any."""
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            f = self.plan.get(site, n)
            if f is not None:
                self.fired.append(f)
            return f

    def count(self, site: str) -> int:
        with self._lock:
            return self._counts.get(site, 0)


# Process-global active registry. None ⇒ injection disabled (the fast
# path: one load + one compare per call site).
_ACTIVE: Optional[FaultRegistry] = None


def active() -> Optional[FaultRegistry]:
    return _ACTIVE


@contextmanager
def install(plan: FaultPlan):
    """Activate ``plan`` for the dynamic extent; restores the previous
    registry (usually None) on exit. Yields the :class:`FaultRegistry`."""
    global _ACTIVE
    reg = FaultRegistry(plan)
    prev = _ACTIVE
    _ACTIVE = reg
    try:
        yield reg
    finally:
        _ACTIVE = prev


def _act(f: Fault) -> None:
    if f.action == "raise":
        raise InjectedFault(f.site, f.nth)
    if f.action in ("hang", "delay"):
        time.sleep(f.seconds if f.seconds > 0 else 60.0
                   if f.action == "hang" else 0.0)
    # "corrupt" at a payload-free site is a no-op: nothing to transform.


def fire(site: str) -> None:
    """Hit ``site``. May raise :class:`InjectedFault` or sleep."""
    reg = _ACTIVE
    if reg is None:
        return
    f = reg.next_fault(site)
    if f is not None:
        _act(f)


def inject(site: str, value: Any) -> Any:
    """Hit ``site`` with a payload. ``corrupt`` actions return a
    NaN-poisoned copy of ``value``; other actions behave like
    :func:`fire` and return ``value`` unchanged."""
    reg = _ACTIVE
    if reg is None:
        return value
    f = reg.next_fault(site)
    if f is None:
        return value
    if f.action == "corrupt":
        return poison(value)
    _act(f)
    return value


def poison(value: Any) -> Any:
    """NaN-poison the first float array reachable in ``value`` (dict or
    array), copying — the caller's original is never mutated."""
    if isinstance(value, dict):
        out = dict(value)
        for k, v in value.items():
            a = np.asarray(v)
            if np.issubdtype(a.dtype, np.floating):
                bad = np.array(a, copy=True)
                bad.reshape(-1)[0] = np.nan
                out[k] = bad
                return out
        return out
    a = np.asarray(value)
    if np.issubdtype(a.dtype, np.floating):
        bad = np.array(a, copy=True)
        bad.reshape(-1)[0] = np.nan
        return bad
    return value


def random_plan(seed: int, sites: Sequence[str], n_faults: int,
                actions: Sequence[str] = ("raise", "delay", "corrupt"),
                max_nth: int = 8, seconds: float = 0.005) -> FaultPlan:
    """Seeded random schedule over ``sites`` — the chaos suite's generator
    when hypothesis isn't driving."""
    rng = np.random.default_rng(seed)
    faults = [Fault(site=str(rng.choice(list(sites))),
                    nth=int(rng.integers(0, max_nth)),
                    action=str(rng.choice(list(actions))),
                    seconds=seconds)
              for _ in range(n_faults)]
    return FaultPlan(faults)


def _install_from_env() -> None:
    spec = os.environ.get(_ENV_VAR)
    if spec:
        global _ACTIVE
        _ACTIVE = FaultRegistry(FaultPlan.from_json(spec))


_install_from_env()
