# Launch layer: meshes, dry-run, training/serving entry points.
# NOTE: do not import repro.launch.dryrun from here — it pins XLA_FLAGS and
# must be the first jax-touching import of its process.
