"""Production meshes. TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512.

``make_production_mesh`` is a FUNCTION (never a module constant) so importing
this module touches no jax device state — required because the dry-run pins
the host-device count before first jax init.
"""
from __future__ import annotations

import jax

from repro.parallel.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return make_mesh((n // model_axis, model_axis), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


# Hardware constants (TPU v5e), used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
