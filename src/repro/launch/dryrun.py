import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 placeholder host devices back the production
meshes (16,16) and (2,16,16).

Per cell:
  1. full-depth scanned compile on the production mesh — proves the
     sharding config is coherent, yields memory_analysis();
  2. unrolled L=1 and L=2 compiles — cost_analysis() + HLO collective
     bytes, extrapolated to full depth (XLA counts while bodies once;
     see repro.analysis.roofline);
  3. JSON artifact under benchmarks/artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k \
      --mesh both --out benchmarks/artifacts/dryrun
  python -m repro.launch.dryrun --all --skip-existing
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.analysis import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import lm_head, specs, transformer
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig
from repro.parallel import (batch_shardings, cache_shardings, params_shardings,
                            replicated, train_state_shardings)
from repro.train import (init_train_state, make_serve_step, make_train_step)
from repro.train.state import TrainState

OUT_DEFAULT = "benchmarks/artifacts/dryrun"


def _mesh(kind: str):
    return make_production_mesh(multi_pod=(kind == "multi"))


def _abstract_train_state(cfg: ModelConfig, opt_cfg, head_kind: str):
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                 head_kind))


# ---------------------------------------------------------------------------
# Cell builders: return (fn, input_specs, in_shardings) for one dict arg.
# ---------------------------------------------------------------------------

def build_train_cell(cfg: ModelConfig, mesh, seq_len: int, batch: int,
                     head_kind: str):
    hcfg = lm_head.head_config(cfg, head_kind, n_neg=1, reg=1e-3)
    opt_cfg = OptimizerConfig(name="adagrad", learning_rate=0.01,
                              clip_norm=1.0)
    train_step = make_train_step(cfg, hcfg, opt_cfg)

    def step(inputs):
        rng = jax.random.PRNGKey(inputs["seed"])
        state, metrics = train_step(inputs["state"], inputs["batch"], rng)
        return state, metrics

    state_abs = _abstract_train_state(cfg, opt_cfg, head_kind)
    batch_abs = specs.train_input_specs(cfg, seq_len, batch)
    in_spec = {"state": state_abs, "batch": batch_abs,
               "seed": jax.ShapeDtypeStruct((), jnp.uint32)}
    in_sh = {"state": train_state_shardings(cfg, mesh, state_abs),
             "batch": batch_shardings(cfg, mesh, batch_abs),
             "seed": replicated(mesh, jax.ShapeDtypeStruct((), jnp.uint32))}
    out_sh = (in_sh["state"], None)
    return step, in_spec, in_sh, out_sh


def build_decode_cell(cfg: ModelConfig, mesh, seq_len: int, batch: int,
                      head_kind: str):
    hcfg = lm_head.head_config(cfg, head_kind)
    serve_step = make_serve_step(cfg, hcfg)

    def step(inputs):
        tok, cache = serve_step(inputs["params"], inputs["head_state"],
                                inputs["token"], inputs["cache"],
                                inputs["cache_pos"],
                                positions=inputs.get("positions"))
        return tok, cache

    d_spec = specs.decode_input_specs(cfg, seq_len, batch)
    params_abs = specs.params_specs(cfg)
    head_abs = jax.eval_shape(
        lambda: lm_head.default_head_state(jax.random.PRNGKey(0), cfg,
                                           head_kind))
    in_spec = {"params": params_abs, "head_state": head_abs, **d_spec}
    cache_sh = cache_shardings(cfg, mesh, d_spec["cache"], batch)
    in_sh = {"params": params_shardings(cfg, mesh, params_abs),
             "head_state": replicated(mesh, head_abs),
             "token": batch_shardings(cfg, mesh, d_spec["token"]),
             "cache": cache_sh,
             "cache_pos": replicated(mesh, d_spec["cache_pos"])}
    if "positions" in d_spec:
        in_sh["positions"] = batch_shardings(cfg, mesh, d_spec["positions"])
    out_sh = (in_sh["token"], cache_sh)
    return step, in_spec, in_sh, out_sh


def build_prefill_cell(cfg: ModelConfig, mesh, seq_len: int, batch: int,
                       head_kind: str):
    hcfg = lm_head.head_config(cfg, head_kind)

    def step(inputs):
        h, cache, _ = transformer.forward(
            inputs["params"], cfg, inputs["tokens"],
            positions=inputs.get("positions"),
            vision_embeds=inputs.get("vision_embeds"),
            cache=inputs["cache"], cache_pos=jnp.int32(0))
        scores = lm_head.lm_predictive_scores(
            cfg, hcfg, lm_head.HeadParams(**inputs["params"]["head"]),
            inputs["head_state"], h[:, -1])
        token = jnp.argmax(scores, axis=-1).astype(jnp.int32)[:, None]
        return token, cache

    p_spec = specs.prefill_input_specs(cfg, seq_len, batch)
    cache_abs = jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, seq_len))
    params_abs = specs.params_specs(cfg)
    head_abs = jax.eval_shape(
        lambda: lm_head.default_head_state(jax.random.PRNGKey(0), cfg,
                                           head_kind))
    in_spec = {"params": params_abs, "head_state": head_abs,
               "cache": cache_abs, **p_spec}
    in_sh = {"params": params_shardings(cfg, mesh, params_abs),
             "head_state": replicated(mesh, head_abs),
             "cache": cache_shardings(cfg, mesh, cache_abs, batch),
             **{k: batch_shardings(cfg, mesh, v) for k, v in p_spec.items()}}
    out_sh = (batch_shardings(
        cfg, mesh, jax.ShapeDtypeStruct((batch, 1), jnp.int32)),
        in_sh["cache"])
    return step, in_spec, in_sh, out_sh


BUILDERS = {"train": build_train_cell, "decode": build_decode_cell,
            "prefill": build_prefill_cell}


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def compile_cell(cfg: ModelConfig, mesh, mode: str, seq_len: int,
                 batch: int, head_kind: str, seq_shard_attn: bool = False,
                 seq_parallel_residual: bool = False,
                 fsdp_gather: bool = False):
    import contextlib

    from repro.parallel.hints import sharding_hints
    from repro.parallel.sharding import mesh_axes

    build = BUILDERS[mode]
    step, in_spec, in_sh, out_sh = build(cfg, mesh, seq_len, batch,
                                         head_kind)
    jitted = jax.jit(step, in_shardings=(in_sh,), out_shardings=out_sh)
    dp_axes, model_axis = mesh_axes(mesh)
    any_hint = seq_shard_attn or seq_parallel_residual or fsdp_gather
    ctx = (sharding_hints(mesh, dp_axes, model_axis,
                          seq_shard_attention=seq_shard_attn,
                          seq_parallel_residual=seq_parallel_residual,
                          fsdp_gather_weights=fsdp_gather)
           if any_hint else contextlib.nullcontext())
    with ctx:
        lowered = jitted.lower(in_spec)
    compiled = lowered.compile()
    return compiled


def run_cell(arch: str, shape: str, mesh_kind: str, head_kind: str,
             with_cost: bool = True,
             cfg_override=None, seq_shard_attn: bool = False,
             seq_parallel_residual: bool = False,
             fsdp_gather: bool = False
             ) -> Dict[str, Any]:
    cfg = cfg_override or cfg_lib.get_config(arch)
    cell = cfg_lib.shape_cells(arch)[shape]
    if cell is None:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "long-context requires sub-quadratic attention"}
    mesh = _mesh(mesh_kind)
    mode, seq_len, batch = cell["mode"], cell["seq_len"], cell["global_batch"]
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "mode": mode,
        "seq_len": seq_len, "global_batch": batch, "head": head_kind,
        "chips": mesh.size, "status": "ok",
    }
    result["seq_shard_attn"] = seq_shard_attn
    result["seq_parallel_residual"] = seq_parallel_residual
    result["fsdp_gather"] = fsdp_gather
    t0 = time.time()
    compiled = compile_cell(cfg, mesh, mode, seq_len, batch, head_kind,
                            seq_shard_attn=seq_shard_attn,
                            seq_parallel_residual=seq_parallel_residual,
                            fsdp_gather=fsdp_gather)
    result["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    if ma is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                result[k] = int(v)
        result["bytes_per_device"] = int(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0))

    if with_cost:
        # Unrolled L=1 / L=2 for trip-count-correct cost extrapolation.
        reports = []
        for n_layers in (1, 2):
            cfg_small = dataclasses.replace(cfg, num_layers=n_layers,
                                            scan_layers=False, remat=False)
            comp = compile_cell(
                cfg_small, mesh, mode, seq_len, batch, head_kind,
                seq_shard_attn=seq_shard_attn,
                seq_parallel_residual=seq_parallel_residual,
                fsdp_gather=fsdp_gather)
            reports.append(rl.report_from_compiled(comp))
        total = rl.extrapolate_layers(reports[0], reports[1],
                                      cfg.num_layers)
        n_active = cfg.active_param_count()
        tokens = batch * seq_len if mode in ("train", "prefill") else batch
        mf = (6.0 if mode == "train" else 2.0) * n_active * tokens
        roof = rl.roofline_terms(total, mesh.size, mf)
        result.update({
            "hlo_flops_per_device": total.flops,
            "hlo_bytes_per_device": total.bytes_accessed,
            "collective_bytes_per_device": total.collective_total,
            "collectives": total.collectives,
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "bottleneck": roof.bottleneck,
            "model_flops": mf,
            "useful_flops_fraction": roof.useful_flops_fraction,
            "mfu_bound": roof.mfu_bound,
        })
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--head", default="adversarial_ns")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the L=1/L=2 cost compiles")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--seq-shard-attn", action="store_true",
                    help="perf hint: sequence-shard attention for archs "
                         "with non-TP-divisible head counts")
    ap.add_argument("--seq-parallel-residual", action="store_true",
                    help="perf hint: Megatron-style sequence-parallel "
                         "residual stream")
    ap.add_argument("--fsdp-gather", action="store_true",
                    help="perf hint: all-gather bf16 weight copies over "
                         "the data axes (explicit-FSDP guidance)")
    ap.add_argument("--softmax-dtype", default=None,
                    help="override attention softmax dtype (e.g. bfloat16)")
    ap.add_argument("--ssm-chunk", type=int, default=None,
                    help="override the SSD chunk length (perf knob: the "
                         "intra-chunk decay matrix scales linearly in it)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default=OUT_DEFAULT)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(cfg_lib.ARCHS)
    shapes = [args.shape] if args.shape else list(cfg_lib.SHAPES)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}_{shape}_{mesh_kind}_{args.head}{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                try:
                    cfg_over = None
                    over = {}
                    if args.softmax_dtype:
                        over["softmax_dtype"] = args.softmax_dtype
                    if args.ssm_chunk:
                        over["ssm_chunk"] = args.ssm_chunk
                    if over:
                        cfg_over = dataclasses.replace(
                            cfg_lib.get_config(arch), **over)
                    res = run_cell(
                        arch, shape, mesh_kind, args.head,
                        with_cost=not args.no_cost,
                        cfg_override=cfg_over,
                        seq_shard_attn=args.seq_shard_attn,
                        seq_parallel_residual=args.seq_parallel_residual,
                        fsdp_gather=args.fsdp_gather)
                except Exception as e:          # noqa: BLE001
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "head": args.head, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok" and "bottleneck" in res:
                    extra = (f" bottleneck={res['bottleneck']}"
                             f" mfu_bound={res['mfu_bound']:.3f}")
                print(f"[{status}] {tag}{extra}", flush=True)
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
