"""Training launcher: pjit the train step onto a mesh and run the
fault-tolerant loop.

On a TPU cluster this is the per-host entry point (jax.distributed +
make_production_mesh); on this CPU container it runs reduced configs on a
host mesh (set XLA_FLAGS=--xla_force_host_platform_device_count=8 to
exercise real multi-device sharding).

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --reduced \
      --steps 20 --head adversarial_ns --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.data import lm_batch_fn
from repro.models import lm_head
from repro.optim import OptimizerConfig
from repro.parallel import batch_shardings, train_state_shardings
from repro.train import (LoopConfig, init_train_state, make_train_step,
                         run_loop)
from repro.train.generator_fit import make_gen_fit_fn


def build(args):
    cfg = (cfg_lib.reduced_config(args.arch) if args.reduced
           else cfg_lib.get_config(args.arch))
    hcfg = lm_head.head_config(cfg, args.head, n_neg=args.n_neg,
                               reg=args.reg)
    opt = OptimizerConfig(name=args.optimizer, learning_rate=args.lr,
                          clip_norm=1.0,
                          head_name=args.head_optimizer,
                          state_dtype=args.state_dtype)
    return cfg, hcfg, opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=list(cfg_lib.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--head", default="adversarial_ns")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--reg", type=float, default=1e-4)
    ap.add_argument("--n-neg", type=int, default=1)
    ap.add_argument("--head-update", default="auto",
                    choices=("auto", "dense", "sparse"),
                    help="head-gradient path (DESIGN.md §8): sparse = "
                         "O(B·K·n_neg) touched-row updates (default for "
                         "sampled heads), dense = O(C·K) autodiff "
                         "(default/required for softmax)")
    ap.add_argument("--head-kernel", action="store_true",
                    help="route the sparse head loss through the fused "
                         "Pallas sampled_head_loss kernel")
    ap.add_argument("--optimizer", default="adagrad")
    ap.add_argument("--head-optimizer", default=None,
                    choices=(None, "sgd", "adagrad", "adamw", "sm3"),
                    help="override the optimizer for head params only "
                         "(DESIGN.md §11): 'sm3' keeps one row + one col "
                         "second-moment cover instead of the full (C, K) "
                         "slab — the 100M-label memory play")
    ap.add_argument("--state-dtype", default="fp32",
                    choices=("fp32", "bf16", "int8"),
                    help="storage dtype for the head optimizer "
                         "accumulators (compute stays fp32; int8 adds a "
                         "per-row scale)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--gen-warmup", type=int, default=0)
    ap.add_argument("--gen-refresh", type=int, default=0,
                    help="refit the generator every N steps (0 = once)")
    ap.add_argument("--gen-async", action="store_true",
                    help="fit in a background thread; swap at the "
                         "recorded step (submit + --gen-swap-delay)")
    ap.add_argument("--gen-swap-delay", type=int, default=4)
    ap.add_argument("--gen-method", default="levelwise",
                    choices=("levelwise", "sequential", "sharded"))
    ap.add_argument("--sampler", default="config",
                    choices=("config", "uniform", "unigram", "lsh", "rff"),
                    help="negative-sampling proposal (core.samplers): "
                         "'config' derives it from --head + the generator "
                         "state (the tree for adversarial_ns); the others "
                         "are fitted once from a model snapshot at startup "
                         "and override the head's default proposal")
    ap.add_argument("--gen-refresh-mode", default="period",
                    choices=("period", "snr"),
                    help="'period' refits every --gen-refresh steps; "
                         "'snr' refits when the online gradient-SNR proxy "
                         "(DESIGN.md §9) degrades past --snr-threshold x "
                         "its post-install reference")
    ap.add_argument("--snr-threshold", type=float, default=0.85)
    ap.add_argument("--snr-patience", type=int, default=8)
    ap.add_argument("--metrics-jsonl", default=None,
                    help="write the repro.obs JSONL event log (per-step "
                         "samples + genfit lifecycle, DESIGN.md §10) to "
                         "this path")
    ap.add_argument("--metrics-interval", type=int, default=1,
                    help="emit a 'step' JSONL event every N steps")
    ap.add_argument("--nonfinite-policy", default="skip",
                    choices=("skip", "raise"),
                    help="on a non-finite loss: 'skip' drops the update "
                         "in-graph and counts it (train/nonfinite_skipped"
                         "), escalating to rollback-restore after "
                         "--max-consecutive-nonfinite skips; 'raise' "
                         "fails fast (DESIGN.md §13)")
    ap.add_argument("--max-consecutive-nonfinite", type=int, default=3)
    ap.add_argument("--max-rollbacks", type=int, default=2,
                    help="rollback-restores allowed per run before the "
                         "loop gives up with FloatingPointError")
    ap.add_argument("--gen-fit-retries", type=int, default=2,
                    help="transient generator-fit failures absorbed by "
                         "retry (exponential backoff) before the loop "
                         "keeps the stale generator")
    ap.add_argument("--gen-fit-timeout", type=float, default=None,
                    help="watchdog seconds for a background fit; a hung "
                         "fit is abandoned and the stale generator kept "
                         "(default: wait forever)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of a few "
                         "steady-state steps into this directory (host "
                         "spans annotate the timeline)")
    args = ap.parse_args()

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(model_axis=args.model_axis)
    print(f"mesh: {dict(mesh.shape)} on {jax.device_count()} devices")

    cfg, hcfg, opt = build(args)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, args.head)
    state_sh = train_state_shardings(cfg, mesh, jax.eval_shape(lambda:
                                                               state))
    state = jax.device_put(state, state_sh)

    make = lm_batch_fn(cfg.vocab_size, args.batch, args.seq, seed=0)
    batch_abs = jax.eval_shape(lambda: {k: jnp.asarray(v)
                                        for k, v in make(0).items()})
    batch_sh = batch_shardings(cfg, mesh, batch_abs)
    # Sparse head updates run shard-local against the vocab-sharded head
    # (each model shard applies only the rows it owns — no all-gather).
    # Donating the TrainState lets XLA scatter the touched rows in place
    # instead of copying the (C, K) param/accumulator buffers to build the
    # functional update — without it the O(U·K) sparse step pays an
    # O(C·K) memcpy. Safe even with --gen-async: run_loop snapshots the
    # leaves the background fit reads (_fit_snapshot, snapshot-then-
    # donate) before submitting, so training can keep invalidating its
    # own buffers mid-fit.
    sampler = None
    if args.sampler != "config":
        # Fit the override proposal once from a startup snapshot, in the
        # head state's own feature space (x_gen = h @ proj) so sampling
        # and the Eq. 5 debias see identical features.
        from repro.train.generator_fit import fit_lm_sampler
        sampler, _ = fit_lm_sampler(
            args.sampler, state.params, cfg,
            ({k: jnp.asarray(v) for k, v in make(10_000 + i).items()}
             for i in range(4)),
            proj=state.head_state.proj)
        print(f"sampler: {type(sampler).__name__} (--sampler "
              f"{args.sampler})")

    donate = (0,)
    # skip_nonfinite puts the accept/reject select inside the jitted step
    # (donation invalidates the old buffers, so the guard cannot live in
    # Python) — the loop's degradation ladder builds on it.
    train_step = jax.jit(make_train_step(
        cfg, hcfg, opt, head_update=args.head_update,
        head_kernel=args.head_kernel, mesh=mesh, sampler=sampler,
        skip_nonfinite=(args.nonfinite_policy == "skip")),
                         in_shardings=(state_sh, batch_sh, None),
                         out_shardings=(state_sh, None),
                         donate_argnums=donate)

    def batch_fn(step):
        return jax.device_put({k: jnp.asarray(v)
                               for k, v in make(step).items()}, batch_sh)

    gen_cb = None
    if args.gen_warmup and args.head in ("adversarial_ns", "nce",
                                         "sampled_softmax", "freq_ns"):
        gen_cb = make_gen_fit_fn(
            cfg, lambda s: {k: jnp.asarray(v) for k, v in make(s).items()},
            kind=args.head, max_tokens=8192, method=args.gen_method)

    loop = LoopConfig(total_steps=args.steps,
                      checkpoint_every=max(args.steps // 2, 1),
                      checkpoint_dir=args.ckpt,
                      gen_warmup_steps=args.gen_warmup,
                      gen_refresh_steps=args.gen_refresh,
                      gen_async=args.gen_async,
                      gen_swap_delay=args.gen_swap_delay,
                      gen_refresh_mode=args.gen_refresh_mode,
                      snr_threshold=args.snr_threshold,
                      snr_patience=args.snr_patience,
                      metrics_jsonl=args.metrics_jsonl,
                      metrics_interval=args.metrics_interval,
                      profile_dir=args.profile_dir,
                      nonfinite_policy=args.nonfinite_policy,
                      max_consecutive_nonfinite=(
                          args.max_consecutive_nonfinite),
                      max_rollbacks=args.max_rollbacks,
                      gen_fit_retries=args.gen_fit_retries,
                      gen_fit_timeout_s=args.gen_fit_timeout)
    from repro.obs import Registry, console_summary
    registry = (Registry() if (args.metrics_jsonl or args.profile_dir)
                else None)
    state, hist = run_loop(
        state, train_step, batch_fn, loop, jax.random.PRNGKey(1),
        gen_fit_fn=gen_cb, registry=registry,
        on_step=lambda s, m: print(
            f"step {s:4d} loss={m['loss']:.4f} "
            f"{m['step_time']*1e3:.0f}ms", flush=True))
    print(f"final loss {hist['loss'][-1]:.4f}; "
          f"stragglers={hist['stragglers']}")
    if registry is not None:
        print(console_summary(registry, title="train metrics"))
        if args.metrics_jsonl:
            print(f"metrics JSONL: {args.metrics_jsonl}")
        if args.profile_dir:
            print(f"profile: {args.profile_dir} (load in TensorBoard / "
                  f"xprof; host spans annotate the trace)")


if __name__ == "__main__":
    main()
