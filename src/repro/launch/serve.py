"""Serving launcher: prefill + batched greedy decode on a mesh, with the
paper's Eq. 5 bias removal in the sampling path.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduced \
      --batch 4 --prompt-len 16 --gen 8

Two decode paths, selected by ``--topk-beam``:

- dense (default, ``--topk-beam 0``): every step computes all-C logits
  (O(C·K) matmul) plus the dense tree pass for log p_n (O(C·k)). Exact
  argmax; per-token cost grows linearly in the vocabulary. Right for eval
  and small C.
- beam (``--topk-beam B``, B > 0): beam search descends the adversarial
  generator tree to propose B candidates in O(B·k·log C), scores only those
  (gather-and-dot / gather_scores kernel), and applies Eq. 5 debiasing on
  the candidate set. Per-token cost is logarithmic in C — the serving path
  for extreme vocabularies — at the price of missing the exact argmax when
  the true top label falls outside the generator's beam (rare once the tree
  is fitted; `benchmarks/bench_serve.py` measures both cost and agreement).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.models import lm_head, transformer
from repro.parallel import (batch_shardings, cache_shardings,
                            params_shardings, replicated)
from repro.train import make_prefill, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=list(cfg_lib.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--head", default="adversarial_ns")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--topk-beam", type=int, default=0,
                    help="0 = dense O(C) scoring; B > 0 = tree-guided beam "
                         "search over B candidates, O(B k log C) per token")
    args = ap.parse_args()

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(model_axis=args.model_axis)
    cfg = (cfg_lib.reduced_config(args.arch) if args.reduced
           else cfg_lib.get_config(args.arch))
    max_len = args.prompt_len + args.gen

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, params_shardings(
        cfg, mesh, jax.eval_shape(lambda: params)))
    head_state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                            args.head)
    hcfg = lm_head.head_config(cfg, args.head)

    cache = transformer.init_cache(cfg, args.batch, max_len)
    cache_sh = cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache),
                               args.batch)
    cache = jax.device_put(cache, cache_sh)

    prefill = jax.jit(make_prefill(cfg))
    serve_step = jax.jit(make_serve_step(cfg, hcfg,
                                         topk_beam=args.topk_beam))

    prompts = jax.random.randint(jax.random.PRNGKey(2),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    _, cache = prefill(params, prompts, cache)
    jax.block_until_ready(jax.tree.leaves(cache)[0])
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{(time.time()-t0)*1e3:.0f} ms")

    token = prompts[:, -1:]
    toks = []
    t0 = time.time()
    for t in range(args.gen):
        token, cache = serve_step(params, head_state, token, cache,
                                  jnp.int32(args.prompt_len + t))
        toks.append(token)
    jax.block_until_ready(token)
    dt = time.time() - t0
    path = (f"beam={args.topk_beam}" if args.topk_beam
            else "dense debiased scores")
    print(f"decode {args.gen} steps: {dt*1e3:.0f} ms "
          f"({args.batch*args.gen/dt:.1f} tok/s) [{path}]")
    print("sample:", jnp.concatenate(toks, 1)[0].tolist())


if __name__ == "__main__":
    main()
