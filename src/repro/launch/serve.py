"""Serving launcher: continuous-batching engine (default) or the legacy
lock-step decode, with the paper's Eq. 5 bias removal in the sampling path.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduced \
      --batch 4 --prompt-len 16 --gen 8 [--topk-beam 32]

Decode paths, selected by ``--topk-beam``:

- dense (default, ``--topk-beam 0``): every step computes all-C logits
  (O(C·K) matmul) plus the dense tree pass for log p_n (O(C·k)). Exact
  argmax; per-token cost grows linearly in the vocabulary. Right for eval
  and small C.
- beam (``--topk-beam B``, B > 0): beam search descends the adversarial
  generator tree to propose B candidates in O(B·k·log C), scores only those
  (gather-and-dot / gather_scores kernel), and applies Eq. 5 debiasing on
  the candidate set. Per-token cost is logarithmic in C — the serving path
  for extreme vocabularies. ``--shard-scores`` routes the candidate scoring
  through ``sharded_candidate_scores`` on the mesh's model axis.

By default requests run through ``repro.serve.Engine``: a paged KV pool
(``--slots`` decode lanes, ``--page-len``/``--n-pages`` page geometry —
defaults reproduce the monolithic one-buffer-per-lane capacity; undersize
``--n-pages`` to pack more lanes into the same device bytes on mixed-length
traffic), FIFO admission with batched multi-request prefill, per-request
EOS / max-length retirement (``--eos-id``) with page reclamation, and the
prefix-keyed candidate cache on the beam path. ``--lockstep`` restores the
fixed-batch loop (still with EOS handling) for A/B comparison; the two
emit identical tokens.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfg_lib
from repro.models import lm_head, transformer
from repro.parallel import cache_shardings, params_shardings
from repro.train import make_prefill, make_serve_step


def run_lockstep(args, cfg, mesh, params, head_state, hcfg):
    """Fixed-batch decode: one lock-step batch, no admission. Rows that emit
    ``--eos-id`` are frozen (their subsequent tokens pinned to EOS) and the
    loop exits early once every row has finished."""
    max_len = args.prompt_len + args.gen
    cache = transformer.init_cache(cfg, args.batch, max_len)
    cache_sh = cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache),
                               args.batch)
    cache = jax.device_put(cache, cache_sh)

    prefill = jax.jit(make_prefill(cfg))
    serve_step = jax.jit(make_serve_step(
        cfg, hcfg, topk_beam=args.topk_beam,
        mesh=mesh if args.shard_scores else None))

    prompts = jax.random.randint(jax.random.PRNGKey(2),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    _, cache = prefill(params, prompts, cache)
    jax.block_until_ready(jax.tree.leaves(cache)[0])
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{(time.time()-t0)*1e3:.0f} ms")

    token = prompts[:, -1:]
    finished = np.zeros((args.batch,), bool)
    toks = []
    t0 = time.time()
    steps = 0
    for t in range(args.gen):
        token, cache = serve_step(params, head_state, token, cache,
                                  jnp.int32(args.prompt_len + t))
        steps += 1
        if args.eos_id >= 0:
            row = np.asarray(token[:, 0])
            row = np.where(finished, args.eos_id, row)
            finished |= row == args.eos_id
            token = jnp.asarray(row[:, None])
            toks.append(row[:, None])
            if finished.all():
                break
        else:
            toks.append(np.asarray(token))
    jax.block_until_ready(token)
    dt = time.time() - t0
    out = np.concatenate(toks, 1)
    if args.eos_id >= 0:
        # Real tokens only: everything after a row's first EOS is padding.
        hit = out == args.eos_id
        real = np.where(hit.any(1), hit.argmax(1) + 1, out.shape[1]).sum()
    else:
        real = out.size
    path = (f"beam={args.topk_beam}" if args.topk_beam
            else "dense debiased scores")
    print(f"decode {steps} steps: {dt*1e3:.0f} ms "
          f"({real/dt:.1f} tok/s) [{path}, lock-step]")
    print("sample:", out[0].tolist())


def run_engine(args, cfg, mesh, params, head_state, hcfg):
    from repro.obs import JsonlExporter, console_summary
    from repro.obs.trace import ProfileWindow
    from repro.serve import Engine, Request, ServeConfig

    slots = args.slots or args.batch
    exporter = (JsonlExporter(args.metrics_jsonl) if args.metrics_jsonl
                else None)
    engine = Engine(cfg, hcfg, params, head_state, ServeConfig(
        n_slots=slots, max_len=args.prompt_len + args.gen,
        page_len=args.page_len, n_pages=args.n_pages,
        beam=args.topk_beam,
        mesh=mesh if args.shard_scores else None,
        eos_id=args.eos_id if args.eos_id >= 0 else None,
        cache_dtype=jnp.bfloat16,
        prefix_sharing=args.prefix_sharing,
        spec_decode=args.spec_decode, max_draft=args.max_draft,
        preemption=args.preemption, page_growth=args.page_growth,
        max_queue=args.max_queue,
        enforce_deadlines=args.enforce_deadlines),
        exporter=exporter, metrics_interval=args.metrics_interval)
    metrics_server = None
    if args.metrics_port is not None:
        from repro.obs import start_metrics_server
        metrics_server = start_metrics_server(engine.registry,
                                              args.metrics_port,
                                              health_fn=engine.health)
        print(f"metrics endpoint: http://0.0.0.0:{metrics_server.port}"
              "/metrics (+ /healthz, /readyz)")
    if args.profile_dir:
        engine.registry.annotate = True     # spans label the trace
    prompts = jax.random.randint(jax.random.PRNGKey(2),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    prompts = np.asarray(prompts)

    t0 = time.time()
    handles = [engine.submit(Request(prompt=p, max_new_tokens=args.gen))
               for p in prompts]
    profiler = ProfileWindow(args.profile_dir, n_steps=10**9)
    profiler.tick(0)            # whole-run capture; bounded by --gen
    engine.run()
    profiler.stop()
    dt = time.time() - t0
    tokens = sum(len(h.tokens) for h in handles)
    path = (f"beam={args.topk_beam}" if args.topk_beam
            else "dense debiased scores")
    print(f"engine: {len(handles)} requests over {slots} slots in "
          f"{dt*1e3:.0f} ms ({len(handles)/dt:.1f} req/s, "
          f"{tokens/dt:.1f} tok/s) [{path}]")
    stats = engine.stats()
    lat = stats["latency"]
    print("stats:", {k: v for k, v in stats.items()
                     if k not in ("latency", "metrics")})
    for name in ("admission_wait", "ttft", "total"):
        s = lat[name]
        if s["count"]:
            print(f"  {name}: p50={s['p50']*1e3:.1f}ms "
                  f"p95={s['p95']*1e3:.1f}ms p99={s['p99']*1e3:.1f}ms "
                  f"(n={s['count']})")
    print(console_summary(engine.registry, title="serve metrics"))
    if exporter is not None:
        summary = {"event": "summary", "metrics": engine.registry.snapshot()}
        exporter.emit(summary)
        exporter.close()
        print(f"metrics JSONL: {args.metrics_jsonl}")
    if metrics_server is not None:
        metrics_server.close()
    print("sample:", handles[0].result().tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=list(cfg_lib.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--head", default="adversarial_ns")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests (and lock-step batch size)")
    ap.add_argument("--slots", type=int, default=0,
                    help="engine decode lanes (0 = --batch)")
    ap.add_argument("--page-len", type=int, default=0,
                    help="KV page size in positions (0 = one max_len page "
                         "per request: monolithic-equivalent; ignored for "
                         "pure-SSM archs, which have no KV arena)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="KV arena capacity in pages (0 = full per-lane "
                         "reservation; smaller packs more lanes into the "
                         "same device bytes on mixed-length traffic; "
                         "ignored for pure-SSM archs)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8,
                    help="max new tokens per request")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop token id (-1 = disabled)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--topk-beam", type=int, default=0,
                    help="0 = dense O(C) scoring; B > 0 = tree-guided beam "
                         "search over B candidates, O(B k log C) per token")
    ap.add_argument("--shard-scores", action="store_true",
                    help="score beam candidates via sharded_candidate_"
                         "scores on the mesh model axis")
    ap.add_argument("--lockstep", action="store_true",
                    help="legacy fixed-batch decode instead of the engine")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="stream repro.obs request/serve_step JSONL "
                         "events (DESIGN.md §10) to this path (engine "
                         "path only)")
    ap.add_argument("--metrics-interval", type=int, default=1,
                    help="emit a 'serve_step' event every N engine "
                         "iterations")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the engine run "
                         "into this directory")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the engine registry as Prometheus text on "
                         "this port (/metrics, stdlib HTTP thread; 0 = "
                         "ephemeral port, engine path only)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="share identical prompt-prefix KV pages across "
                         "requests (radix trie + refcounts + COW tails; "
                         "attention archs only)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decode with the fitted generator "
                         "tree as draft model (byte-identical outputs; "
                         "attention archs only)")
    ap.add_argument("--max-draft", type=int, default=4,
                    help="draft chain cap per speculative verify step")
    ap.add_argument("--preemption", action="store_true",
                    help="allow higher-priority admissions to spill "
                         "lower-priority lanes (byte-exact restore)")
    ap.add_argument("--page-growth", default="reserve",
                    choices=["reserve", "ondemand"],
                    help="KV page policy: worst-case reservation at "
                         "admission vs on-demand growth at page "
                         "boundaries")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="shed (reject with status='shed') submissions "
                         "once this many requests are pending (0 = "
                         "unbounded queue)")
    ap.add_argument("--enforce-deadlines", action="store_true",
                    help="abort queued/active requests whose deadline_s "
                         "expired (status='deadline'), reclaiming their "
                         "lanes and pages")
    args = ap.parse_args()

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(model_axis=args.model_axis)
    cfg = (cfg_lib.reduced_config(args.arch) if args.reduced
           else cfg_lib.get_config(args.arch))

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, params_shardings(
        cfg, mesh, jax.eval_shape(lambda: params)))
    head_state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                            args.head)
    hcfg = lm_head.head_config(cfg, args.head)

    if args.lockstep:
        run_lockstep(args, cfg, mesh, params, head_state, hcfg)
    else:
        run_engine(args, cfg, mesh, params, head_state, hcfg)


if __name__ == "__main__":
    main()
