"""Version-compat shims over ``jax.sharding`` APIs that moved across jax
releases.

``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exist in newer jax. All repo code and the subprocess
test snippets build meshes through :func:`make_mesh` below, which forwards
``axis_types`` when the installed jax understands it and silently drops it
otherwise (older jax treats every axis as Auto anyway, so behaviour is
unchanged). ``shard_map`` is re-exported from wherever the installed jax
keeps it (top-level vs ``jax.experimental``).
"""
from __future__ import annotations

import inspect

import jax

try:
    from jax.sharding import AxisType  # jax >= 0.4.38
    HAS_AXIS_TYPE = True
except ImportError:
    HAS_AXIS_TYPE = False

    class AxisType:  # type: ignore[no-redef]
        """Stand-in with the real enum's member names."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


try:
    shard_map = jax.shard_map  # graduated to the top level in newer jax
except AttributeError:
    from jax.experimental.shard_map import shard_map  # noqa: F401


_SHARD_MAP_REP_KWARG = next(
    (k for k in ("check_rep", "check_vma")
     if k in inspect.signature(shard_map).parameters), None)


def shard_map_norep(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking disabled.

    Control flow inside the mapped body (``lax.fori_loop`` — a ``while``
    HLO) has no replication rule, so bodies containing it can only run
    with the check off (the workaround jax itself names in the error).
    The kwarg was renamed ``check_rep`` -> ``check_vma`` across jax
    releases; forward whichever the installed jax understands.
    """
    kwargs = {}
    if _SHARD_MAP_REP_KWARG is not None:
        kwargs[_SHARD_MAP_REP_KWARG] = False
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kwargs)


_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def ensure_partitionable_rng() -> None:
    """Align older jax to the new-jax default of partitionable threefry.

    Newer jax defaults ``jax_threefry_partitionable`` to True, making
    ``jax.random`` draws independent of how operands are sharded. Older jax
    defaults it to False, where the same program samples *different* values
    on a mesh than on one device — which breaks sharded == single-device
    equivalence checks (and reproducibility of sampled negatives across
    mesh shapes). Call once before building meshes when that equivalence
    matters.
    """
    if not jax.config.jax_threefry_partitionable:
        jax.config.update("jax_threefry_partitionable", True)
