"""Host-level work partitioning for embarrassingly-parallel fit tasks.

The generator fit's subtrees (repro.genfit.sharded) are independent
problems with tiny results (node parameter rows + a leaf permutation
slice), so multi-host fitting is plain round-robin work division plus one
merge of disjoint arrays — no in-graph collectives needed. These helpers
keep that policy in one place; ``shard_index/shard_count`` default to the
JAX distributed runtime's process coordinates so the same call works
single-host and on a pod.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import numpy as np


def round_robin_shard(n_items: int, shard_index: Optional[int] = None,
                      shard_count: Optional[int] = None) -> List[int]:
    """Item ids owned by this shard: ``[i for i in range(n) if i % count
    == index]``. Defaults to ``jax.process_index()/process_count()``."""
    if shard_index is None:
        shard_index = jax.process_index()
    if shard_count is None:
        shard_count = jax.process_count()
    assert 0 <= shard_index < shard_count, (shard_index, shard_count)
    return [i for i in range(n_items) if i % shard_count == shard_index]


def merge_disjoint(parts: Sequence[np.ndarray],
                   fill: float = 0.0) -> np.ndarray:
    """Merge per-shard arrays whose written entries are disjoint (unwritten
    entries hold ``fill``). Used to combine sharded subtree-fit outputs
    after an all-gather (or any out-of-band exchange)."""
    assert parts, "nothing to merge"
    out = np.full_like(parts[0], fill)
    for p in parts:
        written = p != fill
        out[written] = p[written]
    return out
