from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     mesh_axes, param_spec, params_shardings,
                                     replicated, train_state_shardings)

__all__ = ["batch_shardings", "cache_shardings", "mesh_axes", "param_spec",
           "params_shardings", "replicated", "train_state_shardings"]
