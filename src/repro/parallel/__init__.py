from repro.parallel.compat import (AxisType, ensure_partitionable_rng,
                                   make_mesh)
from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     mesh_axes, paged_cache_shardings,
                                     param_spec, params_shardings,
                                     replicated, train_state_shardings)
from repro.parallel.work import merge_disjoint, round_robin_shard

__all__ = ["AxisType", "ensure_partitionable_rng", "make_mesh",
           "batch_shardings", "cache_shardings", "mesh_axes",
           "merge_disjoint", "paged_cache_shardings", "param_spec",
           "params_shardings", "replicated", "round_robin_shard",
           "train_state_shardings"]
