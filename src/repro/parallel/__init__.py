from repro.parallel.compat import (AxisType, ensure_partitionable_rng,
                                   make_mesh)
from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     mesh_axes, paged_cache_shardings,
                                     param_spec, params_shardings,
                                     replicated, train_state_shardings)

__all__ = ["AxisType", "ensure_partitionable_rng", "make_mesh",
           "batch_shardings", "cache_shardings", "mesh_axes",
           "paged_cache_shardings", "param_spec",
           "params_shardings", "replicated", "train_state_shardings"]
