"""Explicit shard_map collectives for the hot communication paths.

GSPMD's automatic partitioning is the baseline; these are the hand-rolled
versions used by the perf iterations and by the gradient-compression path:

- ``sharded_candidate_scores``: score sampled labels against a vocab-sharded
  output embedding — each model shard serves only the rows it owns, one psum
  of the (tiny) score tensor. Matches the masked-gather+allreduce GSPMD
  lowering but guarantees it (no all-gather fallback) and fuses the dot.
- ``compressed_grad_allreduce``: int8 error-feedback gradient all-reduce over
  the data axes (distributed-optimization trick for the pod-level DP
  collective; see repro.optim.compression).
- ``sharded_rows_update``: apply a per-row transform (the sparse-head
  optimizer update, DESIGN.md §8) at sampled ids of vocab-sharded arrays —
  each model shard gathers/updates only the rows it owns; replicated ids,
  no all-gather, no cross-shard traffic at all (the row ownership logic of
  ``sharded_candidate_scores``, reused for the write path).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map, shard_map_norep
from repro.parallel.sharding import mesh_axes


def sharded_candidate_scores(mesh: Mesh, w, b, h, ids):
    """xi_{ids} = w[ids] . h + b[ids] with w (V,K) sharded over 'model'.

    h: (..., K) replicated over 'model'; ids: (..., n). Output replicated
    over 'model' (one psum of the score tensor, O(batch·n) bytes).
    """
    dp_axes, model = mesh_axes(mesh)
    n_shards = mesh.shape[model]
    v = w.shape[0]
    shard_rows = v // n_shards

    def local(w_l, b_l, h_l, ids_l):
        me = jax.lax.axis_index(model)
        lo = me * shard_rows
        local_ids = ids_l - lo
        mine = (local_ids >= 0) & (local_ids < shard_rows)
        safe = jnp.clip(local_ids, 0, shard_rows - 1)
        rows = jnp.take(w_l, safe, axis=0)            # (..., n, K)
        scores = (jnp.einsum("...nk,...k->...n", rows.astype(jnp.float32),
                             h_l.astype(jnp.float32))
                  + jnp.take(b_l, safe).astype(jnp.float32))
        scores = jnp.where(mine, scores, 0.0)
        return jax.lax.psum(scores, model)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(model, None), P(model), P(*([None] * h.ndim)),
                  P(*([None] * ids.ndim))),
        out_specs=P(*([None] * ids.ndim)))(w, b, h, ids)


def sharded_rows_update(mesh: Mesh, fn, ids, vals, dense_arrays,
                        rep_arrays=(), with_mask: bool = False):
    """Row-local transform of vocab-sharded arrays at sampled ``ids``.

    dense_arrays: sequence of (V, ...) arrays sharded over 'model' on dim 0
    (params + optimizer accumulators); ids: (U,) int32, replicated, deduped
    (sentinel ids >= V are dropped); vals: sequence of (U, ...) replicated
    per-row gradient coefficients. ``fn(rows_tuple, vals_tuple) ->
    new_rows_tuple`` is the per-row optimizer math — ONE call covers every
    array touched by the update (w + b + their accumulators), so the whole
    sparse optimizer step is a single shard_map. Each shard resolves
    ``ids`` against the row range it owns (same ownership arithmetic as
    :func:`sharded_candidate_scores`), gathers only its rows, applies
    ``fn``, and scatters back — O(U·K) work per shard and zero collective
    traffic: non-owned and sentinel ids clamp on the gather and drop on
    the scatter.

    rep_arrays / with_mask extend the contract for factored state (the
    SM3 column cover, DESIGN.md §11): ``rep_arrays`` are small replicated
    arrays passed whole to ``fn``, whose updated values are recombined
    across shards with a pmax — exact because the cover update is a
    monotone max. When either is used, ``fn`` is called as
    ``fn(rows, vals, reps, mine) -> (new_rows, new_reps)`` where ``mine``
    is the (U,) ownership mask: non-owned ids gather clamped *garbage*
    rows carrying real gradient values, and fn must exclude them from any
    cross-row reduction (their row scatters are dropped regardless).
    """
    dp_axes, model = mesh_axes(mesh)
    n_shards = mesh.shape[model]
    n_vals = len(vals)
    n_dense = len(dense_arrays)
    extended = with_mask or rep_arrays
    for d in dense_arrays:
        assert d.shape[0] % n_shards == 0, (d.shape, n_shards)

    def local(ids_l, *rest):
        vals_l = rest[:n_vals]
        dense_l = rest[n_vals:n_vals + n_dense]
        reps_l = rest[n_vals + n_dense:]
        me = jax.lax.axis_index(model)
        rows, shard_rows = [], []
        mine_any = None
        for d in dense_l:
            n_rows = d.shape[0]
            loc = ids_l - me * n_rows
            safe = jnp.clip(loc, 0, n_rows - 1)
            rows.append(d[safe])
            shard_rows.append((loc, n_rows))
            if mine_any is None:
                mine_any = (loc >= 0) & (loc < n_rows)
        if extended:
            new_rows, new_reps = fn(tuple(rows), tuple(vals_l),
                                    tuple(reps_l), mine_any)
            new_reps = tuple(jax.lax.pmax(r, model) for r in new_reps)
        else:
            new_rows = fn(tuple(rows), tuple(vals_l))
            new_reps = ()
        out = []
        for d, r, (loc, n_rows) in zip(dense_l, new_rows, shard_rows):
            mine = (loc >= 0) & (loc < n_rows)
            tgt = jnp.where(mine, jnp.clip(loc, 0, n_rows - 1),
                            n_rows)                  # non-mine -> dropped
            out.append(d.at[tgt].set(r.astype(d.dtype), mode="drop"))
        return tuple(out) + new_reps

    rep = lambda a: P(*([None] * a.ndim))            # noqa: E731
    dense_spec = tuple(P(model, *([None] * (d.ndim - 1)))
                       for d in dense_arrays)
    rep_spec = tuple(rep(a) for a in rep_arrays)
    # norep: the lazy-AdamW catch-up replay (DESIGN.md §11) is a fori_loop,
    # and `while` has no shard_map replication rule. Replication still
    # holds by construction: rows carry the model axis, reps are pmax'd.
    out = shard_map_norep(
        local, mesh=mesh,
        in_specs=(rep(ids),) + tuple(rep(v) for v in vals) + dense_spec
        + rep_spec,
        out_specs=dense_spec + rep_spec)(
        ids, *vals, *dense_arrays, *rep_arrays)
    if extended:
        return out[:n_dense], out[n_dense:]
    return out


def compressed_grad_allreduce(mesh: Mesh, grads_stacked: Any, ef_stacked):
    """int8 error-feedback all-reduce over the data axes.

    Per-replica gradients arrive stacked on a leading replica axis of size
    n_dp, sharded over the data axes (shard_map gives each replica its own
    slice). Each replica quantizes (grad + residual) to int8; the int8
    payload is psum'd (4x fewer wire bytes than fp32); the shared max-scale
    dequantizes the sum; the quantization mismatch lands in the residual and
    is re-injected next step (EF-SGD).

    Returns (mean_grads replicated, new_ef stacked like the input).
    """
    from repro.optim.compression import _dequantize_leaf

    dp_axes, model = mesh_axes(mesh)
    n_rep = 1
    for a in dp_axes:
        n_rep *= mesh.shape[a]

    def leaf_fn(g, e):
        g = g[0]                      # local replica slice (1, ...) -> (...)
        e = e[0]
        corrected = g.astype(jnp.float32) + e
        # Shared scale across replicas so the int8 sum is exact.
        amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), dp_axes)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(
            jnp.int8)
        q_sum = jax.lax.psum(q.astype(jnp.int32), dp_axes)
        mean = q_sum.astype(jnp.float32) * scale / n_rep
        new_e = corrected - _dequantize_leaf(q, scale)
        return mean, new_e[None]

    def body(grads_l, err_l):
        out = jax.tree.map(leaf_fn, grads_l, err_l)
        means = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        errs = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        return means, errs

    stack_spec = jax.tree.map(
        lambda g: P(dp_axes, *([None] * (g.ndim - 1))), grads_stacked)
    mean_spec = jax.tree.map(
        lambda g: P(*([None] * (g.ndim - 1))), grads_stacked)
    return shard_map(
        body, mesh=mesh,
        in_specs=(stack_spec, stack_spec),
        out_specs=(mean_spec, stack_spec))(grads_stacked, ef_stacked)
