"""Explicit shard_map collectives for the hot communication paths.

GSPMD's automatic partitioning is the baseline; these are the hand-rolled
versions used by the perf iterations and by the gradient-compression path:

- ``sharded_candidate_scores``: score sampled labels against a vocab-sharded
  output embedding — each model shard serves only the rows it owns, one psum
  of the (tiny) score tensor. Matches the masked-gather+allreduce GSPMD
  lowering but guarantees it (no all-gather fallback) and fuses the dot.
- ``compressed_grad_allreduce``: int8 error-feedback gradient all-reduce over
  the data axes (distributed-optimization trick for the pod-level DP
  collective; see repro.optim.compression).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map
from repro.parallel.sharding import mesh_axes


def sharded_candidate_scores(mesh: Mesh, w, b, h, ids):
    """xi_{ids} = w[ids] . h + b[ids] with w (V,K) sharded over 'model'.

    h: (..., K) replicated over 'model'; ids: (..., n). Output replicated
    over 'model' (one psum of the score tensor, O(batch·n) bytes).
    """
    dp_axes, model = mesh_axes(mesh)
    n_shards = mesh.shape[model]
    v = w.shape[0]
    shard_rows = v // n_shards

    def local(w_l, b_l, h_l, ids_l):
        me = jax.lax.axis_index(model)
        lo = me * shard_rows
        local_ids = ids_l - lo
        mine = (local_ids >= 0) & (local_ids < shard_rows)
        safe = jnp.clip(local_ids, 0, shard_rows - 1)
        rows = jnp.take(w_l, safe, axis=0)            # (..., n, K)
        scores = (jnp.einsum("...nk,...k->...n", rows.astype(jnp.float32),
                             h_l.astype(jnp.float32))
                  + jnp.take(b_l, safe).astype(jnp.float32))
        scores = jnp.where(mine, scores, 0.0)
        return jax.lax.psum(scores, model)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(model, None), P(model), P(*([None] * h.ndim)),
                  P(*([None] * ids.ndim))),
        out_specs=P(*([None] * ids.ndim)))(w, b, h, ids)


def compressed_grad_allreduce(mesh: Mesh, grads_stacked: Any, ef_stacked):
    """int8 error-feedback all-reduce over the data axes.

    Per-replica gradients arrive stacked on a leading replica axis of size
    n_dp, sharded over the data axes (shard_map gives each replica its own
    slice). Each replica quantizes (grad + residual) to int8; the int8
    payload is psum'd (4x fewer wire bytes than fp32); the shared max-scale
    dequantizes the sum; the quantization mismatch lands in the residual and
    is re-injected next step (EF-SGD).

    Returns (mean_grads replicated, new_ef stacked like the input).
    """
    from repro.optim.compression import _dequantize_leaf

    dp_axes, model = mesh_axes(mesh)
    n_rep = 1
    for a in dp_axes:
        n_rep *= mesh.shape[a]

    def leaf_fn(g, e):
        g = g[0]                      # local replica slice (1, ...) -> (...)
        e = e[0]
        corrected = g.astype(jnp.float32) + e
        # Shared scale across replicas so the int8 sum is exact.
        amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), dp_axes)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(
            jnp.int8)
        q_sum = jax.lax.psum(q.astype(jnp.int32), dp_axes)
        mean = q_sum.astype(jnp.float32) * scale / n_rep
        new_e = corrected - _dequantize_leaf(q, scale)
        return mean, new_e[None]

    def body(grads_l, err_l):
        out = jax.tree.map(leaf_fn, grads_l, err_l)
        means = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        errs = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        return means, errs

    stack_spec = jax.tree.map(
        lambda g: P(dp_axes, *([None] * (g.ndim - 1))), grads_stacked)
    mean_spec = jax.tree.map(
        lambda g: P(*([None] * (g.ndim - 1))), grads_stacked)
    return shard_map(
        body, mesh=mesh,
        in_specs=(stack_spec, stack_spec),
        out_specs=(mean_spec, stack_spec))(grads_stacked, ef_stacked)
