"""Sharding rules: DP/FSDP over 'data' (+ pure-DP 'pod'), TP/EP over 'model'.

Policy (baseline; §Perf iterates on it):
  * activations: batch over ('pod','data'); d_model replicated on 'model'
  * attention:  q/kv heads over 'model' when divisible by TP, else FSDP-only
    (d_model dim over 'data') — awkward head counts (hymba 25H, qwen2-vl
    28H, musicgen 24H) fall back rather than padding the architecture
  * MLP: d_ff over 'model' (always divisible), d_model over 'data' (FSDP)
  * MoE: expert dim over 'model' when divisible (deepseek-moe 64e), else
    per-expert d_ff over 'model' (mixtral 8e)
  * embedding + head: vocab over 'model' — GSPMD partitions the token
    gather as masked-local-gather + all-reduce (verified), which is exactly
    the paper-head-friendly layout: candidate score gathers touch only the
    owning shard, and the sparse-head optimizer update (SparseRows leaves,
    DESIGN.md §8) writes shard-local through
    collectives.sharded_rows_update — no all-gather on read or write
  * optimizer state mirrors parameter sharding (ZeRO-style for free)
  * KV cache: batch over data axes; sequence over 'model' (decode attends
    with sharded-S logits; softmax reductions become psums). long-context
    B=1 shards the sequence over every axis.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey, tree_map_with_path

from repro.models.config import ModelConfig


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (data_axes, model_axis). 'pod' folds into data parallelism."""
    names = mesh.axis_names
    assert names[-1] == "model", names
    return tuple(names[:-1]), "model"


def _tp(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _dp(mesh: Mesh) -> int:
    dp_axes, _ = mesh_axes(mesh)
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if isinstance(p, DictKey):
            out.append(str(p.key))
        elif isinstance(p, SequenceKey):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def param_spec(cfg: ModelConfig, mesh: Mesh, path, leaf) -> P:
    """PartitionSpec for one parameter leaf (path-driven rules)."""
    names = _path_names(path)
    tp = _tp(mesh)
    shape = leaf.shape
    scan = cfg.scan_layers
    lead = (None,) if scan else ()   # stacked layer dim

    def div(n):
        return n % tp == 0

    if "embed" in names:
        return P("model", None)
    if "head" in names:
        return P("model", None) if len(shape) == 2 else P("model")
    if "attn" in names:
        d_over_data = "data"
        if names[-1] == "wq":
            return P(*lead, d_over_data,
                     "model" if div(cfg.num_heads) else None, None)
        if names[-1] in ("wk", "wv"):
            return P(*lead, d_over_data,
                     "model" if div(cfg.num_kv_heads) else None, None)
        if names[-1] == "wo":
            return P(*lead, "model" if div(cfg.num_heads) else None, None,
                     d_over_data)
    if "moe" in names:
        e_div = div(cfg.n_experts)
        if names[-1] == "router":
            return P(*lead, "data", None)
        if "shared" in names:
            if names[-1] == "w_down":
                return P(*lead, "model", "data")
            return P(*lead, "data", "model")
        if names[-1] in ("w_gate", "w_up"):
            return (P(*lead, "model", "data", None) if e_div
                    else P(*lead, None, "data", "model"))
        if names[-1] == "w_down":
            return (P(*lead, "model", None, "data") if e_div
                    else P(*lead, None, "model", "data"))
    if "mlp" in names:
        if names[-1] == "w_down":
            return P(*lead, "model", "data")
        return P(*lead, "data", "model")
    if "ssm" in names:
        if names[-1] == "w_in":
            return P(*lead, "data", None)
        if names[-1] == "w_out":
            return P(*lead, "model" if div(cfg.ssm_inner) else None, "data")
        return P(*lead) if scan else P()
    # norms, scalars, biases, conv weights: replicated.
    return P(*([None] * len(shape)))


def params_shardings(cfg: ModelConfig, mesh: Mesh, params_abstract: Any):
    return tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh,
                                         param_spec(cfg, mesh, path, leaf)),
        params_abstract)


def replicated(mesh: Mesh, tree: Any):
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, P(*([None] * len(getattr(leaf, "shape", ()))))), tree)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_abstract: Any):
    """Inputs: batch dim over data axes (replicate if batch == 1)."""
    dp_axes, _ = mesh_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        if names and names[-1] == "positions":        # (3, B, S)
            b = shape[1] if len(shape) > 1 else 0
            ax = dp_axes if b and b % _dp(mesh) == 0 else None
            return NamedSharding(mesh, P(None, ax, None))
        if not shape or shape[0] % _dp(mesh) != 0:
            return NamedSharding(mesh, P(*([None] * len(shape))))
        rest = [None] * (len(shape) - 1)
        return NamedSharding(mesh, P(dp_axes, *rest))

    return tree_map_with_path(spec, batch_abstract)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_abstract: Any,
                    batch: int):
    """KV/SSM cache sharding per the decode policy above."""
    dp_axes, model = mesh_axes(mesh)
    tp = _tp(mesh)
    big_batch = batch % _dp(mesh) == 0

    def spec(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        if names[-1] in ("k", "v"):                   # (L,B,S,KV,hd)
            if big_batch:
                return NamedSharding(mesh, P(None, dp_axes, model, None,
                                             None))
            all_axes = tuple(dp_axes) + (model,)
            return NamedSharding(mesh, P(None, None, all_axes, None, None))
        if names[-1] == "state":                      # (L,B,H,N,P)
            h_ax = model if cfg.ssm_heads % tp == 0 else None
            b_ax = dp_axes if big_batch else None
            return NamedSharding(mesh, P(None, b_ax, h_ax, None, None))
        if names[-1] == "conv":                       # (L,B,W,conv_dim)
            b_ax = dp_axes if big_batch else None
            return NamedSharding(mesh, P(None, b_ax, None, None))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return tree_map_with_path(spec, cache_abstract)


def paged_cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_abstract: Any,
                          n_lanes: int):
    """Sharding for the paged KV arena (repro.serve.PagedPool).

    K/V pages: any lane gathers any page, so the page dim stays replicated
    across data axes; the within-page sequence dim goes over 'model',
    carrying the decode policy above (sharded-S logits, psum'd softmax)
    into the paged layout. SSM conv/state leaves are lane-indexed and keep
    the contiguous-cache rules.
    """
    dp_axes, model = mesh_axes(mesh)
    tp = _tp(mesh)
    big_batch = n_lanes % _dp(mesh) == 0

    def spec(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        if names[-1] in ("k", "v"):                   # (L,P,page_len,KV,hd)
            return NamedSharding(mesh, P(None, None, model, None, None))
        if names[-1] == "state":                      # (L,lanes,H,N,P)
            h_ax = model if cfg.ssm_heads % tp == 0 else None
            b_ax = dp_axes if big_batch else None
            return NamedSharding(mesh, P(None, b_ax, h_ax, None, None))
        if names[-1] == "conv":                       # (L,lanes,W,conv_dim)
            b_ax = dp_axes if big_batch else None
            return NamedSharding(mesh, P(None, b_ax, None, None))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return tree_map_with_path(spec, cache_abstract)


def _state_leaf_sharding(mesh: Mesh, p_spec: P, s) -> Any:
    """Sharding for one optimizer-state leaf given its param's spec.

    Moment leaves are no longer exact shape mirrors of their param
    (DESIGN.md §11): an SM3 cover keeps a (C,) row + (K,) col vector, a
    QuantizedRows box keeps an int8 payload + (C,) scale, lazy-AdamW adds
    a (C,) int32 ``last`` vector. Row-indexed pieces take the param's
    dim-0 axis; the SM3 column cover is replicated (it is K elements and
    recombined by pmax); full-shape pieces mirror the param spec.
    """
    from repro.optim.compression import QuantizedRows
    from repro.optim.optimizers import Sm3Cover

    row_spec = P(p_spec[0]) if len(p_spec) else P()
    if s is None:
        return None
    if isinstance(s, Sm3Cover):
        return Sm3Cover(row=NamedSharding(mesh, row_spec),
                        col=NamedSharding(mesh, P(None)))
    if isinstance(s, QuantizedRows):
        return QuantizedRows(q=NamedSharding(mesh, p_spec),
                             scale=NamedSharding(mesh, row_spec))
    if s.ndim == 0:
        return NamedSharding(mesh, P())
    if s.ndim == 1 and len(p_spec) >= 1:
        return NamedSharding(mesh, row_spec)
    return NamedSharding(mesh, p_spec)


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, state_abstract):
    """TrainState sharding: params rules; opt state mirrors params
    (row-indexed factored/quantized leaves follow the param's dim-0 axis,
    see :func:`_state_leaf_sharding`); head generator state replicated
    (it is small and read-everywhere)."""
    from repro.optim.optimizers import _is_state_leaf, _state_leaves
    from repro.train.state import TrainState

    p_sh = params_shardings(cfg, mesh, state_abstract.params)

    def opt_mirror(opt_abs):
        flat_p, treedef = jax.tree_util.tree_flatten_with_path(
            state_abstract.params)
        specs = [param_spec(cfg, mesh, path, leaf) for path, leaf in flat_p]
        n = len(flat_p)

        def map_state_tree(tree):
            if tree is None:
                return None
            leaves = _state_leaves(tree, n)
            return jax.tree_util.tree_unflatten(
                treedef, [_state_leaf_sharding(mesh, sp, s)
                          for sp, s in zip(specs, leaves)])

        return type(opt_abs)(
            step=NamedSharding(mesh, P()),
            mu=map_state_tree(opt_abs.mu),
            nu=map_state_tree(opt_abs.nu),
            last=map_state_tree(getattr(opt_abs, "last", None)))

    return TrainState(
        step=NamedSharding(mesh, P()),
        params=p_sh,
        opt_state=opt_mirror(state_abstract.opt_state),
        head_state=replicated(mesh, state_abstract.head_state),
        gen_fit_step=NamedSharding(mesh, P()),
        snr_ewma=NamedSharding(mesh, P()),
        snr_ref=NamedSharding(mesh, P()))
