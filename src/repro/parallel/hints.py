"""Activation-sharding hints (perf iteration knobs).

Model code is mesh-agnostic; the launcher/dry-run installs a hint context
(mesh + axis roles) and layers call ``shard_hint`` at documented points.
With no context installed every hint is a no-op, so single-device tests and
CPU examples are untouched.

Current hints (see EXPERIMENTS.md §Perf for their measured effect):
  attn_q:   sequence-shard q (and thus the (B,H,Sq,Skv) logits) over the
            'model' axis when the head count is NOT divisible by TP — the
            fallback otherwise replicates all attention compute per model
            shard (musicgen 24H, hymba 25H, qwen2-vl 28H on TP=16).
  attn_out: restore the standard layout after the output projection.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_hints", default=None)


class HintContext:
    def __init__(self, mesh: Mesh, dp_axes: Tuple[str, ...],
                 model_axis: str = "model",
                 seq_shard_attention: bool = True,
                 seq_parallel_residual: bool = False,
                 fsdp_gather_weights: bool = False):
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.model_axis = model_axis
        self.seq_shard_attention = seq_shard_attention
        self.seq_parallel_residual = seq_parallel_residual
        self.fsdp_gather_weights = fsdp_gather_weights


@contextlib.contextmanager
def sharding_hints(mesh: Mesh, dp_axes: Tuple[str, ...],
                   model_axis: str = "model",
                   seq_shard_attention: bool = True,
                   seq_parallel_residual: bool = False,
                   fsdp_gather_weights: bool = False):
    tok = _CTX.set(HintContext(mesh, dp_axes, model_axis,
                               seq_shard_attention,
                               seq_parallel_residual,
                               fsdp_gather_weights))
    try:
        yield
    finally:
        _CTX.reset(tok)


def _constrain(x, spec: P):
    ctx = _CTX.get()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def hint_attn_q(q, num_heads: int):
    """q: (B, S, H, hd). Returns q possibly sequence-sharded over 'model'."""
    ctx = _CTX.get()
    if ctx is None or not ctx.seq_shard_attention:
        return q
    tp = ctx.mesh.shape[ctx.model_axis]
    b, s, h, _ = q.shape
    if num_heads % tp == 0 or s % tp != 0:
        return q        # head-sharding already covers it / S not divisible
    batch_ax = ctx.dp_axes if b % _dp(ctx) == 0 else None
    return _constrain(q, P(batch_ax, ctx.model_axis, None, None))


def hint_attn_out(out):
    """out: (B, S, d) back to the standard replicated-d layout."""
    ctx = _CTX.get()
    if ctx is None or not ctx.seq_shard_attention:
        return out
    b = out.shape[0]
    batch_ax = ctx.dp_axes if b % _dp(ctx) == 0 else None
    return _constrain(out, P(batch_ax, None, None))


def hint_gathered_weight(w, model_dims: Tuple[int, ...] = ()):
    """Constrain a (bf16-cast) weight copy to be replicated over the data
    axes while keeping its 'model' sharding (on the first divisible dim in
    ``model_dims``). Guides GSPMD to (a) all-gather the *bf16* copy instead
    of the fp32 master (half the FSDP bytes) and (b) contract dW fully
    BEFORE the data-axis collective — the transpose of the gather is a
    reduce-scatter of the small (weight-shaped) grad, instead of the
    mis-placed all-reduce of a huge backward intermediate observed on
    mixtral (§Perf B2/B3)."""
    ctx = _CTX.get()
    if ctx is None or not ctx.fsdp_gather_weights:
        return w
    tp = ctx.mesh.shape[ctx.model_axis]
    spec = [None] * w.ndim
    for dim in model_dims:
        if w.shape[dim] % tp == 0:
            spec[dim] = ctx.model_axis
            break
    return _constrain(w, P(*spec))


def hint_expert_act(x, token_dim: int = 1,
                    model_dims: Tuple[int, ...] = ()):
    """Pin an expert-matmul activation (E, tokens, …) to stay token-sharded
    over the data axes (TP kept on the first divisible dim of
    ``model_dims``). Needed alongside ``hint_gathered_weight``: with the
    weight copy replicated over 'data', GSPMD is otherwise free to
    *replicate the whole expert computation* per data shard (§Perf B3/B4)."""
    ctx = _CTX.get()
    if ctx is None or not ctx.fsdp_gather_weights:
        return x
    tp = ctx.mesh.shape[ctx.model_axis]
    spec = [None] * x.ndim
    if x.shape[token_dim] % _dp(ctx) == 0:
        spec[token_dim] = ctx.dp_axes
    for dim in model_dims:
        if dim != token_dim and x.shape[dim] % tp == 0:
            spec[dim] = ctx.model_axis
            break
    return _constrain(x, P(*spec))


def hint_residual(h):
    """h: (B, S, d) residual stream at layer boundaries. Megatron-style
    sequence parallelism: keep the stream S-sharded over 'model' so norms,
    residual adds and other elementwise work are not replicated per model
    shard; GSPMD inserts the all-gather before each matmul consumer and the
    reduce-scatter after each row-parallel projection."""
    ctx = _CTX.get()
    if ctx is None or not ctx.seq_parallel_residual:
        return h
    b, s, _ = h.shape
    tp = ctx.mesh.shape[ctx.model_axis]
    if s % tp != 0:
        return h
    batch_ax = ctx.dp_axes if b % _dp(ctx) == 0 else None
    return _constrain(h, P(batch_ax, ctx.model_axis, None))


def _dp(ctx: HintContext) -> int:
    n = 1
    for a in ctx.dp_axes:
        n *= ctx.mesh.shape[a]
    return n
