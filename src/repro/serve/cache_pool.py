"""Paged KV-cache pool for continuous batching.

The pool owns ONE device cache pytree, allocated once at engine start via
``transformer.init_paged_cache(cfg, n_lanes, n_pages + 1, page_len)``:
attention K/V leaves are a shared *arena* (L, n_pages + 1, page_len, ...)
of fixed-size pages (the extra physical page is the **sink** — the
designated garbage target for free lanes and padded prefill rows; one
page of deliberate overhead traded for simple, always-in-bounds
addressing over scatter-drop/gather-fill modes), and SSM conv/state
leaves stay lane-indexed (L, n_lanes, ...) since they have no sequence
dimension to page.

A request borrows two resources for its lifetime: a decode *lane* (a row of
the static decode batch) and ``pages_needed(prompt + max_new)`` *pages*
(rounded up to ``page_len``). Unlike the previous one-``max_len``-buffer-
per-slot layout, memory is charged for what the request can actually
reach, so skewed prompt/output lengths pack several times more concurrent
requests into the same device bytes:

            alloc(n)                                release(lane)
  free ───────────────▶ mapped to one lane ───────────────────────▶ free
  pages   lane + pages   (page_table row =    all the lane's pages
          assigned       [p0, p1, .., sink])  reclaimed, row reset to sink

Admission prefills the mapped pages (``make_batched_prefill`` scatters each
logical position p into ``(page_table[p // page_len], p % page_len)``),
decode steps scatter one row per step at the lane's own ``(page, offset)``,
and retirement returns lane and pages to their free lists — stale bytes
left in a reclaimed page are dead by construction (causal masking above the
next occupant's positions; prefill overwrites below), so there is no
host↔device traffic or reallocation in steady state. The jitted step
functions donate the arena, so XLA reuses the same device buffers step over
step.

Bookkeeping is host-side and O(n_lanes + n_pages); the device arrays never
change shape. Invariants (enforced, and property-tested in
``tests/test_serve_engine.py``): free and mapped pages always partition
``range(n_pages)``, no page is mapped by two live lanes, release reclaims
exactly the pages alloc handed out, and a drained pool is indistinguishable
from a fresh one.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig


class PagedPool:
    """Fixed arena of ``n_pages`` KV pages + ``n_lanes`` decode lanes with
    free-list allocation and per-lane page tables."""

    def __init__(self, cfg: ModelConfig, n_lanes: int, n_pages: int,
                 page_len: int, max_len: int, dtype=jnp.float32):
        assert n_lanes >= 1 and n_pages >= 1 and page_len >= 1
        assert max_len >= 2
        self.n_lanes = n_lanes
        self.n_pages = n_pages
        self.page_len = page_len
        self.max_len = max_len
        # Worst-case pages a single request can map; fixes the page-table
        # width (and with it the gathered KV length) at compile time.
        self.max_pages = -(-max_len // page_len)
        assert n_pages >= self.max_pages, (
            f"pool of {n_pages} pages cannot hold one max_len={max_len} "
            f"request ({self.max_pages} pages of {page_len})")
        self.sink = n_pages               # physical garbage page
        self.dtype = dtype
        self.cache = transformer.init_paged_cache(
            cfg, n_lanes, n_pages + 1, page_len, dtype=dtype)
        # LIFO free lists: recently retired lanes/pages are reused first
        # (warm in whatever memory tier the runtime keeps them in).
        self._free_pages: List[int] = list(range(n_pages - 1, -1, -1))
        self._free_lanes: List[int] = list(range(n_lanes - 1, -1, -1))
        self._pages_of: Dict[int, List[int]] = {}      # lane -> its pages
        # Host mirror of the device page tables, fed to every decode step.
        # Unmapped entries point at the sink page.
        self.page_table = np.full((n_lanes, self.max_pages), self.sink,
                                  np.int32)

    # -- allocation ------------------------------------------------------

    def pages_needed(self, total_len: int) -> int:
        """Pages covering ``total_len`` positions (prompt + max new)."""
        return max(1, -(-int(total_len) // self.page_len))

    @property
    def num_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def num_mapped_pages(self) -> int:
        return self.n_pages - len(self._free_pages)

    @property
    def num_free_lanes(self) -> int:
        return len(self._free_lanes)

    @property
    def num_in_use(self) -> int:
        return self.n_lanes - len(self._free_lanes)

    def can_admit(self, n_pages: int) -> bool:
        return bool(self._free_lanes) and len(self._free_pages) >= n_pages

    def alloc(self, n_pages: int) -> Optional[Tuple[int, List[int]]]:
        """Borrow one lane plus ``n_pages`` pages, or None when either
        resource is exhausted (all-or-nothing: no partial grants)."""
        assert 1 <= n_pages <= self.max_pages, n_pages
        if not self.can_admit(n_pages):
            return None
        lane = self._free_lanes.pop()
        assert lane not in self._pages_of, f"lane {lane} double-assigned"
        pages = [self._free_pages.pop() for _ in range(n_pages)]
        self._pages_of[lane] = pages
        row = self.page_table[lane]
        row[:] = self.sink
        row[:n_pages] = pages
        return lane, pages

    def release(self, lane: int) -> List[int]:
        """Return the lane and reclaim exactly its pages."""
        assert 0 <= lane < self.n_lanes
        assert lane in self._pages_of, f"lane {lane} released while free"
        pages = self._pages_of.pop(lane)
        self._free_pages.extend(pages)
        self._free_lanes.append(lane)
        self.page_table[lane] = self.sink
        return pages

    def check_invariants(self) -> None:
        """Free + mapped pages partition range(n_pages); no double-maps;
        page tables mirror the allocator; same for lanes."""
        free = set(self._free_pages)
        assert len(free) == len(self._free_pages), "dup page in free list"
        mapped: set = set()
        for lane, pages in self._pages_of.items():
            ps = set(pages)
            assert len(ps) == len(pages), f"lane {lane} maps a page twice"
            assert not (mapped & ps), "page mapped by two lanes"
            mapped |= ps
            row = self.page_table[lane]
            assert list(row[:len(pages)]) == pages, "page table out of sync"
            assert (row[len(pages):] == self.sink).all()
        assert free | mapped == set(range(self.n_pages))
        assert not (free & mapped)
        free_lanes = set(self._free_lanes)
        assert len(free_lanes) == len(self._free_lanes), "dup free lane"
        assert free_lanes | set(self._pages_of) == set(range(self.n_lanes))
        assert not (free_lanes & set(self._pages_of))
        for lane in free_lanes:
            assert (self.page_table[lane] == self.sink).all(), (
                f"free lane {lane} still holds page mappings")

    # -- device cache ----------------------------------------------------

    def swap_cache(self, new_cache: Any) -> Any:
        """Install the cache pytree returned by a jitted step (functional
        update; with donation the underlying buffers are the same)."""
        old, self.cache = self.cache, new_cache
        return old
