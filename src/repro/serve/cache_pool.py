"""Paged KV-cache pool for continuous batching, with shared pages.

The pool owns ONE device cache pytree, allocated once at engine start via
``transformer.init_paged_cache(cfg, n_lanes, n_pages + 1, page_len)``:
attention K/V leaves are a shared *arena* (L, n_pages + 1, page_len, ...)
of fixed-size pages (the extra physical page is the **sink** — the
designated garbage target for free lanes and padded prefill rows; one
page of deliberate overhead traded for simple, always-in-bounds
addressing over scatter-drop/gather-fill modes), and SSM conv/state
leaves stay lane-indexed (L, n_lanes, ...) since they have no sequence
dimension to page.

A request borrows two resources for its lifetime: a decode *lane* (a row of
the static decode batch) and some number of *pages*. Since PR 9 a physical
page may back the same logical content in SEVERAL lanes at once (shared
prompt prefixes, DESIGN.md §12), so page lifetime is refcounted:

                     alloc / alloc_shared                release(lane)
  free ──────────────────────────────────▶ rc ≥ 1 ──────────────────────┐
  pages    retain() bumps rc per mapping      │ rc hits 0               │
    ▲                                         ▼                         │
    └───── unregister/evict ──────────── cached (rc == 0, registered    │
                                          by the prefix index; holds    │
                                          reusable prefix KV, evictable │
                                          on demand) ◀──────────────────┘
                                                  (registered pages only;
                                                   others free directly)

Free, live (rc > 0) and cached pages always partition ``range(n_pages)``.
``rc(p)`` equals the number of lanes whose page table maps ``p``; a lane
never maps the same page twice. Copy-on-write (`cow`) gives a lane a
private duplicate of a shared page — a device-side page copy plus a remap,
never a whole-arena reallocation. Preemption uses `spill` (device→host
copy of the lane's pages + its SSM lane rows) and `restore` (fresh alloc +
exact byte scatter), so a preempted request resumes bit-identical without
re-running prefill. Stale bytes left in a reclaimed page are dead by
construction (causal masking above the next occupant's positions; prefill
overwrites below), so there is no host↔device traffic in steady state.

Bookkeeping is host-side and O(n_lanes + n_pages); the device arrays never
change shape. Invariants (enforced, and property-tested in
``tests/test_serve_engine.py``): the free/live/cached partition, refcount
== number of mapping lanes, cached ⇔ (rc == 0 and registered), release
unrefs exactly the pages the lane mapped, and a drained, unregistered pool
is indistinguishable from a fresh one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig

# Cache leaves indexed (L, page, offset, ...) — shareable / spillable by
# page. Everything else in the pytree is lane-indexed (L, lane, ...).
_PAGE_KEYS = ("k", "v")


@dataclasses.dataclass
class PageSpill:
    """Host-side byte image of one lane: its pages in logical order plus
    its lane-indexed rows (SSM conv/state, when present)."""
    n_pages: int
    pages: Dict[str, np.ndarray]        # key -> (L, n_pages, page_len, ...)
    lane_rows: Dict[str, np.ndarray]    # key -> (L, ...) single-lane rows

    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in self.pages.values()) + \
            sum(int(a.nbytes) for a in self.lane_rows.values())


class PagedPool:
    """Fixed arena of ``n_pages`` KV pages + ``n_lanes`` decode lanes with
    refcounted free-list allocation and per-lane page tables."""

    def __init__(self, cfg: ModelConfig, n_lanes: int, n_pages: int,
                 page_len: int, max_len: int, dtype=jnp.float32):
        assert n_lanes >= 1 and n_pages >= 1 and page_len >= 1
        assert max_len >= 2
        self.n_lanes = n_lanes
        self.n_pages = n_pages
        self.page_len = page_len
        self.max_len = max_len
        # Worst-case pages a single request can map; fixes the page-table
        # width (and with it the gathered KV length) at compile time.
        self.max_pages = -(-max_len // page_len)
        assert n_pages >= self.max_pages, (
            f"pool of {n_pages} pages cannot hold one max_len={max_len} "
            f"request ({self.max_pages} pages of {page_len})")
        self.sink = n_pages               # physical garbage page
        self.dtype = dtype
        self.cache = transformer.init_paged_cache(
            cfg, n_lanes, n_pages + 1, page_len, dtype=dtype)
        # LIFO free lists: recently retired lanes/pages are reused first
        # (warm in whatever memory tier the runtime keeps them in).
        self._free_pages: List[int] = list(range(n_pages - 1, -1, -1))
        self._free_lanes: List[int] = list(range(n_lanes - 1, -1, -1))
        self._pages_of: Dict[int, List[int]] = {}      # lane -> its pages
        self._refcount: Dict[int, int] = {}            # page -> #lanes
        # Pages pinned by the prefix index: when their refcount drops to 0
        # they park in ``_cached`` (KV bytes intact, evictable) instead of
        # returning to the free list.
        self._registered: set = set()
        self._cached: set = set()
        # Host mirror of the device page tables, fed to every decode step.
        # Unmapped entries point at the sink page.
        self.page_table = np.full((n_lanes, self.max_pages), self.sink,
                                  np.int32)
        self._copy_fn = None              # lazily-built jitted page copy

    # -- allocation ------------------------------------------------------

    def pages_needed(self, total_len: int) -> int:
        """Pages covering ``total_len`` positions (prompt + max new)."""
        return max(1, -(-int(total_len) // self.page_len))

    @property
    def num_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def num_cached_pages(self) -> int:
        return len(self._cached)

    @property
    def num_mapped_pages(self) -> int:
        """Pages live in at least one lane (rc > 0)."""
        return self.n_pages - len(self._free_pages) - len(self._cached)

    @property
    def num_free_lanes(self) -> int:
        return len(self._free_lanes)

    @property
    def num_in_use(self) -> int:
        return self.n_lanes - len(self._free_lanes)

    def can_admit(self, n_pages: int) -> bool:
        return bool(self._free_lanes) and len(self._free_pages) >= n_pages

    def can_admit_evicting(self, n_pages: int) -> bool:
        """Admissible if cached (evictable) pages were reclaimed first."""
        return bool(self._free_lanes) and \
            len(self._free_pages) + len(self._cached) >= n_pages

    def lane_pages(self, lane: int) -> List[int]:
        """The lane's pages in logical order (shared prefix first)."""
        return list(self._pages_of[lane])

    def refcount(self, page: int) -> int:
        return self._refcount.get(page, 0)

    def is_cached(self, page: int) -> bool:
        """rc == 0 but bytes kept for the prefix index (evictable)."""
        return page in self._cached

    def _take_free(self, n: int) -> List[int]:
        pages = [self._free_pages.pop() for _ in range(n)]
        for p in pages:
            assert self._refcount.get(p, 0) == 0
            self._refcount[p] = 1
        return pages

    def _unref(self, page: int) -> None:
        rc = self._refcount[page] - 1
        assert rc >= 0, f"page {page} over-released"
        if rc:
            self._refcount[page] = rc
            return
        del self._refcount[page]
        if page in self._registered:
            self._cached.add(page)        # prefix KV kept warm, evictable
        else:
            self._free_pages.append(page)

    def retain(self, page: int) -> None:
        """Bump a page's refcount for one more mapping lane. Revives
        cached pages (rc 0 → 1) without touching their bytes."""
        assert 0 <= page < self.n_pages
        if page in self._cached:
            self._cached.remove(page)
            assert page not in self._refcount
            self._refcount[page] = 1
        else:
            assert self._refcount.get(page, 0) > 0, (
                f"retain of free page {page}")
            self._refcount[page] += 1

    def _assign_lane(self, pages: List[int]) -> int:
        lane = self._free_lanes.pop()
        assert lane not in self._pages_of, f"lane {lane} double-assigned"
        self._pages_of[lane] = pages
        row = self.page_table[lane]
        row[:] = self.sink
        row[:len(pages)] = pages
        return lane

    def alloc(self, n_pages: int) -> Optional[Tuple[int, List[int]]]:
        """Borrow one lane plus ``n_pages`` fresh pages, or None when
        either resource is exhausted (all-or-nothing: no partial grants)."""
        assert 1 <= n_pages <= self.max_pages, n_pages
        if not self.can_admit(n_pages):
            return None
        pages = self._take_free(n_pages)
        return self._assign_lane(pages), pages

    def alloc_shared(self, shared: Sequence[int], n_private: int,
                     ) -> Optional[Tuple[int, List[int]]]:
        """Borrow one lane mapping ``shared`` (already-live or cached
        pages, refcounts bumped — their KV bytes are reused as-is) followed
        by ``n_private`` fresh pages. Returns (lane, private_pages)."""
        n_total = len(shared) + n_private
        assert 1 <= n_total <= self.max_pages, (len(shared), n_private)
        assert len(set(shared)) == len(shared), "duplicate shared page"
        if not self._free_lanes or len(self._free_pages) < n_private:
            return None
        for p in shared:
            self.retain(p)
        private = self._take_free(n_private)
        lane = self._assign_lane(list(shared) + private)
        return lane, private

    def grow(self, lane: int, n_new: int) -> Optional[List[int]]:
        """Append ``n_new`` fresh pages to a live lane (on-demand page
        growth at a page boundary). None if the free list is short."""
        assert lane in self._pages_of
        have = self._pages_of[lane]
        assert len(have) + n_new <= self.max_pages, (len(have), n_new)
        if len(self._free_pages) < n_new:
            return None
        pages = self._take_free(n_new)
        row = self.page_table[lane]
        row[len(have):len(have) + n_new] = pages
        have.extend(pages)
        return pages

    def release(self, lane: int) -> List[int]:
        """Return the lane and unref exactly its pages. Pages whose
        refcount hits 0 go to the free list, or park as cached when the
        prefix index has them registered."""
        assert 0 <= lane < self.n_lanes
        assert lane in self._pages_of, f"lane {lane} released while free"
        pages = self._pages_of.pop(lane)
        for p in pages:
            self._unref(p)
        self._free_lanes.append(lane)
        self.page_table[lane] = self.sink
        return pages

    # -- prefix-index registration --------------------------------------

    def register(self, pages: Sequence[int]) -> None:
        """Pin pages in the prefix index: on last unref they become
        cached (bytes kept, evictable) instead of free."""
        for p in pages:
            assert 0 <= p < self.n_pages
            assert self._refcount.get(p, 0) > 0 or p in self._cached, (
                f"registering free page {p}")
            self._registered.add(p)

    def unregister(self, pages: Sequence[int]) -> None:
        """Drop the prefix-index pin. Cached pages return to the free
        list immediately; live ones simply lose their parking spot."""
        for p in pages:
            self._registered.discard(p)
            if p in self._cached:
                self._cached.remove(p)
                self._free_pages.append(p)

    # -- device page ops -------------------------------------------------

    def _device_copy_pages(self, src: List[int], dst: List[int]) -> None:
        """Arena-level page copy (all layers), jitted with donation so the
        update is in-place on device rather than a full-arena realloc."""
        if self._copy_fn is None:
            def copy(cache, s, d):
                out = dict(cache)
                for key in _PAGE_KEYS:
                    if key in cache:
                        out[key] = cache[key].at[:, d].set(cache[key][:, s])
                return out
            self._copy_fn = jax.jit(copy, donate_argnums=(0,))
        self.cache = self._copy_fn(self.cache,
                                   jnp.asarray(src, jnp.int32),
                                   jnp.asarray(dst, jnp.int32))

    def cow(self, lane: int, logical_idx: int) -> int:
        """Copy-on-write: give ``lane`` a private copy of the page at
        ``logical_idx`` (device page copy + remap). The original keeps its
        other references / cached registration. Returns the new page."""
        assert lane in self._pages_of
        src = self._pages_of[lane][logical_idx]
        assert len(self._free_pages) >= 1, "cow with empty free list"
        [dst] = self._take_free(1)
        self._device_copy_pages([src], [dst])
        self._pages_of[lane][logical_idx] = dst
        self.page_table[lane, logical_idx] = dst
        self._unref(src)
        return dst

    def spill(self, lane: int) -> PageSpill:
        """Device→host byte image of the lane (pages in logical order +
        SSM lane rows). Caller releases the lane afterwards; restore()
        reproduces the exact bytes in freshly-allocated pages."""
        assert lane in self._pages_of
        pages = self._pages_of[lane]
        idx = np.asarray(pages, np.int32)
        out_pages: Dict[str, np.ndarray] = {}
        out_rows: Dict[str, np.ndarray] = {}
        for key, leaf in self.cache.items():
            if key in _PAGE_KEYS:
                out_pages[key] = np.asarray(jax.device_get(leaf[:, idx]))
            else:
                out_rows[key] = np.asarray(jax.device_get(leaf[:, lane]))
        return PageSpill(n_pages=len(pages), pages=out_pages,
                         lane_rows=out_rows)

    def restore(self, image: PageSpill) -> Optional[Tuple[int, List[int]]]:
        """Allocate a fresh lane + pages and scatter the spilled bytes
        back, byte-identical. None when the pool can't admit it now."""
        got = self.alloc(image.n_pages)
        if got is None:
            return None
        lane, pages = got
        idx = jnp.asarray(pages, jnp.int32)
        cache = dict(self.cache)
        for key, host in image.pages.items():
            cache[key] = cache[key].at[:, idx].set(
                jnp.asarray(host, cache[key].dtype))
        for key, host in image.lane_rows.items():
            cache[key] = cache[key].at[:, lane].set(
                jnp.asarray(host, cache[key].dtype))
        self.cache = cache
        return lane, pages

    def check_invariants(self) -> None:
        """Free/live/cached pages partition range(n_pages); refcounts
        count mapping lanes exactly; cached ⇔ (rc == 0 ∧ registered);
        page tables mirror the allocator; same for lanes."""
        free = set(self._free_pages)
        assert len(free) == len(self._free_pages), "dup page in free list"
        live = {p for p, rc in self._refcount.items() if rc > 0}
        assert set(self._refcount) == live, "zero refcount retained"
        assert not (free & live), "free page has a refcount"
        assert not (free & self._cached), "cached page in free list"
        assert not (live & self._cached), "cached page is live"
        assert free | live | self._cached == set(range(self.n_pages))
        assert self._cached <= self._registered, "cached but unregistered"
        counts: Dict[int, int] = {}
        for lane, pages in self._pages_of.items():
            ps = set(pages)
            assert len(ps) == len(pages), f"lane {lane} maps a page twice"
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
            row = self.page_table[lane]
            assert list(row[:len(pages)]) == pages, "page table out of sync"
            assert (row[len(pages):] == self.sink).all()
        assert counts == self._refcount, (
            f"refcounts {self._refcount} != mapping counts {counts}")
        free_lanes = set(self._free_lanes)
        assert len(free_lanes) == len(self._free_lanes), "dup free lane"
        assert free_lanes | set(self._pages_of) == set(range(self.n_lanes))
        assert not (free_lanes & set(self._pages_of))
        for lane in free_lanes:
            assert (self.page_table[lane] == self.sink).all(), (
                f"free lane {lane} still holds page mappings")

    # -- device cache ----------------------------------------------------

    def swap_cache(self, new_cache: Any) -> Any:
        """Install the cache pytree returned by a jitted step (functional
        update; with donation the underlying buffers are the same)."""
        old, self.cache = self.cache, new_cache
        return old
