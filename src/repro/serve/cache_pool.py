"""Slot-based KV-cache pool for continuous batching.

The pool owns ONE device cache pytree, allocated once at engine start via
``transformer.init_cache(cfg, n_slots, max_len)``: leaves are
(L, n_slots, max_len, ...) for attention K/V and (L, n_slots, ...) for SSM
conv/state. Requests borrow a *slot* (a batch row) for their lifetime:

  free ──alloc()──▶ in-use ──release()──▶ free

Admission prefills the slot (overwriting rows [0, prompt_len) plus the SSM
state), decode steps write one row per step at the slot's own ``cache_pos``,
and retirement just returns the slot index to the free list — the stale
bytes left behind are dead by construction (causal masking below the next
occupant's positions; prefill overwrites the live region), so there is no
host↔device traffic or reallocation in steady state. The jitted step
functions donate the cache argument, so XLA reuses the same device buffers
step over step.

Bookkeeping is host-side and O(n_slots); the device arrays never change
shape. Invariants (enforced, and property-tested in
``tests/test_serve_engine.py``): a slot is never handed out twice without
an intervening release, never released twice, and ``free + in-use`` is
always a partition of ``range(n_slots)``.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


class SlotPool:
    """Fixed pool of ``n_slots`` KV-cache rows with free-list allocation."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 dtype=jnp.float32):
        assert n_slots >= 1 and max_len >= 2
        self.n_slots = n_slots
        self.max_len = max_len
        self.dtype = dtype
        self.cache = transformer.init_cache(cfg, n_slots, max_len,
                                            dtype=dtype)
        # LIFO free list: retired slots are reused first (their buffers are
        # warm in whatever memory tier the runtime keeps them in).
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._in_use = [False] * n_slots

    # -- allocation ------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self) -> Optional[int]:
        """Borrow a free slot index, or None when the pool is saturated."""
        if not self._free:
            return None
        slot = self._free.pop()
        assert not self._in_use[slot], f"slot {slot} double-assigned"
        self._in_use[slot] = True
        return slot

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots
        assert self._in_use[slot], f"slot {slot} released while free"
        self._in_use[slot] = False
        self._free.append(slot)

    def check_invariants(self) -> None:
        """Free list and in-use flags partition range(n_slots) exactly."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate slot in free list"
        for s in range(self.n_slots):
            assert (s in free) != self._in_use[s], (
                f"slot {s}: free={s in free} in_use={self._in_use[s]}")

    # -- device cache ----------------------------------------------------

    def swap_cache(self, new_cache: Any) -> Any:
        """Install the cache pytree returned by a jitted step (functional
        update; with donation the underlying buffers are the same)."""
        old, self.cache = self.cache, new_cache
        return old
