"""Draft sources for speculative decode (DESIGN.md §12).

Speculative decode needs a cheap proposal chain ``d1..dk``; correctness
never depends on it (the verify step accepts exactly the longest prefix
the target model would have produced lock-step, so a bad draft costs only
wasted FLOPs). The drafts here come from the *fitted generator tree*
itself, in two forms:

- **Replay** (`ContinuationStore`): every token the engine emits is the
  tree's own greedy choice at some context; the store records
  ``context → next token`` and a draft is the stored chain walked k deep.
  On shared-prefix / repeat traffic (the adversarial benchmark shape)
  whole continuations replay and the mean accepted length approaches k.
- **Stale-feature seed**: the verify step scores EVERY draft position in
  one batched forward, so the tree's prediction one past the accepted
  prefix (the "bonus" token) is free — the engine feeds those selections
  back through `observe`, which is exactly the tree acting as its own
  draft model at one-step-stale features.

Entries are keyed by head-state *version* (bumped on `swap_head_state`)
so a hot-swapped classifier can never replay a stale tree's outputs, and
the store is a bounded LRU — eviction only ever costs future draft hits.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Protocol, Tuple

Ctx = Tuple[int, ...]

# Drafting conditions on at most this many trailing tokens: contexts this
# deep identify the continuation in practice, and bounded keys keep the
# store O(capacity) memory regardless of prompt length.
CTX_WINDOW = 48


class DraftSource(Protocol):
    """Proposal interface the engine drives. ``propose`` may return fewer
    than ``k`` tokens (including none); ``observe`` feeds back every token
    the engine actually emitted so the source can learn continuations."""

    def propose(self, ctx: Ctx, k: int) -> List[int]: ...

    def observe(self, ctx: Ctx, token: int) -> None: ...

    def bump_version(self) -> None: ...


class ContinuationStore:
    """Version-keyed LRU of ``trailing-context → next token``."""

    def __init__(self, capacity: int = 8192):
        assert capacity >= 1
        self.capacity = capacity
        self.version = 0
        self._map: "OrderedDict[Tuple[int, Ctx], int]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _key_ctx(ctx: Ctx) -> Ctx:
        return ctx[-CTX_WINDOW:]

    def put(self, ctx: Ctx, token: int) -> None:
        key = (self.version, self._key_ctx(ctx))
        self._map[key] = int(token)
        self._map.move_to_end(key)
        while len(self._map) > self.capacity:
            self._map.popitem(last=False)

    def get(self, ctx: Ctx) -> Optional[int]:
        key = (self.version, self._key_ctx(ctx))
        tok = self._map.get(key)
        if tok is None:
            self.misses += 1
            return None
        self._map.move_to_end(key)
        self.hits += 1
        return tok

    def chain(self, ctx: Ctx, k: int) -> List[int]:
        """Walk stored continuations up to ``k`` tokens deep."""
        out: List[int] = []
        cur = ctx
        for _ in range(k):
            tok = self.get(cur)
            if tok is None:
                break
            out.append(tok)
            cur = cur + (tok,)
        return out

    def bump_version(self) -> None:
        """Invalidate everything recorded under the old head state.
        Entries age out of the LRU rather than being swept eagerly."""
        self.version += 1


class ReplayDraft:
    """`DraftSource` over a `ContinuationStore`: proposes the recorded
    continuation chain of the current context."""

    def __init__(self, capacity: int = 8192):
        self.store = ContinuationStore(capacity)

    def propose(self, ctx: Ctx, k: int) -> List[int]:
        return self.store.chain(ctx, k)

    def observe(self, ctx: Ctx, token: int) -> None:
        self.store.put(ctx, token)

    def bump_version(self) -> None:
        self.store.bump_version()


class NullDraft:
    """Always-empty proposals: speculative plumbing with lock-step
    behavior (every verify step advances exactly one token)."""

    def propose(self, ctx: Ctx, k: int) -> List[int]:
        return []

    def observe(self, ctx: Ctx, token: int) -> None:
        pass

    def bump_version(self) -> None:
        pass
