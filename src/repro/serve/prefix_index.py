"""Radix index over token histories → physical KV pages (DESIGN.md §12).

The trie is chunked at page granularity: each edge is a tuple of exactly
``page_len`` tokens, and the node it leads to names the ONE canonical
physical page whose KV encodes those tokens *in the context of the path
above it*. A page is shareable read-only because its KV depends only on
tokens up to its last position — every request whose prompt starts with
the same ``(depth+1) * page_len`` tokens computes bit-identical K/V for
that page, so they can all gather through it (refcounts in ``PagedPool``
keep it alive; nobody writes a full prompt page after prefill).

Partial *tail* pages (a prompt's last ``len % page_len`` tokens) can't be
shared read-only — the owner keeps writing its own decode KV into the
same physical page — so they are indexed separately per node, keyed by
the exact remaining-token tuple, and reused by **copy-on-write**: an
exact-prompt repeat device-copies the tail page into a private page and
skips its prefill; bytes at offsets past the tail are the donor's decode
KV, dead for the new request by causal masking until overwritten by its
own writes at those very positions.

Lifetime: nodes are registered with the pool (``pool.register``) so their
pages park as *cached* (bytes intact, evictable) when the last mapping
lane releases, instead of returning to the free list. Eviction is
LRU leaf-first — since any lane mapping a child page also maps its parent
(page tables list the whole prefix), rc(parent) ≥ rc(child), so an
evictable (rc == 0) interior node can only appear once its entire subtree
is evictable; draining leaves bottom-up never strands reachable pages.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

TokenChunk = Tuple[int, ...]


class _Node:
    __slots__ = ("chunk", "page", "parent", "children", "tails",
                 "tail_ticks", "tick")

    def __init__(self, chunk: Optional[TokenChunk], page: Optional[int],
                 parent: Optional["_Node"]):
        self.chunk = chunk
        self.page = page                  # None only at the root
        self.parent = parent
        self.children: Dict[TokenChunk, _Node] = {}
        self.tails: Dict[TokenChunk, int] = {}       # tail tokens -> page
        self.tail_ticks: Dict[TokenChunk, int] = {}
        self.tick = 0


class PrefixMatch:
    """Result of a longest-prefix lookup."""
    __slots__ = ("pages", "tokens_matched", "tail_page", "tail_len")

    def __init__(self, pages: List[int], tokens_matched: int,
                 tail_page: Optional[int], tail_len: int):
        self.pages = pages                # full shared pages, logical order
        self.tokens_matched = tokens_matched
        self.tail_page = tail_page        # COW donor for the exact tail
        self.tail_len = tail_len


class PrefixIndex:
    """Page-granular radix trie with LRU leaf-first eviction."""

    def __init__(self, page_len: int):
        assert page_len >= 1
        self.page_len = page_len
        self.root = _Node(None, None, None)
        self._clock = itertools.count(1)
        self.n_nodes = 0
        self.n_tails = 0

    # -- lookup ----------------------------------------------------------

    def match(self, tokens) -> PrefixMatch:
        """Longest registered prefix of ``tokens``: full-page path first,
        then (only when every full chunk matched) an exact-tail COW donor.
        Touches the LRU clock on everything it returns."""
        toks = [int(t) for t in tokens]
        pl = self.page_len
        n_full = len(toks) // pl
        node, pages = self.root, []
        tick = next(self._clock)
        for i in range(n_full):
            child = node.children.get(tuple(toks[i * pl:(i + 1) * pl]))
            if child is None:
                return PrefixMatch(pages, len(pages) * pl, None, 0)
            child.tick = tick
            pages.append(child.page)
            node = child
        tail = tuple(toks[n_full * pl:])
        tail_page = node.tails.get(tail) if tail else None
        if tail_page is not None:
            node.tail_ticks[tail] = tick
        return PrefixMatch(pages, len(pages) * pl, tail_page,
                           len(tail) if tail_page is not None else 0)

    # -- registration ----------------------------------------------------

    def insert(self, tokens, pages: List[int], pool) -> int:
        """Register a freshly-prefilled prompt: missing full-chunk nodes
        adopt the lane's pages (logical order), and a non-empty remainder
        becomes a tail entry. Existing nodes keep their canonical page —
        the lane's duplicate stays private. Returns #pages registered."""
        toks = [int(t) for t in tokens]
        pl = self.page_len
        n_full = len(toks) // pl
        assert len(pages) >= -(-len(toks) // pl), (len(pages), len(toks))
        node, registered = self.root, 0
        tick = next(self._clock)
        for i in range(n_full):
            chunk = tuple(toks[i * pl:(i + 1) * pl])
            child = node.children.get(chunk)
            if child is None:
                child = _Node(chunk, pages[i], node)
                node.children[chunk] = child
                pool.register([pages[i]])
                self.n_nodes += 1
                registered += 1
            child.tick = tick
            node = child
        tail = tuple(toks[n_full * pl:])
        if tail and tail not in node.tails:
            node.tails[tail] = pages[n_full]
            node.tail_ticks[tail] = tick
            pool.register([pages[n_full]])
            self.n_tails += 1
            registered += 1
        return registered

    # -- eviction --------------------------------------------------------

    def _evictable(self, pool) -> List[Tuple[int, str, _Node, TokenChunk]]:
        """(tick, kind, node, key) for every LRU-eligible entry: tail
        entries whose page is cached, and childless+tailless nodes whose
        page is cached."""
        out = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            for tail, page in node.tails.items():
                if pool.is_cached(page):
                    out.append((node.tail_ticks[tail], "tail", node, tail))
            if (node is not self.root and not node.children
                    and not node.tails and pool.is_cached(node.page)):
                out.append((node.tick, "node", node, node.chunk))
        return out

    def evict_lru(self, pool) -> int:
        """Evict the least-recently-used evictable entry (tail first at
        tick ties — it frees the same page count without orphaning a
        subtree). Returns pages freed (0 or 1; 0 ⇒ nothing evictable)."""
        cands = self._evictable(pool)
        if not cands:
            return 0
        cands.sort(key=lambda c: (c[0], c[1] != "tail"))
        _, kind, node, key = cands[0]
        if kind == "tail":
            page = node.tails.pop(key)
            node.tail_ticks.pop(key)
            self.n_tails -= 1
        else:
            page = node.page
            node.parent.children.pop(key)
            self.n_nodes -= 1
        pool.unregister([page])
        return 1

    def evict_until(self, pool, n_free: int) -> int:
        """Evict LRU entries until ``pool.num_free_pages >= n_free`` or
        nothing evictable remains. Returns pages freed."""
        freed = 0
        while pool.num_free_pages < n_free:
            got = self.evict_lru(pool)
            if not got:
                break
            freed += got
        return freed

    def clear(self, pool) -> None:
        """Unregister every entry (engine shutdown / head swap flush)."""
        stack = list(self.root.children.values())
        pages = list(self.root.tails.values())
        while stack:
            node = stack.pop()
            pages.append(node.page)
            pages.extend(node.tails.values())
            stack.extend(node.children.values())
        pool.unregister(pages)
        self.root = _Node(None, None, None)
        self.n_nodes = 0
        self.n_tails = 0
