"""Continuous-batching serving engine over the jit-able step functions.

One engine iteration (``Engine.step``) is: admit → decode → select → retire.

  admit   — pop queued requests into free decode lanes + freshly-allocated
            KV pages (``PagedPool.alloc``) and prefill ALL newly-admitted
            prompts in one padded jitted call (``make_batched_prefill``,
            row- and length-bucketed to powers of two so recompiles stay
            bounded); new requests join mid-flight, no draining of the
            running batch. Admission order is per-priority-class
            (higher class first, FIFO within a class, a blocked class
            blocks everything below it); with ``prefix_sharing`` the
            longest full-chunk trie match maps already-resident prompt
            pages into the lane (refcounted, COW on a partial tail) and
            prefill skips the matched tokens; with
            ``page_growth="ondemand"`` admission reserves only the
            prompt + 1 pages and lanes grow (or spill/restore under
            pressure — ``preemption``) as generation proceeds.
  decode  — ONE jitted ``make_paged_decode`` call for the whole pool:
            (B, 1) in-flight tokens, (B,) per-lane ``cache_pos``, and the
            (B, max_pages) page table mapping each lane's logical pages
            onto the shared arena. Free lanes ride along as masked garbage
            (their compute is the price of a static batch shape; their
            writes land in the sink page by construction).
  select  — next-token choice from the final hiddens. Dense path: full
            Eq. 5 debiased scores + argmax (O(C)). Beam path: the prefix-
            keyed ``CandidateCache`` is consulted per slot; on an all-hit
            step the O(beam·k·log C) tree descent is skipped entirely and
            the cached candidate sets go straight to re-scoring
            (O(beam·K) gather-and-dot, optionally the gather_scores Pallas
            kernel or mesh-sharded ``sharded_candidate_scores``).
  retire  — per-lane EOS / max-new-tokens / max-len checks; finished
            requests release their lane AND their pages the same step
            (page reclamation), making room for the next admission.

With ``spec_decode`` the decode+select pair becomes a *verify* launch:
the replay-draft store proposes up to ``max_draft`` tokens per lane,
one batched ``paged_prefill`` scores the whole chain, and the longest
prefix matching the target's own Eq.-5 argmax is emitted — exact
accept/reject, so speculation changes latency, never output
(DESIGN.md §12).

Request lifecycle: QUEUED → RUNNING(lane, pages) → FINISHED. The caller
drives the loop (``step()`` / ``run()``) and reads results incrementally
through the streaming ``ResultStream`` handle returned by ``submit``.

Determinism: greedy decode has no RNG, admission order is a pure
function of the submitted sequence, and the per-slot math is
row-independent, so a request's output depends only on its prompt and
the params — byte-identical to the lock-step ``make_serve_step`` path
with sharing, speculation, and preemption in any combination
(property-tested in tests/test_serve_engine.py). The candidate cache can
only skip work, never change results: a prefix hit implies a bit-identical
hidden state, hence identical re-scored argmax.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heads as heads_lib
from repro.core import tree as tree_lib
from repro.core.heads import HeadConfig, HeadParams
from repro.models import lm_head
from repro.models.config import ModelConfig
from repro.models import transformer
from repro.obs import JsonlExporter, Registry
from repro.obs.trace import span
from repro.resilience import faults
from repro.serve.cache_pool import PagedPool
from repro.serve.candidate_cache import CandidateCache
from repro.serve.prefix_index import PrefixIndex
from repro.serve.spec import NullDraft, ReplayDraft
from repro.train.step import (make_batched_prefill, make_paged_decode,
                              make_paged_prefill, make_prefill,
                              make_serve_step)


_LOCKSTEP_FNS: Dict[Any, Any] = {}


def lockstep_decode(cfg: ModelConfig, hcfg: HeadConfig, params, head_state,
                    prompts, gen_tokens: int, topk_beam: int = 0,
                    mesh=None, cache_dtype=jnp.float32) -> np.ndarray:
    """Reference fixed-batch greedy decode — the pre-engine serving loop.

    THE byte-identity oracle for the engine: tests, benchmarks, and
    examples compare ``Engine`` outputs against this exact loop, so it is
    defined once here. Returns the (batch, gen_tokens) generated ids.
    The jitted prefill/step pair is memoized per static configuration so
    repeated calls (hypothesis examples, bench chunks) reuse the compile.
    """
    prompts = jnp.asarray(prompts)
    b, pl = prompts.shape
    # Mesh objects are hashable and key by value — never id(), which can
    # alias a dead mesh after GC. Cap the memo: it exists to dedupe
    # repeated oracle calls (hypothesis examples, bench chunks), not to
    # pin every configuration's executables forever.
    key = (cfg, hcfg, topk_beam, mesh, jnp.dtype(cache_dtype).name)
    if key not in _LOCKSTEP_FNS:
        if len(_LOCKSTEP_FNS) >= 16:
            _LOCKSTEP_FNS.clear()
        _LOCKSTEP_FNS[key] = (
            jax.jit(make_prefill(cfg)),
            jax.jit(make_serve_step(cfg, hcfg, topk_beam=topk_beam,
                                    mesh=mesh)))
    prefill, step = _LOCKSTEP_FNS[key]
    cache = transformer.init_cache(cfg, b, pl + gen_tokens,
                                   dtype=cache_dtype)
    _, cache = prefill(params, prompts, cache)
    token, toks = prompts[:, -1:], []
    for t in range(gen_tokens):
        token, cache = step(params, head_state, token, cache,
                            jnp.int32(pl + t))
        toks.append(np.asarray(token))
    return np.concatenate(toks, 1)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (static: they shape the compiled step functions)."""
    n_slots: int = 8             # concurrent decode lanes
    max_len: int = 256           # per-request KV capacity (prompt + new)
    page_len: int = 0            # KV page size; 0 = max_len (one page per
    #                              request: the monolithic-equivalent
    #                              geometry, full per-request reservation)
    n_pages: int = 0             # arena capacity; 0 = n_slots * pages-per-
    #                              max_len-request (byte-equivalent to the
    #                              old one-buffer-per-slot pool). Undersize
    #                              it (mixed-length traffic) to hold more
    #                              lanes in the same device bytes.
    batched_prefill: bool = True  # one padded prefill per admission round;
    #                               False = one call per request (same
    #                               bytes out — oracle-tested)
    beam: int = 0                # 0 = dense O(C) scoring; >0 = tree beam
    use_kernel: bool = False     # gather_scores Pallas kernel for scoring
    mesh: Any = None             # route scoring via sharded_candidate_scores
    use_candidate_cache: bool = True   # prefix-keyed descent skipping
    candidate_cache_capacity: int = 4096
    eos_id: Optional[int] = None       # engine-wide default stop token
    cache_dtype: Any = jnp.float32
    retain_completed: int = 4096       # finished handles kept for audit;
    #                                    older ones drop (callers hold
    #                                    their own ResultStream refs)
    # -- multi-tenant knobs (PR 9, DESIGN.md §12). All default OFF /
    #    legacy so the engine is drop-in identical unless opted in. --
    prefix_sharing: bool = False  # radix-trie shared prompt pages + COW
    spec_decode: bool = False     # tree-draft speculative decode
    max_draft: int = 4            # draft chain cap per verify step
    draft_capacity: int = 8192    # continuation-store LRU entries
    preemption: bool = False      # spill lower-priority lanes under
    #                               pressure (restore is byte-exact)
    page_growth: str = "reserve"  # "reserve" = worst-case pages at
    #                               admission; "ondemand" = admit on
    #                               prompt-size pages, grow at page
    #                               boundaries (evict/preempt/spill-self
    #                               when the free list runs dry)
    # -- resilience knobs (DESIGN.md §13). Both default OFF/legacy. --
    max_queue: int = 0            # bounded admission queue: submit()
    #                               beyond this depth returns a handle
    #                               with status="shed" instead of
    #                               enqueueing (0 = unbounded legacy)
    enforce_deadlines: bool = False  # abort requests past deadline_s —
    #                               queued ones are rejected, running
    #                               ones reclaim their lane + pages
    #                               mid-decode (legacy: advisory only)


@dataclasses.dataclass
class Request:
    """One generation request. ``eos_id=None`` inherits the engine default;
    ``max_new_tokens`` is the per-sequence length budget. ``priority`` is
    the SLA class (higher = more urgent; interactive traffic above batch):
    admission scans classes high→low, FIFO within a class, and with
    ``preemption`` a blocked higher class may spill strictly-lower lanes.
    ``deadline_s`` is an advisory per-request latency target recorded in
    the per-class stats (the scheduler does not drop late requests)."""
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    priority: int = 0
    deadline_s: Optional[float] = None


class ResultStream:
    """Streaming handle: ``tokens`` grows as the engine decodes; ``done``
    flips on retirement. Timestamps are perf_counter seconds.

    ``status`` reports how the request ended (DESIGN.md §13):
    ``"ok"`` — completed normally; ``"shed"`` — rejected at submit by the
    bounded admission queue; ``"deadline"`` — aborted past its
    ``deadline_s`` (under ``enforce_deadlines``); ``"error"`` — its
    prefill raised and the request was failed in isolation. Every
    non-"ok" terminal sets ``done`` with whatever tokens were produced.
    """

    def __init__(self, request: Request, request_id: int, now: float):
        self.request = request
        self.request_id = request_id
        self.tokens: List[int] = []
        self.done = False
        self.status = "ok"
        self.submitted_at = now
        self.admitted_at: Optional[float] = None
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        # -- scheduler state (engine-internal) --
        self.slot: Optional[int] = None
        self.cache_pos = 0
        self.next_input = 0
        self.history: List[int] = []
        self._eos: Optional[int] = None
        self.priority = request.priority
        self.admitted_seq = -1        # admission order, preemption tiebreak
        self.preempted = 0            # times spilled back to the queue
        self._spill = None            # PageSpill while waiting to restore
        self._suffix_start = 0        # prompt tokens covered by shared KV

    @property
    def eos_hit(self) -> bool:
        return bool(self.tokens) and self.tokens[-1] == self._eos

    def result(self) -> np.ndarray:
        assert self.done, "request still in flight"
        return np.asarray(self.tokens, np.int32)

    @property
    def latency(self) -> float:
        assert self.done
        return self.finished_at - self.submitted_at


class Engine:
    """Continuous-batching decode engine. See module docstring."""

    def __init__(self, cfg: ModelConfig, hcfg: HeadConfig, params,
                 head_state, serve_cfg: ServeConfig,
                 registry: Optional[Registry] = None,
                 exporter: Optional[JsonlExporter] = None,
                 metrics_interval: int = 1):
        self.cfg = cfg
        self.hcfg = hcfg
        self.params = params
        self.head_state = head_state
        self.scfg = serve_cfg
        # Observability (repro.obs, DESIGN.md §10). The engine always
        # carries an enabled registry — its instruments back the
        # ``stats()`` latency view, and host-side bookkeeping is noise
        # next to a decode launch. Pass a shared registry to aggregate
        # across engines, or an ``exporter`` to stream ``request`` /
        # ``serve_step`` JSONL events (sampled every
        # ``metrics_interval`` engine iterations).
        self.registry = registry if registry is not None else Registry()
        self.exporter = exporter
        self.metrics_interval = max(metrics_interval, 1)
        reg = self.registry
        self._h_admission = reg.histogram("serve/admission_wait_s")
        self._h_ttft = reg.histogram("serve/ttft_s")
        self._h_latency = reg.histogram("serve/latency_s")
        self._c_tokens = reg.counter("serve/tokens")
        self._g_queue = reg.gauge("serve/queue_depth")
        self._g_active = reg.gauge("serve/active")
        self._g_pages = reg.gauge("serve/page_occupancy")
        page_len = serve_cfg.page_len or serve_cfg.max_len
        max_pages = -(-serve_cfg.max_len // page_len)
        n_pages = serve_cfg.n_pages or serve_cfg.n_slots * max_pages
        if cfg.block == "ssm":
            # Pure-SSM: there is no K/V arena — pages would back zero
            # device bytes, so they must never gate admission. Pin one
            # nominal page per lane; lanes alone bound concurrency.
            page_len, n_pages = serve_cfg.max_len, serve_cfg.n_slots
        self.pool = PagedPool(cfg, serve_cfg.n_slots, n_pages, page_len,
                              serve_cfg.max_len,
                              dtype=serve_cfg.cache_dtype)
        if serve_cfg.mesh is not None:
            # Mesh serving: shard the page arena per the decode policy
            # (page_len over 'model') so each device holds 1/TP of the
            # cache instead of a full replica next to sharded params.
            # Shapes the mesh cannot divide (jax 0.4 requires exact
            # divisibility) stay on default placement — GSPMD reshards
            # inside the step.
            from repro.parallel.sharding import paged_cache_shardings
            try:
                self.pool.cache = jax.device_put(
                    self.pool.cache,
                    paged_cache_shardings(
                        cfg, serve_cfg.mesh,
                        jax.eval_shape(lambda: self.pool.cache),
                        serve_cfg.n_slots))
            except ValueError:
                pass
        beam = serve_cfg.beam
        if beam:
            assert hcfg.kind == "adversarial_ns" and \
                head_state.gen.tree is not None, \
                "beam serving needs a fitted adversarial generator tree"
            beam = min(beam, tree_lib.padded_size(hcfg.num_labels))
        self.beam = beam
        self.candidate_cache = (
            CandidateCache(serve_cfg.candidate_cache_capacity)
            if beam and serve_cfg.use_candidate_cache else None)

        # -- multi-tenant machinery (DESIGN.md §12) --
        assert serve_cfg.page_growth in ("reserve", "ondemand"), \
            serve_cfg.page_growth
        if serve_cfg.prefix_sharing or serve_cfg.spec_decode:
            assert cfg.block == "attn", (
                "prefix sharing / speculative decode need position-local "
                "KV; SSM and hybrid caches carry recurrent state")
        self.prefix_index = (PrefixIndex(self.pool.page_len)
                             if serve_cfg.prefix_sharing else None)
        self.draft = (ReplayDraft(serve_cfg.draft_capacity)
                      if serve_cfg.spec_decode else NullDraft())
        # prefix-sharing counters
        self.share_lookups = 0
        self.share_hits = 0           # admissions reusing >= 1 page
        self.shared_pages_reused = 0  # pages NOT allocated thanks to trie
        self.prefill_tokens_saved = 0
        self.cow_copies = 0
        self.trie_evictions = 0
        # speculative-decode counters
        self.verify_steps = 0
        self.drafts_proposed = 0
        self.drafts_accepted = 0
        # scheduler counters
        self.preemptions = 0
        self.restores = 0
        self.page_grows = 0
        self.deadline_misses = 0
        self._class_hists: Dict[int, Any] = {}
        # resilience counters (DESIGN.md §13)
        self.shed_count = 0          # submits rejected by the queue bound
        self.deadline_aborts = 0     # requests aborted past deadline_s
        self.poisoned_count = 0      # requests failed in isolation
        self._compiled = False       # first launch done (readiness gate)

        # Per-priority FIFO queues (higher class admits first; a blocked
        # class blocks everything below it — no sneaking past a starved
        # interactive request). Single-class traffic degenerates to the
        # old global FIFO exactly.
        self._queues: Dict[int, "deque[ResultStream]"] = {}
        self._active: Dict[int, ResultStream] = {}     # slot -> handle
        self._next_id = 0
        self._admit_seq = 0
        # Bounded audit trails — a long-running engine must not grow host
        # memory per request served; counters carry the lifetime totals.
        keep = serve_cfg.retain_completed
        self.admission_order: "deque[int]" = deque(maxlen=keep)
        self.completed: "deque[ResultStream]" = deque(maxlen=keep)
        self.completed_count = 0
        self.decode_steps = 0
        self.descent_skips = 0      # all-hit steps that skipped beam_search
        self.prefill_calls = 0      # padded batched-prefill launches
        self._occupancy_sum = 0
        self._page_occupancy_sum = 0
        self.peak_active = 0
        self.peak_pages_in_use = 0

        # Jitted step functions. The arena argument is donated so the
        # pool's device buffers are reused in place step over step.
        self._prefill = jax.jit(
            make_batched_prefill(cfg, self.pool.page_len, self.pool.sink,
                                 cache_dtype=serve_cfg.cache_dtype),
            donate_argnums=(4,))
        self._decode = jax.jit(make_paged_decode(cfg), donate_argnums=(2,))
        # Multi-token paged forward: shared-prefix suffix prefill AND the
        # speculative verify step share this one jitted function.
        self._paged_prefill = (
            jax.jit(make_paged_prefill(cfg), donate_argnums=(4,))
            if (serve_cfg.prefix_sharing or serve_cfg.spec_decode)
            else None)
        self._select_dense = jax.jit(self._build_dense_select())
        if beam:
            self._propose = jax.jit(self._build_propose())
            self._score = jax.jit(self._build_score())

    # -- jitted head-path builders --------------------------------------

    def _build_dense_select(self):
        cfg, hcfg = self.cfg, self.hcfg

        def dense_select(params, head_state, h):
            scores = lm_head.lm_predictive_scores(
                cfg, hcfg, HeadParams(**params["head"]), head_state, h)
            return jnp.argmax(scores, axis=-1).astype(jnp.int32)

        return dense_select

    def _build_propose(self):
        beam = self.beam

        def propose(head_state, h):
            x_gen = lm_head.gen_features(head_state, h)
            return tree_lib.beam_search(head_state.gen.tree, x_gen, beam,
                                        beam)

        return propose

    def _build_score(self):
        hcfg = self.hcfg
        score_fn = lm_head.serving_score_fn(
            self.cfg, use_kernel=self.scfg.use_kernel, mesh=self.scfg.mesh)

        def score(params, h, cand, log_pn):
            # heads.rescore_candidates is the same tail predictive_topk
            # runs, so engine outputs match the lock-step beam path
            # bit-for-bit.
            _, labels = heads_lib.rescore_candidates(
                hcfg, HeadParams(**params["head"]), h, cand, log_pn, 1,
                score_fn=score_fn)
            return labels[..., 0].astype(jnp.int32)

        return score

    # -- public API ------------------------------------------------------

    def submit(self, request: Request) -> ResultStream:
        prompt = np.asarray(request.prompt, np.int32)
        assert prompt.ndim == 1 and prompt.size >= 1, "prompt must be (S,)"
        if request.max_new_tokens < 1:
            # The engine always runs at least one decode step; a zero
            # budget would write at cache_pos == prompt_len + max_new,
            # one position past the request's page reservation.
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + request.max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds slot capacity "
                f"({self.scfg.max_len})")
        request = dataclasses.replace(request, prompt=prompt)
        handle = ResultStream(request, self._next_id, time.perf_counter())
        handle._eos = (request.eos_id if request.eos_id is not None
                       else self.scfg.eos_id)
        self._next_id += 1
        if (self.scfg.max_queue
                and self.num_pending >= self.scfg.max_queue):
            # Bounded admission: shed with an explicit status instead of
            # growing the queue without limit. The caller gets a DONE
            # handle it can retry against a less-loaded replica; latency
            # percentiles stay meaningful because the queue cannot hide
            # unbounded wait behind them.
            handle.status = "shed"
            handle.done = True
            handle.finished_at = handle.submitted_at
            self.shed_count += 1
            self.registry.counter("serve/shed").inc()
            return handle
        self._queues.setdefault(handle.priority, deque()).append(handle)
        self._g_queue.set(self.num_pending)
        return handle

    def swap_head_state(self, head_state) -> None:
        """Install a refreshed generator head state (online swap).

        The jitted select/propose/score functions take ``head_state`` as a
        traced argument, so the swap costs no recompiles. The candidate
        cache, however, holds (candidates, log_pn) pairs proposed by the
        OLD tree — under the new generator those candidate sets and Eq. 5
        debias terms are simply wrong, so every resident entry is
        invalidated (version bump): the next step on any prefix re-descends
        the new tree. Requests already in flight continue seamlessly
        against the new head (greedy decode keeps no head-side state
        between steps).
        """
        if self.beam:
            assert (self.hcfg.kind == "adversarial_ns"
                    and head_state.gen.tree is not None), \
                "beam serving needs a fitted adversarial generator tree"
        self.head_state = head_state
        if self.candidate_cache is not None:
            self.candidate_cache.bump_version()
        # Replayed continuations were decoded by the OLD tree — a new
        # draft from them would still be *verified* exactly (speculation
        # never affects outputs), but it would stop matching, so flush.
        self.draft.bump_version()

    @property
    def num_pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def num_active(self) -> int:
        return len(self._active)

    def step(self) -> bool:
        """One admit → decode → select → retire iteration. Returns False
        when there was nothing to do (idle engine)."""
        # Site "serve/step": a delay here models a straggling iteration
        # (deadline pressure); a raise reaches the driver before any
        # state mutates, so the engine stays consistent.
        faults.fire("serve/step")
        if self.scfg.enforce_deadlines:
            self._abort_expired()
        self._admit()
        if not self._active:
            # Not necessarily idle: if every request admitted this round
            # was terminated (poisoned prefill), the queue may still hold
            # work — report it so run() keeps driving. With no active
            # lanes all resources are free, so the next _admit always
            # makes progress.
            return self.num_pending > 0
        if self.scfg.spec_decode:
            self._spec_decode_and_retire()
        else:
            self._ensure_capacity({})
            if self._active:
                self._decode_and_retire()
        return True

    def run(self) -> None:
        """Drive until queue and in-flight batch are empty."""
        while self.step():
            pass

    def stream(self, handle: ResultStream):
        """Yield ``handle``'s tokens as they are produced, stepping the
        engine as needed (single-consumer streaming API)."""
        sent = 0
        while True:
            while sent < len(handle.tokens):
                yield handle.tokens[sent]
                sent += 1
            if handle.done:
                return
            if not self.step():
                raise RuntimeError("engine idle but request not finished")

    def warm_prefill_buckets(self, prompt_lens) -> int:
        """Compile every (rows, padded-length) batched-prefill shape that
        admission can hit for prompts drawn from ``prompt_lens`` — the
        same bucketing ``_flush_prefill`` applies, kept here so benchmark
        warmups cannot drift from it. The probe rows are zero-length:
        their scatters route to the sink page / dropped lanes, so nothing
        real lands in the arena. Returns the number of shapes compiled.
        """
        pool = self.pool
        shapes = sorted({self._prefill_shape(k, int(pl))
                         for k in range(1, self.scfg.n_slots + 1)
                         for pl in prompt_lens})
        for r, s in shapes:
            _, new_cache = self._prefill(
                self.params, np.zeros((r, s), np.int32),
                np.zeros((r,), np.int32),
                np.full((r,), pool.n_lanes, np.int32), pool.cache,
                np.full((r, pool.max_pages), pool.sink, np.int32))
            pool.swap_cache(new_cache)
        return len(shapes)

    def health(self) -> dict:
        """Cheap liveness/readiness snapshot (the /healthz payload and
        ``stats()["health"]``). ``ready`` is the /readyz gate: the model
        has compiled (first prefill/decode launch done — before that a
        request would stall seconds on XLA) and the queue is below the
        shed threshold (an engine that would shed the next submit is not
        ready for more traffic)."""
        qd = self.num_pending
        return {
            "compiled": self._compiled,
            "queue_depth": qd,
            "active": len(self._active),
            "pages_free": self.pool.num_free_pages,
            "lanes_free": self.pool.num_free_lanes,
            "shed": self.shed_count,
            "poisoned": self.poisoned_count,
            "deadline_aborts": self.deadline_aborts,
            "deadline_misses": self.deadline_misses,
            "ready": bool(self._compiled
                          and (not self.scfg.max_queue
                               or qd < self.scfg.max_queue)),
        }

    def stats(self) -> dict:
        """Engine snapshot: the pre-obs keys (unchanged, for compat) plus
        the registry view — ``latency`` carries per-request histograms
        (admission-wait, TTFT, total; count/mean/p50/p95/p99 derived from
        the same perf_counter timestamps the handles expose raw) and
        ``metrics`` is the full ``repro.obs`` snapshot, including the
        ``serve/phase/*`` span timings."""
        pool = self.pool
        # Internal fragmentation: the tail of each active request's last
        # page holds positions it has not reached (and with upfront
        # reservation, whole unreached pages). 0 = every mapped byte
        # corresponds to a written position.
        mapped_pos = pool.num_mapped_pages * pool.page_len
        used_pos = sum(st.cache_pos for st in self._active.values())
        # Admission-time reservation accounting: pages a lane maps but has
        # not written into yet (whole pages past ceil(cache_pos/page_len)).
        # Under worst-case reservation this is the fragmentation the
        # "ondemand" growth policy exists to reclaim; reporting it apart
        # from pages_in_use keeps the occupancy gauges meaningful.
        reserved_unwritten = sum(
            max(0, len(pool.lane_pages(slot))
                - -(-st.cache_pos // pool.page_len))
            for slot, st in self._active.items())
        out = {
            "completed": self.completed_count,
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "descent_skips": self.descent_skips,
            # The honest amortization metric: the fraction of decode steps
            # whose tree descent was actually skipped (a partial-hit step
            # still descends, even though its lookups count as cache hits).
            "descent_skip_rate": (self.descent_skips / self.decode_steps
                                  if self.decode_steps else 0.0),
            "mean_occupancy": (self._occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0),
            "n_slots": self.scfg.n_slots,
            "peak_active": self.peak_active,
            # -- paged-pool memory accounting --
            "n_pages": pool.n_pages,
            "page_len": pool.page_len,
            "pages_in_use": pool.num_mapped_pages,
            "pages_reserved_unwritten": reserved_unwritten,
            "pages_cached": pool.num_cached_pages,
            "pages_free": pool.num_free_pages,
            "peak_pages_in_use": self.peak_pages_in_use,
            "page_occupancy": pool.num_mapped_pages / pool.n_pages,
            "mean_page_occupancy": (
                self._page_occupancy_sum / (self.decode_steps
                                            * pool.n_pages)
                if self.decode_steps else 0.0),
            "internal_fragmentation": (1.0 - used_pos / mapped_pos
                                       if mapped_pos else 0.0),
        }
        out["latency"] = {
            "admission_wait": self._h_admission.snapshot(),
            "ttft": self._h_ttft.snapshot(),
            "total": self._h_latency.snapshot(),
        }
        out["tokens"] = self._c_tokens.value
        if self.candidate_cache is not None:
            cc = self.candidate_cache.stats()
            out["candidate_cache"] = cc
            lookups = cc["hits"] + cc["misses"]
            self.registry.gauge("serve/candidate_cache_hit_rate").set(
                cc["hits"] / lookups if lookups else 0.0)
        if self.prefix_index is not None:
            hit_rate = (self.share_hits / self.share_lookups
                        if self.share_lookups else 0.0)
            out["prefix"] = {
                "lookups": self.share_lookups,
                "hits": self.share_hits,
                "hit_rate": hit_rate,
                "pages_reused": self.shared_pages_reused,
                "prefill_tokens_saved": self.prefill_tokens_saved,
                "cow_copies": self.cow_copies,
                "evictions": self.trie_evictions,
                "trie_nodes": self.prefix_index.n_nodes,
                "trie_tails": self.prefix_index.n_tails,
            }
            self.registry.gauge("serve/prefix_hit_rate").set(hit_rate)
            self.registry.gauge("serve/pages_cached").set(
                pool.num_cached_pages)
        if self.scfg.spec_decode:
            mean_acc = (self.drafts_accepted / self.verify_steps
                        if self.verify_steps else 0.0)
            out["spec"] = {
                "verify_steps": self.verify_steps,
                "drafts_proposed": self.drafts_proposed,
                "drafts_accepted": self.drafts_accepted,
                "mean_accepted": mean_acc,
                # tokens emitted per launch = accepted + the bonus token
                "mean_emitted_per_step": 1.0 + mean_acc,
            }
            store = getattr(self.draft, "store", None)
            if store is not None:
                out["spec"]["draft_store"] = {
                    "hits": store.hits, "misses": store.misses,
                    "entries": len(store._map)}
            self.registry.gauge("serve/spec_mean_accepted").set(mean_acc)
        out["health"] = self.health()
        out["sched"] = {
            "preemptions": self.preemptions,
            "restores": self.restores,
            "page_grows": self.page_grows,
            "deadline_misses": self.deadline_misses,
            "page_growth": self.scfg.page_growth,
            "per_class_latency": {pri: hist.snapshot()
                                  for pri, hist in
                                  sorted(self._class_hists.items())},
        }
        self.registry.gauge("serve/preemptions").set(self.preemptions)
        # Scheduler counters stay plain attributes (benchmarks reset the
        # peaks between warmup and the measured trace); the registry view
        # mirrors them at snapshot time.
        for name, v in (("serve/decode_steps", self.decode_steps),
                        ("serve/prefill_calls", self.prefill_calls),
                        ("serve/descent_skips", self.descent_skips),
                        ("serve/completed", self.completed_count),
                        ("serve/peak_active", self.peak_active)):
            self.registry.gauge(name).set(v)
        out["metrics"] = self.registry.snapshot()
        return out

    # -- scheduler internals --------------------------------------------

    def _terminate(self, h: ResultStream, status: str, now: float) -> None:
        """Terminal non-"ok" path shared by deadline aborts and poison
        isolation: reclaim the lane + pages if held, mark the handle
        done, keep the audit counters honest. Refcounted shared pages
        drop through ``pool.release`` exactly as a normal retirement
        would, so an abort can never strand a page (the chaos suite's
        no-leak invariant)."""
        if h.slot is not None:
            self._active.pop(h.slot, None)
            self.pool.release(h.slot)
            h.slot = None
        h._spill = None
        h.status = status
        h.done = True
        h.finished_at = now
        if status == "deadline":
            self.deadline_aborts += 1
            self.deadline_misses += 1
            self.registry.counter("serve/deadline_aborts").inc()
        elif status == "error":
            self.poisoned_count += 1
            self.registry.counter("serve/poisoned").inc()
        self.completed.append(h)
        if self.exporter is not None:
            self.exporter.emit({
                "event": "request", "request_id": h.request_id,
                "tokens": len(h.tokens), "priority": h.priority,
                "status": status,
                "admission_wait_s": (h.admitted_at - h.submitted_at
                                     if h.admitted_at is not None
                                     else None),
                "ttft_s": (h.first_token_at - h.submitted_at
                           if h.first_token_at is not None else None),
                "latency_s": h.finished_at - h.submitted_at})

    def _abort_expired(self) -> None:
        """Wall-clock deadline enforcement (``enforce_deadlines``):
        queued requests past ``deadline_s`` are rejected before wasting
        a prefill; running lanes past theirs are aborted mid-decode,
        reclaiming lane + pages for requests that can still make their
        SLA. Requests without a deadline are untouched."""
        now = time.perf_counter()

        def expired(h: ResultStream) -> bool:
            return (h.request.deadline_s is not None
                    and now - h.submitted_at > h.request.deadline_s)

        for pri in list(self._queues):
            q = self._queues[pri]
            kept: "deque[ResultStream]" = deque()
            while q:
                h = q.popleft()
                if expired(h):
                    self._terminate(h, "deadline", now)
                else:
                    kept.append(h)
            if kept:
                self._queues[pri] = kept
            else:
                del self._queues[pri]
        for slot in list(self._active):
            st = self._active[slot]
            if expired(st):
                self._terminate(st, "deadline", now)

    def _admit(self) -> None:
        """Class-ordered admission: scan SLA classes high→low, FIFO within
        a class, and a blocked class blocks everything below it (no
        sneaking past a starved interactive request). Head-of-line order
        *within* a class is preserved unconditionally (a request is never
        skipped in favour of a later one, even when a later, smaller
        request would fit the remaining pages) — the fairness property the
        tests pin down; single-class traffic reproduces the old global
        FIFO exactly.

        Per request, resources come in escalating order: free pages →
        eviction of cached prefix pages (LRU leaf-first) → preemption of
        strictly-lower-class lanes (spill-and-restore). Prompts (or, with
        sharing, their unmatched suffixes) are prefilled in one padded
        batched call (or one call per request with
        ``batched_prefill=False`` — same bytes out, oracle-tested).
        """
        batch: List[ResultStream] = []        # legacy full-prompt prefill
        suffix_jobs: List[ResultStream] = []  # sharing-path prefill
        admitted: List[ResultStream] = []
        for pri in sorted(self._queues, reverse=True):
            q = self._queues[pri]
            while q:
                if not self._try_admit(q[0], batch, suffix_jobs, admitted):
                    break
                q.popleft()
            if q:
                break
        for pri in [p for p, q in self._queues.items() if not q]:
            del self._queues[pri]
        if batch:
            self._prefill_batch(batch)
        if suffix_jobs:
            self._flush_suffix_prefill(suffix_jobs)
        self._finish_admission(admitted)
        self.peak_active = max(self.peak_active, len(self._active))
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pool.num_mapped_pages)
        self._g_queue.set(self.num_pending)
        self._g_active.set(len(self._active))
        self._g_pages.set(self.pool.num_mapped_pages / self.pool.n_pages)

    def _try_admit(self, h: ResultStream, batch: List[ResultStream],
                   suffix_jobs: List[ResultStream],
                   admitted: List[ResultStream]) -> bool:
        """Admit one head-of-class request if its resources can be found
        (free → evict cached → preempt lower classes), else False."""
        pool, scfg = self.pool, self.scfg
        match = None
        counted_lookup = False
        while True:
            if h._spill is not None:
                need = h._spill.n_pages       # exact resume footprint
                free_needed = need
            else:
                prompt = h.request.prompt
                # "reserve": worst-case pages up front (a request admitted
                # is a request that finishes). "ondemand": admit on pages
                # for the prompt + first decode write; grow at boundaries.
                horizon = (prompt.size + h.request.max_new_tokens
                           if scfg.page_growth == "reserve"
                           else prompt.size + 1)
                need = pool.pages_needed(horizon)
                if self.prefix_index is not None:
                    match = self.prefix_index.match(prompt)
                    if not counted_lookup:
                        self.share_lookups += 1
                        counted_lookup = True
                # A matched COW tail still consumes one free page (the
                # private copy) — only its *prefill* is saved, not the
                # byte; matched full pages are pure savings.
                free_needed = need - (len(match.pages) if match else 0)
            if pool.num_free_lanes >= 1 and \
                    pool.num_free_pages >= free_needed:
                break
            if (self.prefix_index is not None
                    and self.prefix_index.evict_lru(pool)):
                # Re-match after every eviction: the LRU choice may have
                # pruned part of our own matched path.
                self.trie_evictions += 1
                continue
            if scfg.preemption and self._preempt_one(h.priority):
                continue
            return False

        if h._spill is not None:
            lane, _pages = pool.restore(h._spill)
            h._spill = None
            h.slot = lane
            self.restores += 1
            admitted.append(h)
            return True

        prompt = h.request.prompt
        if match is not None and (match.pages
                                  or match.tail_page is not None):
            shared = list(match.pages)
            tail_idx = None
            if match.tail_page is not None:
                tail_idx = len(shared)
                shared.append(match.tail_page)
            lane, _priv = pool.alloc_shared(shared, need - len(shared))
            if tail_idx is not None:
                pool.cow(lane, tail_idx)
                self.cow_copies += 1
            covered = match.tokens_matched + match.tail_len
            self.share_hits += 1
            self.shared_pages_reused += len(shared)
            self.prefill_tokens_saved += covered
        else:
            lane, _pages = pool.alloc(need)
            covered = 0
        h.slot = lane
        h.cache_pos = int(prompt.size)
        h.next_input = int(prompt[-1])
        h.history = [int(t) for t in prompt]
        h._suffix_start = covered
        if self.prefix_index is not None:
            if covered < prompt.size:
                suffix_jobs.append(h)
                if not scfg.batched_prefill:
                    self._flush_suffix_prefill(suffix_jobs)
                    suffix_jobs.clear()
        else:
            batch.append(h)
            if not scfg.batched_prefill:
                self._prefill_batch(batch)
                batch.clear()
        admitted.append(h)
        return True

    def _finish_admission(self, admitted: List[ResultStream]) -> None:
        """Post-flush bookkeeping, in admission order. Runs after the
        prefill launches so trie registration only ever exposes pages
        whose KV bytes are already valid."""
        now = time.perf_counter()
        for h in admitted:
            if h.done:
                continue        # failed in isolation during its prefill
            if h.admitted_at is None:       # first admission only
                h.admitted_at = now
                self._h_admission.observe(now - h.submitted_at)
            h.admitted_seq = self._admit_seq
            self._admit_seq += 1
            self.admission_order.append(h.request_id)
            self._active[h.slot] = h
            if (self.prefix_index is not None and not h.tokens
                    and h.cache_pos == h.request.prompt.size):
                self.prefix_index.insert(
                    h.request.prompt, self.pool.lane_pages(h.slot),
                    self.pool)

    def _spill_to_queue(self, st: ResultStream) -> None:
        """Preempt a running lane: device→host byte image of its pages +
        lane rows, release everything, requeue at the FRONT of its class
        (it lost its turn through no fault of its own). Restore is
        byte-exact, so the request's output is unchanged."""
        st._spill = self.pool.spill(st.slot)
        self.pool.release(st.slot)
        del self._active[st.slot]
        st.slot = None
        st.preempted += 1
        self.preemptions += 1
        self._queues.setdefault(st.priority, deque()).appendleft(st)

    def _preempt_one(self, above: int) -> bool:
        """Spill the youngest-admitted lane of strictly lower class than
        ``above``. Youngest first: it has the least sunk prefill/decode
        work and the shortest spill image on average."""
        victims = [st for st in self._active.values()
                   if st.priority < above]
        if not victims:
            return False
        self._spill_to_queue(max(victims, key=lambda s: s.admitted_seq))
        return True

    def _ensure_capacity(self, extra: Dict[int, int]) -> None:
        """On-demand page growth: before a decode/verify launch, every
        active lane must map pages covering its write positions this step
        (``cache_pos .. cache_pos + extra[slot]``). Escalation mirrors
        admission (grow → evict cached → preempt lower → spill *self*);
        a lane spilled here simply sits out the step and resumes
        byte-exact later. No-op under the "reserve" policy."""
        if self.scfg.page_growth != "ondemand":
            return
        pool = self.pool
        for slot in list(self._active):
            st = self._active.get(slot)
            if st is None:
                continue                    # preempted by an earlier lane
            need = pool.pages_needed(st.cache_pos + extra.get(slot, 0) + 1)
            while len(pool.lane_pages(slot)) < need:
                if pool.grow(slot, need - len(pool.lane_pages(slot))):
                    self.page_grows += 1
                    break
                if (self.prefix_index is not None
                        and self.prefix_index.evict_lru(pool)):
                    self.trie_evictions += 1
                    continue
                if self.scfg.preemption and self._preempt_one(st.priority):
                    continue
                self._spill_to_queue(st)
                break

    @staticmethod
    def _bucket(n: int) -> int:
        """Next power of two >= n: bounds the distinct (rows, length)
        shapes the batched prefill compiles for."""
        b = 1
        while b < n:
            b *= 2
        return b

    def _prefill_shape(self, n_handles: int, s_max: int):
        """THE (rows, padded-length) jit shape admission uses for a group
        of ``n_handles`` prompts up to ``s_max`` long — shared by
        ``_flush_prefill`` and ``warm_prefill_buckets`` so warmups compile
        exactly the shapes the engine will launch. Attn prompts pad to a
        power of two (causality keeps padding invisible); ssm/hybrid run
        at exact length (recurrent state is not padding-invariant)."""
        n_rows = min(self._bucket(n_handles), self.pool.n_lanes)
        s_pad = self._bucket(s_max) if self.cfg.block == "attn" else s_max
        return n_rows, s_pad

    def _prefill_batch(self, handles: List[ResultStream]) -> None:
        """Batched prefill for ``handles``: rows bucketed to a power of two
        (padding rows scatter into the sink page / drop their lane writes),
        prompts right-padded to a power-of-two length (causal attention
        keeps padding invisible to the real tokens).

        Length padding is only sound for pure-attention models: K/V are
        position-local, so padded positions land in the sink page and the
        real rows' bytes are untouched. An SSM branch carries a *recurrent*
        state out of the prefill, and padding tokens would keep updating it
        past the prompt — so ssm/hybrid admissions are grouped by exact
        prompt length (still one call per group, just no length padding).
        """
        if self.cfg.block != "attn" and len(handles) > 1:
            by_len: Dict[int, List[ResultStream]] = {}
            for h in handles:
                by_len.setdefault(h.request.prompt.size, []).append(h)
            for group in by_len.values():
                self._flush_prefill(group)
        else:
            self._flush_prefill(handles)
        # Admission bookkeeping (SUBMISSION order, independent of flush
        # grouping) happens in _finish_admission after every launch.

    def _screen_poison(self, handles: List[ResultStream]
                       ) -> List[ResultStream]:
        """Site "serve/prefill": one invocation per request entering a
        prefill launch, so an injected raise fails exactly one request —
        the handle is terminated with status="error", its lane + pages
        reclaimed, and the rest of the batch proceeds."""
        if faults.active() is None:
            return handles
        ok = []
        for h in handles:
            try:
                faults.fire("serve/prefill")
                ok.append(h)
            except Exception:
                self._terminate(h, "error", time.perf_counter())
        return ok

    def _flush_prefill(self, handles: List[ResultStream]) -> None:
        handles = self._screen_poison(handles)
        if not handles:
            return
        try:
            self._launch_prefill(handles)
        except Exception:
            if len(handles) == 1:
                self._terminate(handles[0], "error", time.perf_counter())
                return
            # Poison isolation: the batched launch raised — re-run one
            # request per launch so only the raiser fails and the rest
            # of the batch prefills normally.
            for h in handles:
                self._flush_prefill([h])

    def _launch_prefill(self, handles: List[ResultStream]) -> None:
        pool = self.pool
        n_rows, s_pad = self._prefill_shape(
            len(handles), max(h.request.prompt.size for h in handles))
        tokens = np.zeros((n_rows, s_pad), np.int32)
        lengths = np.zeros((n_rows,), np.int32)
        lanes = np.full((n_rows,), pool.n_lanes, np.int32)  # OOB => drop
        ptab = np.full((n_rows, pool.max_pages), pool.sink, np.int32)
        for i, h in enumerate(handles):
            prompt = h.request.prompt
            tokens[i, :prompt.size] = prompt
            lengths[i] = prompt.size
            lanes[i] = h.slot
            ptab[i] = pool.page_table[h.slot]
        with span("serve/phase/prefill", self.registry):
            hid, new_cache = self._prefill(self.params, tokens, lengths,
                                           lanes, pool.cache, ptab)
            del hid   # first output token comes from the decode step,
            #           matching the lock-step path token-for-token
            pool.swap_cache(new_cache)
        self.prefill_calls += 1
        self._compiled = True

    def _flush_suffix_prefill(self, handles: List[ResultStream]) -> None:
        """Sharing-path prefill: each admitted prompt runs only its
        UNMATCHED suffix through the paged multi-token step — attention
        gathers the shared prefix pages through the lane's page table, so
        the suffix K/V comes out byte-identical to a full prefill while
        the matched tokens' compute and writes are skipped entirely.
        Rows and lengths pad to powers of two; padded rows carry an
        all-sink page table and zero length (writes routed to the sink).
        """
        handles = self._screen_poison(handles)
        pool = self.pool
        jobs = [h for h in handles
                if h._suffix_start < h.request.prompt.size]
        if not jobs:
            return                  # fully-matched prompts: nothing to run
        try:
            self._launch_suffix_prefill(jobs)
        except Exception:
            if len(jobs) == 1:
                self._terminate(jobs[0], "error", time.perf_counter())
                return
            for h in jobs:          # poison isolation, as in the full path
                self._flush_suffix_prefill([h])

    def _launch_suffix_prefill(self, jobs: List[ResultStream]) -> None:
        pool = self.pool
        n_rows = self._bucket(len(jobs))
        s_pad = self._bucket(max(h.request.prompt.size - h._suffix_start
                                 for h in jobs))
        tokens = np.zeros((n_rows, s_pad), np.int32)
        start = np.zeros((n_rows,), np.int32)
        lengths = np.zeros((n_rows,), np.int32)
        ptab = np.full((n_rows, pool.max_pages), pool.sink, np.int32)
        for i, h in enumerate(jobs):
            suffix = h.request.prompt[h._suffix_start:]
            tokens[i, :suffix.size] = suffix
            start[i] = h._suffix_start
            lengths[i] = suffix.size
            ptab[i] = pool.page_table[h.slot]
        with span("serve/phase/prefill", self.registry):
            hid, new_cache = self._paged_prefill(
                self.params, tokens, start, lengths, pool.cache, ptab)
            del hid   # first output token comes from the decode step
            pool.swap_cache(new_cache)
        self.prefill_calls += 1
        self._compiled = True

    def _decode_and_retire(self) -> None:
        n = self.scfg.n_slots
        token = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        for slot, st in self._active.items():
            token[slot, 0] = st.next_input
            pos[slot] = st.cache_pos
        with span("serve/phase/decode", self.registry):
            h, new_cache = self._decode(self.params, token, self.pool.cache,
                                        pos, self.pool.page_table)
            self.pool.swap_cache(new_cache)
        self._compiled = True
        self.decode_steps += 1
        self._occupancy_sum += len(self._active)
        self._page_occupancy_sum += self.pool.num_mapped_pages

        with span("serve/phase/select", self.registry):
            next_tokens = self._select(h)

        now = time.perf_counter()
        n_live = len(self._active)
        for slot in list(self._active):
            st = self._active[slot]
            self._emit_token(slot, st, int(next_tokens[slot]), now)
        self._c_tokens.inc(n_live)
        self._post_step_metrics()

    def _emit_token(self, slot: int, st: ResultStream, tok: int,
                    now: float) -> bool:
        """Append one generated token and retire the request when any
        stop condition fires (the same checks, in the same order, as the
        lock-step oracle). Returns True when the request retired."""
        if st.first_token_at is None:
            st.first_token_at = now
            self._h_ttft.observe(now - st.submitted_at)
        st.tokens.append(tok)
        st.history.append(tok)
        st.next_input = tok
        st.cache_pos += 1
        done = (len(st.tokens) >= st.request.max_new_tokens
                or (st._eos is not None and tok == st._eos)
                or st.cache_pos >= self.scfg.max_len)
        if done:
            st.done = True
            st.finished_at = now
            del self._active[slot]
            self.pool.release(slot)
            self.completed.append(st)
            self.completed_count += 1
            latency = st.finished_at - st.submitted_at
            self._h_latency.observe(latency)
            self._class_hist(st.priority).observe(latency)
            if (st.request.deadline_s is not None
                    and latency > st.request.deadline_s):
                self.deadline_misses += 1
            if self.exporter is not None:
                self.exporter.emit({
                    "event": "request", "request_id": st.request_id,
                    "tokens": len(st.tokens), "priority": st.priority,
                    "preempted": st.preempted,
                    "admission_wait_s": (st.admitted_at
                                         - st.submitted_at),
                    "ttft_s": st.first_token_at - st.submitted_at,
                    "latency_s": latency})
        return done

    def _class_hist(self, priority: int):
        h = self._class_hists.get(priority)
        if h is None:
            h = self.registry.histogram(f"serve/latency_s/class_{priority}")
            self._class_hists[priority] = h
        return h

    def _post_step_metrics(self) -> None:
        self._g_active.set(len(self._active))
        self._g_pages.set(self.pool.num_mapped_pages / self.pool.n_pages)
        if (self.exporter is not None
                and self.decode_steps % self.metrics_interval == 0):
            self.exporter.emit({
                "event": "serve_step", "engine_step": self.decode_steps,
                "queue_depth": self.num_pending,
                "active": len(self._active),
                "page_occupancy": (self.pool.num_mapped_pages
                                   / self.pool.n_pages)})

    def _spec_decode_and_retire(self) -> None:
        """Speculative step: draft → one batched multi-token verify →
        exact accept/reject → retire.

        Each lane's verify chain is ``[y_last, d1..dk]`` fed at positions
        ``cache_pos .. cache_pos+k``; the target model's own greedy choice
        at every chain position comes out of ONE launch. Acceptance is the
        longest draft prefix that matches those choices, plus the bonus
        token — the emitted tokens are exactly the lock-step sequence, so
        speculation changes wall-clock only, never bytes (oracle-tested).
        K/V written for rejected positions is dead on arrival: the next
        step's writes land on top of it before causality can expose it.
        """
        scfg, pool = self.scfg, self.pool
        drafts: Dict[int, List[int]] = {}
        for slot, st in self._active.items():
            # k is capped so the LAST chain write stays inside the
            # request's budget: cache_pos+k <= prompt+max_new-1 (and the
            # max_len retirement bound the oracle also respects).
            cap = min(scfg.max_draft,
                      st.request.max_new_tokens - len(st.tokens) - 1,
                      scfg.max_len - 1 - st.cache_pos)
            d = self.draft.propose(tuple(st.history), cap) if cap > 0 \
                else []
            drafts[slot] = [int(t) for t in d[:max(cap, 0)]]
        self._ensure_capacity({s: len(d) for s, d in drafts.items()})
        if not self._active:
            return                      # capacity pressure spilled everyone
        k_max = max(len(drafts[s]) for s in self._active)
        s_pad = self._bucket(k_max + 1)
        n = scfg.n_slots
        tokens = np.zeros((n, s_pad), np.int32)
        start = np.zeros((n,), np.int32)
        lengths = np.zeros((n,), np.int32)
        ptab = np.full((n, pool.max_pages), pool.sink, np.int32)
        for slot, st in self._active.items():
            chain = [st.next_input] + drafts[slot]
            tokens[slot, :len(chain)] = chain
            start[slot] = st.cache_pos
            lengths[slot] = len(chain)
            ptab[slot] = pool.page_table[slot]
        with span("serve/phase/decode", self.registry):
            h, new_cache = self._paged_prefill(self.params, tokens, start,
                                               lengths, pool.cache, ptab)
            pool.swap_cache(new_cache)
        self.decode_steps += 1
        self.verify_steps += 1
        self._occupancy_sum += len(self._active)
        self._page_occupancy_sum += pool.num_mapped_pages

        with span("serve/phase/select", self.registry):
            sel = np.asarray(self._select(h, multi=True))   # (n, s_pad)

        now = time.perf_counter()
        emitted = 0
        for slot in list(self._active):
            st = self._active[slot]
            d = drafts[slot]
            self.drafts_proposed += len(d)
            a = 0
            while a < len(d) and d[a] == int(sel[slot, a]):
                a += 1
            self.drafts_accepted += a
            for j in range(a + 1):
                tok = int(sel[slot, j])
                # Feed the tree's own (possibly stale-feature) choice
                # back to the draft source: next time this context
                # repeats, the whole continuation replays as the draft.
                self.draft.observe(tuple(st.history), tok)
                emitted += 1
                if self._emit_token(slot, st, tok, now):
                    break
        self._c_tokens.inc(emitted)
        self._post_step_metrics()

    def _select(self, h, multi: bool = False) -> np.ndarray:
        """Next-token selection for every slot (free rows give garbage that
        the caller never reads). ``multi=True`` selects at EVERY position
        of a (B, S, d) verify step — the head path (dense scores, beam
        descent, re-scoring) is row-local over leading batch dims, so the
        per-position choices are bitwise the single-token ones. The
        candidate cache is bypassed in that mode (it keys whole-prefix
        single steps; skipping it can only cost duplicate descent work,
        never change a result)."""
        if not self.beam:
            return np.asarray(self._select_dense(self.params,
                                                 self.head_state, h))

        cache = None if multi else self.candidate_cache
        cached: Dict[int, Any] = {}
        if cache is not None:
            for slot, st in self._active.items():
                cached[slot] = cache.get(tuple(st.history))
        all_hit = (cache is not None and self._active
                   and all(v is not None for v in cached.values()))
        if all_hit:
            # Skip the tree descent entirely: assemble cached candidate
            # sets; free rows stay all-invalid (-1 / -inf).
            n = self.scfg.n_slots
            cand = np.full((n, self.beam), -1, np.int32)
            log_pn = np.full((n, self.beam), -np.inf, np.float32)
            for slot, (c, lp) in cached.items():
                cand[slot], log_pn[slot] = c, lp
            self.descent_skips += 1
        else:
            with span("serve/phase/descent", self.registry):
                cand, log_pn = self._propose(self.head_state, h)
            if cache is not None:
                # One host transfer for both arrays (they are tiny:
                # n_slots x beam ids + log-probs).
                cand_np, log_pn_np = jax.device_get((cand, log_pn))
                for slot, st in self._active.items():
                    if cached.get(slot) is None:
                        cache.put(tuple(st.history), cand_np[slot],
                                  log_pn_np[slot])
        return np.asarray(self._score(self.params, h, cand, log_pn))
