"""Continuous-batching serving engine over the jit-able step functions.

One engine iteration (``Engine.step``) is: admit → decode → select → retire.

  admit   — pop FIFO'd requests into free KV slots (``SlotPool.alloc``) and
            prefill each prompt into its slot (``make_prefill_into_slot``);
            new requests join mid-flight, no draining of the running batch.
  decode  — ONE jitted ``make_slot_decode`` call for the whole pool: (B, 1)
            in-flight tokens, (B,) per-slot ``cache_pos``. Free slots ride
            along as masked garbage (their compute is the price of a static
            batch shape; their writes are dead by construction).
  select  — next-token choice from the final hiddens. Dense path: full
            Eq. 5 debiased scores + argmax (O(C)). Beam path: the prefix-
            keyed ``CandidateCache`` is consulted per slot; on an all-hit
            step the O(beam·k·log C) tree descent is skipped entirely and
            the cached candidate sets go straight to re-scoring
            (O(beam·K) gather-and-dot, optionally the gather_scores Pallas
            kernel or mesh-sharded ``sharded_candidate_scores``).
  retire  — per-slot EOS / max-new-tokens / max-len checks; finished
            requests release their slot the same step, making room for the
            next admission.

Request lifecycle: QUEUED → RUNNING(slot) → FINISHED. The caller drives the
loop (``step()`` / ``run()``) and reads results incrementally through the
streaming ``ResultStream`` handle returned by ``submit``.

Determinism: greedy decode has no RNG, admission is FIFO, and the per-slot
math is row-independent, so a request's output depends only on its prompt
and the params — byte-identical to the lock-step ``make_serve_step`` path
(property-tested in tests/test_serve_engine.py). The candidate cache can
only skip work, never change results: a prefix hit implies a bit-identical
hidden state, hence identical re-scored argmax.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heads as heads_lib
from repro.core import tree as tree_lib
from repro.core.heads import HeadConfig, HeadParams
from repro.models import lm_head
from repro.models.config import ModelConfig
from repro.models import transformer
from repro.serve.cache_pool import SlotPool
from repro.serve.candidate_cache import CandidateCache
from repro.train.step import (make_prefill, make_prefill_into_slot,
                              make_serve_step, make_slot_decode)


_LOCKSTEP_FNS: Dict[Any, Any] = {}


def lockstep_decode(cfg: ModelConfig, hcfg: HeadConfig, params, head_state,
                    prompts, gen_tokens: int, topk_beam: int = 0,
                    mesh=None, cache_dtype=jnp.float32) -> np.ndarray:
    """Reference fixed-batch greedy decode — the pre-engine serving loop.

    THE byte-identity oracle for the engine: tests, benchmarks, and
    examples compare ``Engine`` outputs against this exact loop, so it is
    defined once here. Returns the (batch, gen_tokens) generated ids.
    The jitted prefill/step pair is memoized per static configuration so
    repeated calls (hypothesis examples, bench chunks) reuse the compile.
    """
    prompts = jnp.asarray(prompts)
    b, pl = prompts.shape
    # Mesh objects are hashable and key by value — never id(), which can
    # alias a dead mesh after GC. Cap the memo: it exists to dedupe
    # repeated oracle calls (hypothesis examples, bench chunks), not to
    # pin every configuration's executables forever.
    key = (cfg, hcfg, topk_beam, mesh, jnp.dtype(cache_dtype).name)
    if key not in _LOCKSTEP_FNS:
        if len(_LOCKSTEP_FNS) >= 16:
            _LOCKSTEP_FNS.clear()
        _LOCKSTEP_FNS[key] = (
            jax.jit(make_prefill(cfg)),
            jax.jit(make_serve_step(cfg, hcfg, topk_beam=topk_beam,
                                    mesh=mesh)))
    prefill, step = _LOCKSTEP_FNS[key]
    cache = transformer.init_cache(cfg, b, pl + gen_tokens,
                                   dtype=cache_dtype)
    _, cache = prefill(params, prompts, cache)
    token, toks = prompts[:, -1:], []
    for t in range(gen_tokens):
        token, cache = step(params, head_state, token, cache,
                            jnp.int32(pl + t))
        toks.append(np.asarray(token))
    return np.concatenate(toks, 1)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (static: they shape the compiled step functions)."""
    n_slots: int = 8             # concurrent decode lanes (KV pool rows)
    max_len: int = 256           # per-slot KV capacity
    beam: int = 0                # 0 = dense O(C) scoring; >0 = tree beam
    use_kernel: bool = False     # gather_scores Pallas kernel for scoring
    mesh: Any = None             # route scoring via sharded_candidate_scores
    use_candidate_cache: bool = True   # prefix-keyed descent skipping
    candidate_cache_capacity: int = 4096
    eos_id: Optional[int] = None       # engine-wide default stop token
    cache_dtype: Any = jnp.float32
    retain_completed: int = 4096       # finished handles kept for audit;
    #                                    older ones drop (callers hold
    #                                    their own ResultStream refs)


@dataclasses.dataclass
class Request:
    """One generation request. ``eos_id=None`` inherits the engine default;
    ``max_new_tokens`` is the per-sequence length budget."""
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None


class ResultStream:
    """Streaming handle: ``tokens`` grows as the engine decodes; ``done``
    flips on retirement. Timestamps are perf_counter seconds."""

    def __init__(self, request: Request, request_id: int, now: float):
        self.request = request
        self.request_id = request_id
        self.tokens: List[int] = []
        self.done = False
        self.submitted_at = now
        self.admitted_at: Optional[float] = None
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        # -- scheduler state (engine-internal) --
        self.slot: Optional[int] = None
        self.cache_pos = 0
        self.next_input = 0
        self.history: List[int] = []
        self._eos: Optional[int] = None

    @property
    def eos_hit(self) -> bool:
        return bool(self.tokens) and self.tokens[-1] == self._eos

    def result(self) -> np.ndarray:
        assert self.done, "request still in flight"
        return np.asarray(self.tokens, np.int32)

    @property
    def latency(self) -> float:
        assert self.done
        return self.finished_at - self.submitted_at


class Engine:
    """Continuous-batching decode engine. See module docstring."""

    def __init__(self, cfg: ModelConfig, hcfg: HeadConfig, params,
                 head_state, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.hcfg = hcfg
        self.params = params
        self.head_state = head_state
        self.scfg = serve_cfg
        self.pool = SlotPool(cfg, serve_cfg.n_slots, serve_cfg.max_len,
                             dtype=serve_cfg.cache_dtype)
        if serve_cfg.mesh is not None:
            # Mesh serving: shard the KV pool per the decode policy (seq
            # over 'model') so each device holds 1/TP of the cache instead
            # of a full replica next to sharded params. Pool shapes that
            # the mesh cannot divide (jax 0.4 requires exact divisibility)
            # stay on default placement — GSPMD reshards inside the step.
            from repro.parallel.sharding import cache_shardings
            try:
                self.pool.cache = jax.device_put(
                    self.pool.cache,
                    cache_shardings(cfg, serve_cfg.mesh,
                                    jax.eval_shape(lambda: self.pool.cache),
                                    serve_cfg.n_slots))
            except ValueError:
                pass
        beam = serve_cfg.beam
        if beam:
            assert hcfg.kind == "adversarial_ns" and \
                head_state.gen.tree is not None, \
                "beam serving needs a fitted adversarial generator tree"
            beam = min(beam, tree_lib.padded_size(hcfg.num_labels))
        self.beam = beam
        self.candidate_cache = (
            CandidateCache(serve_cfg.candidate_cache_capacity)
            if beam and serve_cfg.use_candidate_cache else None)

        self._queue: "deque[ResultStream]" = deque()
        self._active: Dict[int, ResultStream] = {}     # slot -> handle
        self._next_id = 0
        # Bounded audit trails — a long-running engine must not grow host
        # memory per request served; counters carry the lifetime totals.
        keep = serve_cfg.retain_completed
        self.admission_order: "deque[int]" = deque(maxlen=keep)
        self.completed: "deque[ResultStream]" = deque(maxlen=keep)
        self.completed_count = 0
        self.decode_steps = 0
        self.descent_skips = 0      # all-hit steps that skipped beam_search
        self._occupancy_sum = 0

        # Jitted step functions. The cache argument is donated so the pool's
        # device buffers are reused in place step over step.
        self._prefill = jax.jit(
            make_prefill_into_slot(cfg, serve_cfg.max_len,
                                   cache_dtype=serve_cfg.cache_dtype),
            donate_argnums=(2,))
        self._decode = jax.jit(make_slot_decode(cfg), donate_argnums=(2,))
        self._select_dense = jax.jit(self._build_dense_select())
        if beam:
            self._propose = jax.jit(self._build_propose())
            self._score = jax.jit(self._build_score())

    # -- jitted head-path builders --------------------------------------

    def _build_dense_select(self):
        cfg, hcfg = self.cfg, self.hcfg

        def dense_select(params, head_state, h):
            scores = lm_head.lm_predictive_scores(
                cfg, hcfg, HeadParams(**params["head"]), head_state, h)
            return jnp.argmax(scores, axis=-1).astype(jnp.int32)

        return dense_select

    def _build_propose(self):
        beam = self.beam

        def propose(head_state, h):
            x_gen = lm_head.gen_features(head_state, h)
            return tree_lib.beam_search(head_state.gen.tree, x_gen, beam,
                                        beam)

        return propose

    def _build_score(self):
        hcfg = self.hcfg
        score_fn = lm_head.serving_score_fn(
            self.cfg, use_kernel=self.scfg.use_kernel, mesh=self.scfg.mesh)

        def score(params, h, cand, log_pn):
            # heads.rescore_candidates is the same tail predictive_topk
            # runs, so engine outputs match the lock-step beam path
            # bit-for-bit.
            _, labels = heads_lib.rescore_candidates(
                hcfg, HeadParams(**params["head"]), h, cand, log_pn, 1,
                score_fn=score_fn)
            return labels[..., 0].astype(jnp.int32)

        return score

    # -- public API ------------------------------------------------------

    def submit(self, request: Request) -> ResultStream:
        prompt = np.asarray(request.prompt, np.int32)
        assert prompt.ndim == 1 and prompt.size >= 1, "prompt must be (S,)"
        if prompt.size + request.max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds slot capacity "
                f"({self.scfg.max_len})")
        request = dataclasses.replace(request, prompt=prompt)
        handle = ResultStream(request, self._next_id, time.perf_counter())
        handle._eos = (request.eos_id if request.eos_id is not None
                       else self.scfg.eos_id)
        self._next_id += 1
        self._queue.append(handle)
        return handle

    @property
    def num_pending(self) -> int:
        return len(self._queue)

    @property
    def num_active(self) -> int:
        return len(self._active)

    def step(self) -> bool:
        """One admit → decode → select → retire iteration. Returns False
        when there was nothing to do (idle engine)."""
        self._admit()
        if not self._active:
            return False
        self._decode_and_retire()
        return True

    def run(self) -> None:
        """Drive until queue and in-flight batch are empty."""
        while self.step():
            pass

    def stream(self, handle: ResultStream):
        """Yield ``handle``'s tokens as they are produced, stepping the
        engine as needed (single-consumer streaming API)."""
        sent = 0
        while True:
            while sent < len(handle.tokens):
                yield handle.tokens[sent]
                sent += 1
            if handle.done:
                return
            if not self.step():
                raise RuntimeError("engine idle but request not finished")

    def stats(self) -> dict:
        out = {
            "completed": self.completed_count,
            "decode_steps": self.decode_steps,
            "descent_skips": self.descent_skips,
            # The honest amortization metric: the fraction of decode steps
            # whose tree descent was actually skipped (a partial-hit step
            # still descends, even though its lookups count as cache hits).
            "descent_skip_rate": (self.descent_skips / self.decode_steps
                                  if self.decode_steps else 0.0),
            "mean_occupancy": (self._occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0),
            "n_slots": self.scfg.n_slots,
        }
        if self.candidate_cache is not None:
            out["candidate_cache"] = self.candidate_cache.stats()
        return out

    # -- scheduler internals --------------------------------------------

    def _admit(self) -> None:
        """FIFO admission into free slots; prefill each admitted prompt.

        Head-of-line order is preserved unconditionally (a request is never
        skipped in favour of a later one) — the fairness property the tests
        pin down.
        """
        while self._queue and self.pool.num_free:
            handle = self._queue.popleft()
            slot = self.pool.alloc()
            assert slot is not None
            prompt = handle.request.prompt
            h, new_cache = self._prefill(self.params, prompt[None, :],
                                         self.pool.cache, slot)
            del h   # first output token comes from the decode step below,
            #         matching the lock-step path token-for-token
            self.pool.swap_cache(new_cache)
            handle.slot = slot
            handle.cache_pos = int(prompt.size)
            handle.next_input = int(prompt[-1])
            handle.history = [int(t) for t in prompt]
            handle.admitted_at = time.perf_counter()
            self.admission_order.append(handle.request_id)
            self._active[slot] = handle

    def _decode_and_retire(self) -> None:
        n = self.scfg.n_slots
        token = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        for slot, st in self._active.items():
            token[slot, 0] = st.next_input
            pos[slot] = st.cache_pos
        h, new_cache = self._decode(self.params, token, self.pool.cache,
                                    pos)
        self.pool.swap_cache(new_cache)
        self.decode_steps += 1
        self._occupancy_sum += len(self._active)

        next_tokens = self._select(h)

        now = time.perf_counter()
        for slot in list(self._active):
            st = self._active[slot]
            tok = int(next_tokens[slot])
            if st.first_token_at is None:
                st.first_token_at = now
            st.tokens.append(tok)
            st.history.append(tok)
            st.next_input = tok
            st.cache_pos += 1
            done = (len(st.tokens) >= st.request.max_new_tokens
                    or (st._eos is not None and tok == st._eos)
                    or st.cache_pos >= self.scfg.max_len)
            if done:
                st.done = True
                st.finished_at = now
                del self._active[slot]
                self.pool.release(slot)
                self.completed.append(st)
                self.completed_count += 1

    def _select(self, h) -> np.ndarray:
        """Next-token selection for every slot (free rows give garbage that
        the caller never reads)."""
        if not self.beam:
            return np.asarray(self._select_dense(self.params,
                                                 self.head_state, h))

        cache = self.candidate_cache
        cached: Dict[int, Any] = {}
        if cache is not None:
            for slot, st in self._active.items():
                cached[slot] = cache.get(tuple(st.history))
        all_hit = (cache is not None and self._active
                   and all(v is not None for v in cached.values()))
        if all_hit:
            # Skip the tree descent entirely: assemble cached candidate
            # sets; free rows stay all-invalid (-1 / -inf).
            n = self.scfg.n_slots
            cand = np.full((n, self.beam), -1, np.int32)
            log_pn = np.full((n, self.beam), -np.inf, np.float32)
            for slot, (c, lp) in cached.items():
                cand[slot], log_pn[slot] = c, lp
            self.descent_skips += 1
        else:
            cand, log_pn = self._propose(self.head_state, h)
            if cache is not None:
                # One host transfer for both arrays (they are tiny:
                # n_slots x beam ids + log-probs).
                cand_np, log_pn_np = jax.device_get((cand, log_pn))
                for slot, st in self._active.items():
                    if cached.get(slot) is None:
                        cache.put(tuple(st.history), cand_np[slot],
                                  log_pn_np[slot])
        return np.asarray(self._score(self.params, h, cand, log_pn))
