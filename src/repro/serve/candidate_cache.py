"""Prefix-keyed LRU cache of beam-proposed candidate sets.

The expensive part of the sublinear decode is the tree descent:
``tree_lib.beam_search`` walks the adversarial generator for O(beam·k·log C)
per token. But greedy decode is deterministic — the candidate set the tree
proposes depends only on the token *prefix* (prompt + tokens generated so
far), because the hidden state, hence the generator feature
``x_gen = proj(h)``, is a pure function of that prefix under fixed params.
Repeated prefixes (shared system prompts, retried requests, common query
heads — the ROADMAP's named workload) can therefore skip the descent
entirely and jump straight to candidate re-scoring
(``candidate_scores`` / ``gather_scores`` + Eq. 5 debias), which is
O(beam·K) with no tree in sight.

Key scheme: ``key = tuple(prompt tokens) + tuple(generated tokens)`` — the
full history whose last token is the decode step's input. Value: the
``(candidates, log_pn)`` pair beam search returned for that step, as host
numpy arrays of shape (beam,). Exactness: on a true prefix repeat the
hidden state is bit-identical, so scoring cached candidates reproduces the
fresh path byte-for-byte; the cache can never change outputs, only skip
work — PROVIDED the generator that proposed the entry is still installed.
A generator swap changes the tree, hence the candidate sets and the Eq. 5
``log_pn`` debias terms, so every pre-swap entry is stale the moment a new
head state lands: entries are keyed on an explicit generator ``version``
and :meth:`CandidateCache.bump_version` (called by
``Engine.swap_head_state``) retires the whole resident set at once.
Eviction is plain LRU. Sizing: the value arrays are tiny
(beam · 8 bytes) but the tuple key costs ~8 bytes per history token plus
Python object overhead — roughly 2 KB for a 256-token prefix — so size
the capacity against key memory (a hashed/rolling key is the upgrade path
if million-entry caches over long prefixes are ever needed).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

Key = Tuple[int, ...]


class CandidateCache:
    """LRU map: token-prefix → (candidates (beam,), log_pn (beam,))."""

    def __init__(self, capacity: int = 4096):
        assert capacity >= 1
        self.capacity = capacity
        self._data: "OrderedDict[tuple, Tuple[np.ndarray, np.ndarray]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # Generator version the resident entries were proposed under.
        # Bumped (and the map cleared) on every head-state swap — a cached
        # (candidates, log_pn) pair is only exact for the tree that
        # produced it.
        self.version = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Key) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        hit = self._data.get((self.version, *key))
        if hit is None:
            self.misses += 1
            return None
        self._data.move_to_end((self.version, *key))
        self.hits += 1
        return hit

    def put(self, key: Key, candidates: np.ndarray,
            log_pn: np.ndarray) -> None:
        key = (self.version, *key)
        if key in self._data:
            self._data.move_to_end(key)
            return
        self._data[key] = (np.asarray(candidates), np.asarray(log_pn))
        if len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def bump_version(self) -> None:
        """Invalidate every resident entry (generator swap). The version
        prefix in the key makes this airtight even if a clear were ever
        made lazy: post-swap lookups can only match post-swap entries."""
        self._data.clear()
        self.version += 1
        self.invalidations += 1

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._data),
                "hit_rate": self.hit_rate, "version": self.version,
                "invalidations": self.invalidations}
