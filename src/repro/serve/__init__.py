"""repro.serve — continuous-batching serving engine (design overview).

PR 1 made the per-token math sublinear in C (`tree_lib.beam_search` +
`predictive_topk`); this package makes *serving* a system: request
admission, KV-slot management, and cross-request amortization of the
adversarial generator's candidate work, sitting between the model/step
layer (`repro.train.step`, `repro.models`) and the launchers
(`repro.launch.serve`, `examples/serve_lm.py`).

Scheduler states (``engine.Engine``)::

    submit()            admit (FIFO)            retire
  ───────────▶ QUEUED ─────────────▶ RUNNING ─────────▶ FINISHED
                        lane+pages     │  ▲              lane + pages
                        = alloc(n);    └──┘              reclaimed,
                        batched        decode step       EOS / max-new /
                        prefill        (page-table       max-len reached
                        into pages     gather/scatter)

Page lifecycle (``cache_pool.PagedPool``): the pool owns one device arena
pytree sized (layers, n_pages + 1, page_len, ...) — fixed-size KV pages
plus a sink page for free lanes' garbage writes — allocated once.
Admission allocates a decode lane plus ``ceil((prompt + max_new) /
page_len)`` pages, records them in the lane's page table, and prefills
ALL newly-admitted prompts in one padded jitted call
(`train.step.make_batched_prefill`, row/length power-of-two bucketing to
bound recompiles). Decode scatter-writes each lane's token at
``(page_table[pos // page_len], pos % page_len)`` and gathers the lane's
pages back into logical order for the softmax (paged branch of
`models.layers.attention`); retirement returns lane and pages to their
free lists. Stale bytes from previous page occupants are never read:
causal masking hides positions above the new occupant's depth and prefill
overwrites the region below. Steady state does zero device allocation
(the jitted steps donate the arena). Against the old one-max_len-buffer-
per-slot layout, memory is charged per reachable position instead of per
worst-case slot, so mixed-length traffic packs several times more
concurrent requests into the same device bytes.

Candidate-cache key scheme (``candidate_cache.CandidateCache``): key =
the full token history ``tuple(prompt + generated)`` whose last element is
the step's input token; value = the ``(candidates, log_pn)`` sets the tree
beam proposed for that history. Greedy decode is deterministic, so a key
hit implies a bit-identical hidden state and the cached candidates are
exactly what the descent would return — repeated prefixes skip the
O(beam·k·log C) tree walk and go straight to O(beam·K) re-scoring with
Eq. 5 debias on the candidate set.

Multi-tenant extensions (PR 9, DESIGN.md §12), all opt-in per
``ServeConfig`` and byte-identical to lock-step decode when enabled:

- ``prefix_index.PrefixIndex`` — page-granular radix trie mapping shared
  prompt prefixes onto the same physical pages (refcounts in the pool,
  copy-on-write tails), with LRU leaf-first eviction of cached pages.
- ``spec.ReplayDraft`` — the fitted generator tree as draft model:
  continuation replay + stale-feature seeds verified by one batched
  multi-token target step with exact accept/reject.
- SLA scheduling — per-request priority classes, preemption with page
  spill-and-restore, and on-demand page growth replacing worst-case
  reservation.

``traffic`` supplies the Poisson-arrival driver used by
``benchmarks/bench_engine.py`` to measure request throughput and p50/p99
latency for dense vs beam vs beam+cache serving, plus the adversarial
generators (shared-prefix Zipf bursts, heavy-tail length mixes) the
multi-tenant features target.
"""
from repro.serve.cache_pool import PagedPool, PageSpill
from repro.serve.candidate_cache import CandidateCache
from repro.serve.engine import (Engine, Request, ResultStream, ServeConfig,
                                lockstep_decode)
from repro.serve.prefix_index import PrefixIndex
from repro.serve.spec import ContinuationStore, NullDraft, ReplayDraft
from repro.serve.traffic import (TrafficConfig, drive,
                                 make_heavy_tail_mix,
                                 make_shared_prefix_burst, make_workload)

__all__ = ["PagedPool", "PageSpill", "CandidateCache", "Engine", "Request",
           "ResultStream", "ServeConfig", "TrafficConfig", "drive",
           "lockstep_decode", "make_workload", "PrefixIndex",
           "ContinuationStore", "NullDraft", "ReplayDraft",
           "make_shared_prefix_burst", "make_heavy_tail_mix"]
