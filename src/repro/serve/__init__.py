"""repro.serve — continuous-batching serving engine (design overview).

PR 1 made the per-token math sublinear in C (`tree_lib.beam_search` +
`predictive_topk`); this package makes *serving* a system: request
admission, KV-slot management, and cross-request amortization of the
adversarial generator's candidate work, sitting between the model/step
layer (`repro.train.step`, `repro.models`) and the launchers
(`repro.launch.serve`, `examples/serve_lm.py`).

Scheduler states (``engine.Engine``)::

    submit()            admit (FIFO)            retire
  ───────────▶ QUEUED ─────────────▶ RUNNING ─────────▶ FINISHED
                        slot=alloc()  │  ▲               slot released,
                        prefill into  └──┘               EOS / max-new /
                        the slot      decode step        max-len reached

Slot lifecycle (``cache_pool.SlotPool``): the pool owns one device cache
pytree sized (layers, n_slots, max_len, ...), allocated once — admission
prefills a slot in place, decode writes one row per step at the slot's own
``cache_pos`` (per-row scatter in `models.layers.attention`), retirement
returns the index to a free list. Stale bytes from previous occupants are
never read: causal masking hides positions above the new occupant's depth
and prefill overwrites the region below. Steady state does zero device
allocation (the jitted steps donate the cache).

Candidate-cache key scheme (``candidate_cache.CandidateCache``): key =
the full token history ``tuple(prompt + generated)`` whose last element is
the step's input token; value = the ``(candidates, log_pn)`` sets the tree
beam proposed for that history. Greedy decode is deterministic, so a key
hit implies a bit-identical hidden state and the cached candidates are
exactly what the descent would return — repeated prefixes skip the
O(beam·k·log C) tree walk and go straight to O(beam·K) re-scoring with
Eq. 5 debias on the candidate set.

``traffic`` supplies the Poisson-arrival driver used by
``benchmarks/bench_engine.py`` to measure request throughput and p50/p99
latency for dense vs beam vs beam+cache serving.
"""
from repro.serve.cache_pool import SlotPool
from repro.serve.candidate_cache import CandidateCache
from repro.serve.engine import (Engine, Request, ResultStream, ServeConfig,
                                lockstep_decode)
from repro.serve.traffic import TrafficConfig, drive, make_workload

__all__ = ["SlotPool", "CandidateCache", "Engine", "Request",
           "ResultStream", "ServeConfig", "TrafficConfig", "drive",
           "lockstep_decode", "make_workload"]
