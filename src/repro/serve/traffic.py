"""Synthetic traffic driver: Poisson arrivals against a live engine.

Models the ROADMAP's "heavy traffic from millions of users" shape at bench
scale: requests arrive as a Poisson process (exponential inter-arrival
gaps at ``rate`` req/s), prompts are random token strings — a fixed
length by default (one length bucket, one prefill jit entry), or mixed
lengths via ``prompt_len_choices``/``gen_tokens_choices`` (the skewed
shape the paged KV pool packs; each distinct length adds a prefill
bucket, so warm them via ``Engine.warm_prefill_buckets`` before timing) —
and a configurable fraction of requests reuse a small set of shared
prompts — the repeated-prefix workload the candidate cache exists for
(shared system prompts / common query heads in production).

The driver is open-loop: a request is submitted the moment its arrival
time passes on the wall clock, regardless of engine backlog, so a slow
serving path shows up as queueing delay in the latency tail rather than
as reduced offered load. ``drive`` pumps ``Engine.step`` until all
requests complete and reports request throughput plus p50/p99 end-to-end
latency (submit → last token).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import Engine, Request, ResultStream


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 32
    rate: float = 50.0            # offered load, requests/second
    prompt_len: int = 8
    gen_tokens: int = 8           # max_new_tokens per request
    # Mixed-length traffic (the skewed shape the paged KV pool exists
    # for): when set, each non-shared request draws its prompt length /
    # token budget uniformly from these choices instead of the scalars
    # above. Shared prompts keep the scalar prompt_len so repeats stay
    # exact repeats.
    prompt_len_choices: Optional[Tuple[int, ...]] = None
    gen_tokens_choices: Optional[Tuple[int, ...]] = None
    vocab_size: int = 1024
    repeat_frac: float = 0.0      # fraction drawing from shared prompts
    n_shared_prompts: int = 1
    eos_id: Optional[int] = None
    seed: int = 0
    # -- adversarial generators (PR 9): the traffic shapes prefix
    #    sharing / speculative decode / SLA scheduling target. --
    # shared-prefix burst (make_shared_prefix_burst): Zipf over a
    # template pool of long preambles, short unique suffixes, arrivals
    # in bursts — N requests paying one preamble's KV/prefill.
    n_templates: int = 8
    zipf_a: float = 1.5           # template popularity skew (>1)
    template_len: int = 24        # shared preamble tokens
    suffix_len: int = 4           # unique per-request tail tokens
    exact_repeat_frac: float = 0.25   # requests with NO suffix (exact
    #                                   prompt repeats: COW-tail +
    #                                   replay-draft hits)
    burst: int = 4                # arrivals per burst instant
    # heavy-tail mix (make_heavy_tail_mix): mostly-short interactive
    # requests sharing the pool with rare long batch jobs — the shape
    # FIFO handles worst and priority/preemption exist for.
    interactive_frac: float = 0.75
    interactive_priority: int = 1
    interactive_deadline_s: Optional[float] = None
    tail_alpha: float = 1.2       # Pareto shape for batch lengths


def make_workload(tcfg: TrafficConfig) -> List[Tuple[float, Request]]:
    """Returns [(arrival_offset_seconds, Request)] sorted by arrival."""
    rng = np.random.default_rng(tcfg.seed)
    gaps = rng.exponential(1.0 / tcfg.rate, size=tcfg.n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]         # first request at t=0
    shared = rng.integers(0, tcfg.vocab_size,
                          (max(1, tcfg.n_shared_prompts), tcfg.prompt_len))

    def pick(choices, default):
        if choices is None:
            return default
        return int(choices[rng.integers(0, len(choices))])

    out = []
    for t in arrivals:
        gen = pick(tcfg.gen_tokens_choices, tcfg.gen_tokens)
        if rng.random() < tcfg.repeat_frac:
            prompt = shared[rng.integers(0, len(shared))]
        else:
            pl = pick(tcfg.prompt_len_choices, tcfg.prompt_len)
            prompt = rng.integers(0, tcfg.vocab_size, pl)
        out.append((float(t), Request(prompt=np.asarray(prompt, np.int32),
                                      max_new_tokens=gen,
                                      eos_id=tcfg.eos_id)))
    return out


def make_shared_prefix_burst(tcfg: TrafficConfig,
                             ) -> List[Tuple[float, Request]]:
    """Adversarial shape #1: Zipf-popular templates, bursty arrivals.

    A pool of ``n_templates`` preambles (``template_len`` tokens each) is
    sampled once; every request picks a template with Zipf(``zipf_a``)
    popularity and appends either nothing (``exact_repeat_frac`` — exact
    prompt repeats, the COW-tail + replay-draft case) or a short unique
    suffix. Arrivals come ``burst`` at a time at Poisson burst instants,
    so a whole burst of one popular template is in the queue at once —
    prefix sharing pays that preamble's KV and prefill exactly once,
    FIFO-without-sharing pays it per request. Interactive requests (short
    ``gen_tokens``) carry ``interactive_priority``.
    """
    rng = np.random.default_rng(tcfg.seed)
    n_bursts = -(-tcfg.n_requests // max(1, tcfg.burst))
    gaps = rng.exponential(max(1, tcfg.burst) / tcfg.rate, size=n_bursts)
    burst_at = np.cumsum(gaps) - gaps[0]
    templates = rng.integers(0, tcfg.vocab_size,
                             (max(1, tcfg.n_templates), tcfg.template_len))
    ranks = np.arange(1, len(templates) + 1, dtype=np.float64)
    zipf = ranks ** -tcfg.zipf_a
    zipf /= zipf.sum()
    out: List[Tuple[float, Request]] = []
    for b in range(n_bursts):
        for _ in range(max(1, tcfg.burst)):
            if len(out) >= tcfg.n_requests:
                break
            t_idx = rng.choice(len(templates), p=zipf)
            prompt = templates[t_idx]
            if rng.random() >= tcfg.exact_repeat_frac and tcfg.suffix_len:
                suffix = rng.integers(0, tcfg.vocab_size, tcfg.suffix_len)
                prompt = np.concatenate([prompt, suffix])
            interactive = rng.random() < tcfg.interactive_frac
            gen = (tcfg.gen_tokens if interactive
                   else pick_from(rng, tcfg.gen_tokens_choices,
                                  tcfg.gen_tokens))
            out.append((float(burst_at[b]), Request(
                prompt=np.asarray(prompt, np.int32), max_new_tokens=gen,
                eos_id=tcfg.eos_id,
                priority=(tcfg.interactive_priority if interactive else 0),
                deadline_s=(tcfg.interactive_deadline_s
                            if interactive else None))))
    return out


def make_heavy_tail_mix(tcfg: TrafficConfig,
                        ) -> List[Tuple[float, Request]]:
    """Adversarial shape #2: heavy-tailed prompt/gen lengths + SLA mix.

    ``interactive_frac`` of requests are short interactive probes
    (scalar ``prompt_len`` / ``gen_tokens``, ``interactive_priority``);
    the rest are batch jobs whose prompt and budget draw a discrete
    Pareto(``tail_alpha``) between the scalar and the longest configured
    choice — the occasional whale that clogs a FIFO pool for everyone.
    ``prompt_len_choices`` / ``gen_tokens_choices`` (required) bound the
    lengths so every request still fits the engine's ``max_len``.
    """
    assert tcfg.prompt_len_choices and tcfg.gen_tokens_choices, (
        "heavy-tail mix needs prompt_len_choices / gen_tokens_choices "
        "as the length buckets (and prefill-jit shapes) it draws from")
    rng = np.random.default_rng(tcfg.seed)
    gaps = rng.exponential(1.0 / tcfg.rate, size=tcfg.n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]

    def tail_pick(choices) -> int:
        # Pareto-weighted choice over the sorted buckets: index grows
        # like a heavy tail, snapped to a configured (pre-warmed) bucket.
        cs = sorted(int(c) for c in choices)
        u = float(rng.pareto(tcfg.tail_alpha))
        idx = min(int(u), len(cs) - 1)
        return cs[idx]

    out: List[Tuple[float, Request]] = []
    for t in arrivals:
        interactive = rng.random() < tcfg.interactive_frac
        if interactive:
            pl, gen, pri = (tcfg.prompt_len, tcfg.gen_tokens,
                            tcfg.interactive_priority)
        else:
            pl, gen, pri = (tail_pick(tcfg.prompt_len_choices),
                            tail_pick(tcfg.gen_tokens_choices), 0)
        prompt = rng.integers(0, tcfg.vocab_size, pl)
        out.append((float(t), Request(
            prompt=np.asarray(prompt, np.int32), max_new_tokens=gen,
            eos_id=tcfg.eos_id, priority=pri,
            deadline_s=(tcfg.interactive_deadline_s
                        if interactive else None))))
    return out


def pick_from(rng, choices, default) -> int:
    if choices is None:
        return int(default)
    return int(choices[rng.integers(0, len(choices))])


def drive(engine: Engine, workload: Sequence[Tuple[float, Request]],
          time_scale: float = 1.0) -> dict:
    """Run the workload against the engine, open-loop.

    ``time_scale`` compresses the arrival schedule (0.5 = twice the offered
    rate) without regenerating the workload. Returns throughput and latency
    percentiles; handles stay on ``engine.completed`` for deeper digging.
    """
    handles: List[ResultStream] = []
    due: List[float] = []            # absolute scheduled arrival times
    t0 = time.perf_counter()
    i = 0
    while i < len(workload) or engine.num_pending or engine.num_active:
        now = time.perf_counter() - t0
        while i < len(workload) and workload[i][0] * time_scale <= now:
            handles.append(engine.submit(workload[i][1]))
            due.append(t0 + workload[i][0] * time_scale)
            i += 1
        if not engine.step() and i < len(workload):
            # Idle engine waiting on the next arrival: sleep to it.
            next_due = workload[i][0] * time_scale
            wait = next_due - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 0.01))
    elapsed = time.perf_counter() - t0

    # Latency is measured from the *scheduled* arrival, not the actual
    # submit call: arrivals falling due while the engine is inside a step
    # are submitted late, and that wait is queueing delay the tail must
    # show, not timing noise to exclude.
    lat = np.asarray([h.finished_at - d for h, d in zip(handles, due)])
    tokens = sum(len(h.tokens) for h in handles)
    out = {
        "n_requests": len(handles),
        "elapsed_s": elapsed,
        "throughput_rps": len(handles) / elapsed,
        "throughput_tok_s": tokens / elapsed,
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(lat, 99) * 1e3),
        "latency_mean_ms": float(lat.mean() * 1e3),
    }
    # Per-SLA-class tails: the whole point of priority scheduling is the
    # interactive class's p99, so it is reported per class, always (a
    # single class shows up as one entry keyed by its priority).
    by_class: dict = {}
    for h, d in zip(handles, due):
        by_class.setdefault(h.priority, []).append(h.finished_at - d)
    out["per_class"] = {
        int(pri): {
            "n": len(ls),
            "latency_p50_ms": float(np.percentile(ls, 50) * 1e3),
            "latency_p99_ms": float(np.percentile(ls, 99) * 1e3),
            "latency_mean_ms": float(np.mean(ls) * 1e3),
        } for pri, ls in sorted(by_class.items())}
    # Raw per-request latencies in submission order: benchmarks comparing
    # an SLA run against a priority-stripped FIFO baseline need to regroup
    # the FIFO latencies by the *original* class of each request.
    out["per_request_latency_s"] = [float(x) for x in lat]
    return out
