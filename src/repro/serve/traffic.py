"""Synthetic traffic driver: Poisson arrivals against a live engine.

Models the ROADMAP's "heavy traffic from millions of users" shape at bench
scale: requests arrive as a Poisson process (exponential inter-arrival
gaps at ``rate`` req/s), prompts are random token strings — a fixed
length by default (one length bucket, one prefill jit entry), or mixed
lengths via ``prompt_len_choices``/``gen_tokens_choices`` (the skewed
shape the paged KV pool packs; each distinct length adds a prefill
bucket, so warm them via ``Engine.warm_prefill_buckets`` before timing) —
and a configurable fraction of requests reuse a small set of shared
prompts — the repeated-prefix workload the candidate cache exists for
(shared system prompts / common query heads in production).

The driver is open-loop: a request is submitted the moment its arrival
time passes on the wall clock, regardless of engine backlog, so a slow
serving path shows up as queueing delay in the latency tail rather than
as reduced offered load. ``drive`` pumps ``Engine.step`` until all
requests complete and reports request throughput plus p50/p99 end-to-end
latency (submit → last token).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import Engine, Request, ResultStream


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 32
    rate: float = 50.0            # offered load, requests/second
    prompt_len: int = 8
    gen_tokens: int = 8           # max_new_tokens per request
    # Mixed-length traffic (the skewed shape the paged KV pool exists
    # for): when set, each non-shared request draws its prompt length /
    # token budget uniformly from these choices instead of the scalars
    # above. Shared prompts keep the scalar prompt_len so repeats stay
    # exact repeats.
    prompt_len_choices: Optional[Tuple[int, ...]] = None
    gen_tokens_choices: Optional[Tuple[int, ...]] = None
    vocab_size: int = 1024
    repeat_frac: float = 0.0      # fraction drawing from shared prompts
    n_shared_prompts: int = 1
    eos_id: Optional[int] = None
    seed: int = 0


def make_workload(tcfg: TrafficConfig) -> List[Tuple[float, Request]]:
    """Returns [(arrival_offset_seconds, Request)] sorted by arrival."""
    rng = np.random.default_rng(tcfg.seed)
    gaps = rng.exponential(1.0 / tcfg.rate, size=tcfg.n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]         # first request at t=0
    shared = rng.integers(0, tcfg.vocab_size,
                          (max(1, tcfg.n_shared_prompts), tcfg.prompt_len))

    def pick(choices, default):
        if choices is None:
            return default
        return int(choices[rng.integers(0, len(choices))])

    out = []
    for t in arrivals:
        gen = pick(tcfg.gen_tokens_choices, tcfg.gen_tokens)
        if rng.random() < tcfg.repeat_frac:
            prompt = shared[rng.integers(0, len(shared))]
        else:
            pl = pick(tcfg.prompt_len_choices, tcfg.prompt_len)
            prompt = rng.integers(0, tcfg.vocab_size, pl)
        out.append((float(t), Request(prompt=np.asarray(prompt, np.int32),
                                      max_new_tokens=gen,
                                      eos_id=tcfg.eos_id)))
    return out


def drive(engine: Engine, workload: Sequence[Tuple[float, Request]],
          time_scale: float = 1.0) -> dict:
    """Run the workload against the engine, open-loop.

    ``time_scale`` compresses the arrival schedule (0.5 = twice the offered
    rate) without regenerating the workload. Returns throughput and latency
    percentiles; handles stay on ``engine.completed`` for deeper digging.
    """
    handles: List[ResultStream] = []
    due: List[float] = []            # absolute scheduled arrival times
    t0 = time.perf_counter()
    i = 0
    while i < len(workload) or engine.num_pending or engine.num_active:
        now = time.perf_counter() - t0
        while i < len(workload) and workload[i][0] * time_scale <= now:
            handles.append(engine.submit(workload[i][1]))
            due.append(t0 + workload[i][0] * time_scale)
            i += 1
        if not engine.step() and i < len(workload):
            # Idle engine waiting on the next arrival: sleep to it.
            next_due = workload[i][0] * time_scale
            wait = next_due - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 0.01))
    elapsed = time.perf_counter() - t0

    # Latency is measured from the *scheduled* arrival, not the actual
    # submit call: arrivals falling due while the engine is inside a step
    # are submitted late, and that wait is queueing delay the tail must
    # show, not timing noise to exclude.
    lat = np.asarray([h.finished_at - d for h, d in zip(handles, due)])
    tokens = sum(len(h.tokens) for h in handles)
    return {
        "n_requests": len(handles),
        "elapsed_s": elapsed,
        "throughput_rps": len(handles) / elapsed,
        "throughput_tok_s": tokens / elapsed,
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(lat, 99) * 1e3),
        "latency_mean_ms": float(lat.mean() * 1e3),
    }
