"""Model zoo: composable decoder blocks for all assigned arch families."""
from repro.models.config import ModelConfig
from repro.models.transformer import (forward, init_cache, init_params)

__all__ = ["ModelConfig", "forward", "init_cache", "init_params"]
