"""LM output head: the paper's adversarial softmax approximation wired into
the decoder, plus all baseline heads, with vocab padding + gemma2 softcap.

The generator feature x_gen (paper: PCA of the input) is a fixed linear
projection of the (stop-gradient) final hidden state — `LMHeadState.proj` —
refreshed together with the tree (DESIGN.md §2). Padded vocab rows are masked
out of full-logit paths; the samplers only ever draw real labels.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import heads as heads_lib
from repro.core import tree as tree_lib
from repro.core.heads import Generator, HeadConfig, HeadParams
from repro.models.config import ModelConfig


class LMHeadState(NamedTuple):
    """Non-trainable head state (generator + feature projection)."""
    gen: Generator
    proj: Optional[jax.Array] = None    # (d_model, k)


def default_head_state(rng, cfg: ModelConfig, kind: str) -> LMHeadState:
    """Head state before any generator fitting: random tree / uniform freq.
    Real runs refresh this via repro.train.generator_fit."""
    k1, k2 = jax.random.split(rng)
    proj = jax.random.normal(k1, (cfg.d_model, cfg.gen_feature_dim),
                             jnp.float32) / jnp.sqrt(cfg.d_model)
    gen = Generator()
    if kind in ("adversarial_ns", "nce", "sampled_softmax"):
        gen = Generator(tree=tree_lib.init_tree(
            k2, cfg.vocab_size, cfg.gen_feature_dim, scale=0.05))
    elif kind == "freq_ns":
        gen = heads_lib.make_freq_generator(
            jnp.ones((cfg.vocab_size,), jnp.float32))
    return LMHeadState(gen=gen, proj=proj)


def head_config(cfg: ModelConfig, kind: str, n_neg: int = 1,
                reg: float = 0.0) -> HeadConfig:
    return HeadConfig(num_labels=cfg.vocab_size, kind=kind, n_neg=n_neg,
                      reg=reg)


def gen_features(state: LMHeadState, h: jax.Array) -> jax.Array:
    """x_gen = stop_grad(h) @ proj — the O(d·k) generator feature."""
    h = jax.lax.stop_gradient(h).astype(jnp.float32)
    return h @ state.proj


def _softcap_score_fn(cap: float, base=heads_lib.candidate_scores):
    def fn(params: HeadParams, h, ids):
        s = base(params, h, ids)
        return cap * jnp.tanh(s / cap) if cap else s
    return fn


def serving_score_fn(cfg: ModelConfig, use_kernel: bool = False,
                     mesh=None) -> heads_lib.ScoreFn:
    """Candidate scorer for the serving paths, final softcap included.

    Selection (one place, shared by ``make_serve_step`` and the serve
    engine so the two stay byte-identical): ``mesh`` → vocab-sharded
    ``sharded_candidate_scores`` (each model shard scores only its rows,
    one psum of the tiny score tensor); ``use_kernel`` → the gather_scores
    Pallas kernel; else the plain O(beam·K) gather-and-dot.
    """
    if mesh is not None:
        from repro.parallel.collectives import sharded_candidate_scores

        def base(p: HeadParams, hh, ids):
            return sharded_candidate_scores(mesh, p.w, p.b, hh, ids)
    elif use_kernel:
        base = heads_lib.kernel_score_fn()
    else:
        base = heads_lib.candidate_scores
    if cfg.final_logit_softcap:
        return _softcap_score_fn(cfg.final_logit_softcap, base)
    return base


def masked_full_logits(cfg: ModelConfig, params: HeadParams, h: jax.Array
                       ) -> jax.Array:
    """(…, V_pad) logits with padded rows masked and final softcap applied."""
    logits = heads_lib.full_logits(params, h)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(
            logits / cfg.final_logit_softcap)
    pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(pad_mask, logits, -1e30)


def lm_head_loss(cfg: ModelConfig, hcfg: HeadConfig, params: HeadParams,
                 state: LMHeadState, h: jax.Array, labels: jax.Array,
                 rng: jax.Array, mask: Optional[jax.Array] = None,
                 score_fn=None, sampler=None):
    """Next-token loss on final hiddens h (…, d) and labels (…,).

    Dispatches to the configured head strategy; `softmax` uses the padded/
    softcapped full-logit path (the O(K·C) baseline the paper replaces).
    ``sampler`` overrides the negative-sampling proposal (a
    ``repro.core.samplers.NegativeSampler``); default derives it from
    ``hcfg.kind`` + the generator state.
    """
    x_gen = gen_features(state, h)
    if hcfg.kind == "softmax":
        logits = masked_full_logits(cfg, params, h)
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        pos = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                                  axis=-1)[..., 0]
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = jnp.sum((logz - pos) * mask) / denom
        return loss, {"pos_score": jnp.sum(pos * mask) / denom}
    if score_fn is None:
        score_fn = (_softcap_score_fn(cfg.final_logit_softcap)
                    if cfg.final_logit_softcap
                    else heads_lib.candidate_scores)
    return heads_lib.head_loss(hcfg, params, state.gen, h, x_gen, labels,
                               rng, score_fn=score_fn, mask=mask,
                               sampler=sampler)


def lm_sparse_head_loss(cfg: ModelConfig, hcfg: HeadConfig,
                        params: HeadParams, state: LMHeadState,
                        h: jax.Array, labels: jax.Array, rng: jax.Array,
                        mask: Optional[jax.Array] = None,
                        use_kernel: bool = False, sampler=None):
    """Sampled-head loss with O(B·K·n_neg) analytic gradients (DESIGN.md
    §8): same loss/metrics stream as :func:`lm_head_loss` (softcap folded
    into the coefficients), plus the deduped ``SparseRows`` head gradient
    and the trunk cotangent ``dh``. Returns (loss, metrics, sparse, dh)."""
    x_gen = gen_features(state, h)
    return heads_lib.sparse_head_loss(
        hcfg, params, state.gen, h, x_gen, labels.astype(jnp.int32), rng,
        mask=mask, softcap=cfg.final_logit_softcap, use_kernel=use_kernel,
        sampler=sampler)


def lm_predictive_topk(cfg: ModelConfig, hcfg: HeadConfig,
                       params: HeadParams, state: LMHeadState, h: jax.Array,
                       topk: int, beam: Optional[int] = None,
                       use_kernel: bool = False,
                       score_fn: Optional[heads_lib.ScoreFn] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Top-``topk`` debiased (scores, labels) without the O(C) logits matmul.

    Adversarial head: beam search over the generator tree proposes ``beam``
    candidates, only those are scored (softcap applied per candidate, padded
    vocab rows unreachable since candidates are real labels), Eq. 5 debias
    on the candidate set. ``use_kernel`` routes candidate scoring through
    the gather_scores Pallas kernel; ``score_fn`` overrides the scorer
    entirely and is used as-is (build one with :func:`serving_score_fn`,
    which bakes in the softcap). Other heads fall back to the dense
    path + top_k.
    """
    if hcfg.kind == "adversarial_ns" and state.gen.tree is not None:
        x_gen = gen_features(state, h)
        if score_fn is None:
            score_fn = serving_score_fn(cfg, use_kernel=use_kernel)
        return heads_lib.predictive_topk(hcfg, params, state.gen, h, x_gen,
                                         topk, beam=beam, score_fn=score_fn)
    scores = lm_predictive_scores(cfg, hcfg, params, state, h)
    top, labels = jax.lax.top_k(scores, topk)
    return top, labels.astype(jnp.int32)


def lm_predictive_scores(cfg: ModelConfig, hcfg: HeadConfig,
                         params: HeadParams, state: LMHeadState,
                         h: jax.Array) -> jax.Array:
    """Full-vocab scores with Eq. 5 bias removal (adversarial head)."""
    scores = masked_full_logits(cfg, params, h)
    if not hcfg.debias:
        return scores
    if hcfg.kind == "adversarial_ns" and state.gen.tree is not None:
        x_gen = gen_features(state, h)
        log_pn = tree_lib.log_prob_all(state.gen.tree, x_gen)
        zeros = jnp.zeros(scores.shape[:-1] + (cfg.padded_vocab
                                               - cfg.vocab_size,))
        return scores + jnp.concatenate([log_pn, zeros], axis=-1)
    if hcfg.kind == "freq_ns":
        corr = jnp.zeros((cfg.padded_vocab,)).at[:cfg.vocab_size].set(
            state.gen.freq_log)
        return scores + corr
    return scores
