"""Model configuration covering all assigned architecture families.

One frozen dataclass describes dense GQA transformers, SWA / local-global
alternation (gemma2, mixtral, h2o-danube), logit softcaps (gemma2), M-RoPE
(qwen2-vl), MoE with shared + fine-grained routed experts (deepseek-moe,
mixtral), Mamba-2 SSD blocks (mamba2), and parallel attn∥SSM hybrid blocks
(hymba). Per-layer heterogeneity (e.g. alternating window sizes) is expressed
as arrays scanned alongside the stacked layer parameters so the whole stack
stays a single `lax.scan`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

BLOCK_KINDS = ("attn", "ssm", "hybrid")
MOE_SHARDINGS = ("expert", "ffn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    num_heads: int = 0             # query heads (0 for pure-SSM archs)
    num_kv_heads: int = 0
    head_dim: int = 0              # 0 => d_model // num_heads
    window_size: int = 0           # sliding-window width; 0 = full attention
    window_pattern: int = 1        # every `p`-th layer is full attention
                                   # (1 = all layers use `window_size`;
                                   #  2 = gemma2-style local/global alternate)
    attn_logit_softcap: float = 0.0     # gemma2: 50.0
    final_logit_softcap: float = 0.0    # gemma2: 30.0
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE dims per section
    # --- block selection ---
    block: str = "attn"            # attn | ssm | hybrid
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv_width: int = 4
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_norm: str = "softmax_topk"   # deepseek | "topk_softmax" (mixtral)
    # --- embeddings / head ---
    vocab_pad_multiple: int = 512
    gen_feature_dim: int = 32      # k: generator-tree feature dim (paper §3)
    # --- modality frontend (stub per task statement) ---
    modality: str = "text"         # text | audio | vision
    num_vision_tokens: int = 0     # vision: prefix of precomputed embeddings
    # --- numerics / memory ---
    dtype: str = "bfloat16"
    softmax_dtype: str = "float32"   # attention logits/softmax precision;
                                     # bf16 halves the S^2 byte traffic and
                                     # is defensible under a logit softcap
                                     # (§Perf C2)
    remat: bool = True
    scan_layers: bool = True

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.block in BLOCK_KINDS, self.block
        if self.block in ("attn", "hybrid"):
            assert self.num_heads > 0 and self.num_kv_heads > 0
            assert self.num_heads % self.num_kv_heads == 0
        if self.block in ("ssm", "hybrid"):
            assert self.ssm_state > 0

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def window_for_layer(self, layer: int) -> int:
        """Per-layer sliding window (0 = full). gemma2: even layers local."""
        if self.window_size == 0:
            return 0
        if self.window_pattern <= 1:
            return self.window_size
        return self.window_size if layer % self.window_pattern != \
            (self.window_pattern - 1) else 0

    def layer_windows(self):
        return [self.window_for_layer(i) for i in range(self.num_layers)]

    def param_count(self) -> int:
        """Approximate trainable parameter count (for 6·N·D roofline)."""
        d, v = self.d_model, self.padded_vocab
        n = 2 * v * d                      # in-embed + head (untied)
        hd = self.resolved_head_dim
        per_layer = 0
        if self.block in ("attn", "hybrid"):
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            per_layer += q + kv + o
        if self.block in ("ssm", "hybrid"):
            di, ns = self.ssm_inner, self.ssm_state
            heads = self.ssm_heads
            conv_dim = di + 2 * ns
            per_layer += d * (2 * di + 2 * ns + heads)   # in_proj
            per_layer += conv_dim * self.ssm_conv_width  # conv1d
            per_layer += di * d                          # out_proj
            per_layer += 2 * heads + di                  # A, D, norm
        if self.is_moe:
            per_layer += d * self.n_experts              # router
            per_layer += 3 * d * self.d_ff * (self.n_experts
                                              + self.n_shared_experts)
        else:
            per_layer += 3 * d * self.d_ff               # SwiGLU
        per_layer += 2 * d                               # 2 RMSNorms
        return n + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        inactive = 3 * d * self.d_ff * (self.n_experts - self.top_k)
        return self.param_count() - self.num_layers * inactive
