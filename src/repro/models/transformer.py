"""Decoder-only LM assembly: scan-over-layers, embeddings, caches.

One scanned block body serves all four families (attn / ssm / hybrid / moe):
per-layer heterogeneity (gemma2 local↔global windows) rides along as scanned
arrays, so the HLO stays O(1) in depth — essential for the 70-cell dry-run
and for remat-policy control at scale.

Modes:
  forward(..., cache=None)        — training / teacher forcing
  forward(..., cache, cache_pos)  — serving prefill (writes cache) and
                                    single-token decode (S == 1)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_layer(rng, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(rng, 4)
    p: Dict[str, Any] = {
        "norm_mix": L.init_rmsnorm(cfg.d_model),
        "norm_ffn": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.block in ("attn", "hybrid"):
        p["attn"] = L.init_attention(ks[0], cfg)
    if cfg.block in ("ssm", "hybrid"):
        p["ssm"] = ssm_lib.init_ssm_block(ks[1], cfg)
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(ks[2], cfg)
    elif cfg.d_ff > 0:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    else:
        del p["norm_ffn"]     # mamba2: the SSM block is the whole layer
    return p


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k_e, k_l, k_h = jax.random.split(rng, 3)
    vp, d = cfg.padded_vocab, cfg.d_model
    layer_keys = jax.random.split(k_l, cfg.num_layers)
    if cfg.scan_layers:
        layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    else:
        layers = [init_layer(k, cfg) for k in layer_keys]
    params = {
        "embed": L.normal_init(k_e, (vp, d), d),
        "layers": layers,
        "final_norm": L.init_rmsnorm(d),
        "head": {
            "w": L.normal_init(k_h, (vp, d), d),
            "b": jnp.zeros((vp,), jnp.float32),
        },
    }
    return params


# ---------------------------------------------------------------------------
# Caches (stacked over layers for scan)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Decode cache pytree, leaves stacked on a leading layer dim."""
    ell = cfg.num_layers
    cache: Dict[str, Any] = {}
    if cfg.block in ("attn", "hybrid"):
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((ell, batch, max_len, kv, hd), dtype)
        cache["v"] = jnp.zeros((ell, batch, max_len, kv, hd), dtype)
    if cfg.block in ("ssm", "hybrid"):
        conv, state = ssm_lib.init_ssm_cache(cfg, batch)
        cache["conv"] = jnp.tile(conv[None], (ell,) + (1,) * conv.ndim)
        cache["state"] = jnp.tile(state[None], (ell,) + (1,) * state.ndim)
    return cache


def init_paged_cache(cfg: ModelConfig, n_lanes: int, n_pages: int,
                     page_len: int, dtype=jnp.bfloat16):
    """Paged decode cache: attention K/V live in a shared page arena
    (``n_pages`` physical pages of ``page_len`` positions each, including
    the allocator's sink page) addressed through per-lane page tables, not
    in per-row ``max_len`` buffers. SSM conv/state have no sequence dim to
    page, so they stay lane-indexed (``n_lanes`` rows) as before."""
    ell = cfg.num_layers
    cache: Dict[str, Any] = {}
    if cfg.block in ("attn", "hybrid"):
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((ell, n_pages, page_len, kv, hd), dtype)
        cache["v"] = jnp.zeros((ell, n_pages, page_len, kv, hd), dtype)
    if cfg.block in ("ssm", "hybrid"):
        conv, state = ssm_lib.init_ssm_cache(cfg, n_lanes)
        cache["conv"] = jnp.tile(conv[None], (ell,) + (1,) * conv.ndim)
        cache["state"] = jnp.tile(state[None], (ell,) + (1,) * state.ndim)
    return cache


# ---------------------------------------------------------------------------
# Block body
# ---------------------------------------------------------------------------

def _block(layer_params, cfg: ModelConfig, h, positions, window,
           cache_l, cache_pos, decode: bool, attn_mask=None,
           page_table=None, write_mask=None):
    """One decoder block. Returns (h, new_cache_l, metrics)."""
    from repro.parallel.hints import hint_residual
    h = hint_residual(h)   # seq-parallel residual (no-op unless hinted)
    metrics = {}
    mix_in = L.rmsnorm(layer_params["norm_mix"], h)
    new_cache: Dict[str, Any] = {}
    mix_out = 0.0
    n_branches = 0
    if cfg.block in ("attn", "hybrid"):
        kvc = (cache_l["k"], cache_l["v"]) if cache_l is not None else None
        a_out, a_cache = L.attention(layer_params["attn"], cfg, mix_in,
                                     positions, window, kv_cache=kvc,
                                     cache_pos=cache_pos, mask=attn_mask,
                                     page_table=page_table,
                                     write_mask=write_mask)
        if cache_l is not None:
            new_cache["k"], new_cache["v"] = a_cache
        mix_out = mix_out + a_out
        n_branches += 1
    if cfg.block in ("ssm", "hybrid"):
        sc = ((cache_l["conv"], cache_l["state"])
              if cache_l is not None else None)
        s_out, s_cache = ssm_lib.ssm_block(layer_params["ssm"], cfg, mix_in,
                                           cache=sc, decode=decode)
        if cache_l is not None:
            new_cache["conv"], new_cache["state"] = s_cache
        mix_out = mix_out + s_out
        n_branches += 1
    # hymba: mean of parallel heads. Cast keeps the scan carry dtype stable
    # regardless of cache dtype promotion.
    h = h + (mix_out / float(n_branches)).astype(h.dtype)

    if cfg.is_moe or cfg.d_ff > 0:
        ffn_in = L.rmsnorm(layer_params["norm_ffn"], h)
        if cfg.is_moe:
            f_out, moe_metrics = moe_lib.moe_ffn(layer_params["moe"], cfg,
                                                 ffn_in)
            metrics.update(moe_metrics)
        else:
            f_out = L.mlp(layer_params["mlp"], ffn_in, jnp.dtype(cfg.dtype))
        h = h + f_out.astype(h.dtype)
    return h, new_cache, metrics


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, tokens: jax.Array,
                 vision_embeds: Optional[jax.Array] = None) -> jax.Array:
    """Token embedding; vision/audio frontends prepend precomputed embeddings
    (modality stub per the task statement)."""
    cdt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if vision_embeds is not None:
        h = jnp.concatenate([vision_embeds.astype(cdt), h], axis=1)
    return h


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            vision_embeds: Optional[jax.Array] = None,
            cache=None, cache_pos: Optional[jax.Array] = None,
            page_table: Optional[jax.Array] = None,
            write_mask: Optional[jax.Array] = None,
            inputs_embeds: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, Any, Dict[str, jax.Array]]:
    """Run the stack. Returns (hidden (B,S,d), new_cache, metrics).

    - training:        cache=None
    - serving prefill: cache=init_cache(...), cache_pos=0, S=prompt len
    - serving decode:  cache from prefill, cache_pos=current, S=1;
      cache_pos may be a (B,) vector for slotted decode (repro.serve),
      writing each row's KV at its own depth
    - paged decode:    cache=init_paged_cache(...), cache_pos (B,) vector,
      page_table (B, max_pages) mapping each lane's logical pages onto the
      shared arena (repro.serve.PagedPool); the page table is shared by
      every layer. S may exceed 1 (shared-prefix suffix prefill and the
      speculative verify step, repro.serve): row r's tokens occupy logical
      positions cache_pos[r] .. cache_pos[r]+S-1, and ``write_mask``
      (B, S) reroutes padding positions' K/V writes to the sink page

    ``inputs_embeds`` bypasses the embedding gather entirely: the caller
    supplies the (B, S, d) hidden input (already cast, vision embeds
    already concatenated). This is the sparse-embedding training path
    (DESIGN.md §11): the gather runs *outside* the trunk vjp so its
    cotangent can be collected as SparseRows instead of a dense (V, d)
    scatter-add.
    """
    h = (inputs_embeds if inputs_embeds is not None
         else embed_inputs(params, cfg, tokens, vision_embeds))
    bsz, s, _ = h.shape
    auto_positions = positions is None
    if positions is None:
        # cache_pos may be a scalar (lock-step decode / prefill) or a (B,)
        # vector (slotted decode: each row at its own depth).
        base = jnp.asarray(0 if cache_pos is None else cache_pos, jnp.int32)
        positions = base[..., None] + jnp.arange(s, dtype=jnp.int32)
        if positions.ndim == 1:
            positions = positions[None]
        positions = jnp.broadcast_to(positions, (bsz, s))
    decode = cache is not None and s == 1
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)       # (L,)

    # Hoist the training-mode attention mask out of the layer scan: one
    # (2, S, S) constant (full-causal / windowed) instead of per-layer
    # (B, S, S) index arithmetic (§Perf C1). Only valid when positions are
    # the default arange and there is no cache (pure self-attention).
    masks = None
    if auto_positions and cache is None and cfg.block in ("attn", "hybrid"):
        masks = jnp.stack([
            L.causal_window_mask(s, 0),
            L.causal_window_mask(s, cfg.window_size or 0)])

    body = functools.partial(_block, cfg=cfg, positions=positions,
                             cache_pos=cache_pos, decode=decode,
                             page_table=page_table, write_mask=write_mask)

    if cfg.scan_layers:
        def scan_body(carry, xs):
            lp, window, cache_l = xs
            attn_mask = (None if masks is None
                         else jnp.where(window > 0, masks[1], masks[0]))
            new_h, new_cache_l, metrics = body(lp, h=carry, window=window,
                                               cache_l=cache_l,
                                               attn_mask=attn_mask)
            return new_h, (new_cache_l, metrics)

        if cfg.remat:
            scan_body = jax.checkpoint(
                scan_body,
                policy=jax.checkpoint_policies.nothing_saveable)
        h, (new_cache, metrics) = jax.lax.scan(
            scan_body, h, (params["layers"], windows, cache))
        metrics = jax.tree.map(jnp.mean, metrics)
    else:
        new_cache_layers, metrics = [], {}
        for i in range(cfg.num_layers):
            cache_l = (None if cache is None
                       else jax.tree.map(lambda c: c[i], cache))
            attn_mask = (None if masks is None else
                         masks[1 if cfg.window_for_layer(i) > 0 else 0])
            h, nc, metrics = body(params["layers"][i], h=h,
                                  window=windows[i], cache_l=cache_l,
                                  attn_mask=attn_mask)
            new_cache_layers.append(nc)
        new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *new_cache_layers)
                     if cache is not None else None)

    h = L.rmsnorm(params["final_norm"], h)
    return h, new_cache, metrics
