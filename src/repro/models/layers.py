"""Core layers: RMSNorm, (M-)RoPE, GQA attention (SWA / softcap), SwiGLU.

Pure-jax parameter-dict style (no flax): each layer is an ``init_*`` returning
a param pytree plus an ``apply``-style function. All attention math runs in
fp32; bulk matmuls honour ``cfg.dtype``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def normal_init(rng, shape, fan_in, dtype=jnp.float32):
    return (jax.random.normal(rng, shape) / jnp.sqrt(float(max(fan_in, 1)))
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}    # (1 + scale) * x-hat


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE sections, qwen2-vl style)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sections: Tuple[int, ...] = ()) -> jax.Array:
    """x: (B, S, H, hd). positions: (B, S) or (n_sections, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the rotary half-dims are split into `sections`
    (temporal/height/width), each rotated by its own position stream. With
    identical streams this reduces exactly to standard RoPE.
    """
    b, s, h, hd = x.shape
    half = hd // 2
    inv_freq = rope_frequencies(hd, theta)                    # (half,)
    if positions.ndim == 2:
        pos = positions[None]                                 # (1, B, S)
        sections = (half,)
    else:
        pos = positions
        if not sections:
            sections = (half,)
    assert sum(sections) == half, (sections, half)
    # Build per-dim position source by section.
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=half)
    pos_per_dim = pos[sec_id, :, :]                           # (half, B, S)
    angles = jnp.einsum("fbs,f->bsf", pos_per_dim.astype(jnp.float32),
                        inv_freq)                             # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]                      # (B,S,1,half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig):
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(rng, 4)
    return {
        "wq": normal_init(ks[0], (d, h, hd), d),
        "wk": normal_init(ks[1], (d, kv, hd), d),
        "wv": normal_init(ks[2], (d, kv, hd), d),
        "wo": normal_init(ks[3], (h, hd, d), h * hd),
    }


def _softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


def _expand_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd) via broadcast (GQA)."""
    b, s, kv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd))
    return k.reshape(b, s, kv * groups, hd)


def causal_window_mask(seq_len: int, window: int) -> jax.Array:
    """(S, S) bool validity mask: causal, optionally sliding-window."""
    pos = jnp.arange(seq_len)
    delta = pos[:, None] - pos[None, :]
    valid = delta >= 0
    if window > 0:
        valid &= delta < window
    return valid


def attention(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
              window: jax.Array,
              kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_pos: Optional[jax.Array] = None,
              mask: Optional[jax.Array] = None,
              page_table: Optional[jax.Array] = None,
              write_mask: Optional[jax.Array] = None):
    """GQA attention with causal + per-layer sliding-window mask + softcap.

    Training/prefill: ``kv_cache is None`` → self-attention over x and the
    freshly written cache (k, v) is returned for serving prefill.
    Decode: ``kv_cache=(k, v)`` of shape (B, S_max, KV, hd), ``cache_pos``
    scalar index of the current token; x has S=1. ``cache_pos`` may also be
    a (B,) vector — one write position per row — which is the continuous-
    batching decode path (`repro.serve`): every KV slot sits at its own
    depth, so the write is a per-row scatter instead of one slice update.

    Paged decode: with ``page_table`` (B, max_pages) the cache leaves are a
    shared page *arena* (n_pages, page_len, KV, hd) instead of per-row
    buffers. Row b's logical position p lives at physical
    ``(page_table[b, p // page_len], p % page_len)``: the step scatter-writes
    the new token there and gathers the row's pages back into logical order
    for the softmax. Page tables hold only live mappings for positions the
    row has reached; unmapped entries point at the allocator's sink page,
    whose bytes are causally masked (delta >= 0 fails above ``cache_pos``)
    exactly like a previous occupant's stale rows in the contiguous layout.

    ``window`` is a traced int32 scalar (0 = full attention) so that
    heterogeneous layers (gemma2 local/global) share one scanned body.
    ``mask`` (..., Sq, Skv), if given, OVERRIDES the position-derived mask —
    the training path hoists one (S, S) mask out of the layer scan instead
    of materializing (B, S, S) index arithmetic per layer (§Perf C1).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = h // kv
    cdt = _dtype(cfg)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cdt))
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    # Perf hint (no-op without an installed hint context): sequence-shard q
    # over 'model' when the head count is not TP-divisible — otherwise the
    # whole attention replicates per model shard (EXPERIMENTS.md §Perf).
    from repro.parallel.hints import hint_attn_out, hint_attn_q
    q = hint_attn_q(q, h)

    if kv_cache is None:
        k_all, v_all = k, v
        k_pos = positions if positions.ndim == 2 else positions[0]
        q_pos = k_pos
        new_cache = (k, v)
    elif page_table is not None:
        # Paged decode / paged multi-token step: cache leaves are the shared
        # arena. Scatter each incoming token at its (page, offset), then
        # gather this row's pages back into logical order — positions are
        # identical to the contiguous layout, only the physical addressing
        # differs, so the softmax sees byte-identical inputs (the property
        # the geometry oracle pins). With s > 1 (shared-prefix suffix
        # prefill / speculative verify) row r's tokens land at logical
        # positions cache_pos[r] .. cache_pos[r]+s-1; ``write_mask``
        # (B, s) reroutes padding positions' writes to the sink page (the
        # LAST physical page by construction — PagedPool.sink == n_pages,
        # arena holds n_pages + 1). Reads are untouched by the mask: real
        # rows gather only their own mapped pages.
        ck, cv = kv_cache                       # (P, page_len, KV, hd)
        page_len = ck.shape[1]
        sink = ck.shape[0] - 1
        cp = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (b,))
        rows = jnp.arange(b)
        pos_w = cp[:, None] + jnp.arange(s, dtype=jnp.int32)     # (B, s)
        # Out-of-range logical pages (padded s past a row's reservation)
        # clamp in the gather below; their writes are masked to the sink.
        pid = page_table[rows[:, None], pos_w // page_len]       # (B, s)
        off = pos_w % page_len
        if write_mask is not None:
            pid = jnp.where(write_mask, pid, sink)
        # Distinct live rows own distinct pages (allocator invariant), so
        # the only duplicate scatter targets are free rows' sink writes —
        # garbage into the garbage page, in unspecified order.
        k_arena = ck.at[pid, off].set(k.astype(ck.dtype))
        v_arena = cv.at[pid, off].set(v.astype(cv.dtype))
        new_cache = (k_arena, v_arena)
        s_max = page_table.shape[1] * page_len
        k_all = k_arena[page_table].reshape(b, s_max, kv, hd)
        v_all = v_arena[page_table].reshape(b, s_max, kv, hd)
        k_pos = jnp.broadcast_to(jnp.arange(s_max)[None], (b, s_max))
        q_pos = positions if positions.ndim == 2 else positions[0]
    else:
        ck, cv = kv_cache
        cp = jnp.asarray(cache_pos)
        if cp.ndim:
            # Per-row write position (slotted decode). Rows past a slot's
            # position hold stale bytes from the previous occupant; the
            # causal mask (delta >= 0) keeps them out of the softmax.
            assert s == 1, "per-row cache_pos requires single-token decode"
            rows = jnp.arange(b)
            k_all = ck.at[rows, cp].set(k[:, 0].astype(ck.dtype))
            v_all = cv.at[rows, cp].set(v[:, 0].astype(cv.dtype))
        else:
            k_all = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), cache_pos, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), cache_pos, axis=1)
        s_max = ck.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(s_max)[None], (b, s_max))
        q_pos = positions if positions.ndim == 2 else positions[0]
        new_cache = (k_all, v_all)

    kx = _expand_kv(k_all, groups)
    vx = _expand_kv(v_all, groups)
    sm_dt = jnp.dtype(cfg.softmax_dtype)
    # Fold the 1/sqrt(hd) scale into q: one (B,S,H,hd) multiply instead of
    # an (B,H,Sq,Skv) one (§Perf C3).
    q = q * (1.0 / jnp.sqrt(float(hd))).astype(q.dtype)
    logits = jnp.einsum("bqhk,bshk->bhqs", q, kx,
                        preferred_element_type=sm_dt)
    logits = _softcap(logits, cfg.attn_logit_softcap)
    # Under a logit softcap the logits are bounded (|z| <= cap), so the
    # max-subtraction in softmax is unnecessary: exp(cap) is far from f32
    # overflow. Masked entries use -1e4 (exp -> 0 exactly, no overflow).
    # Saves the (B,H,Sq,Skv) max-reduce + subtract passes (§Perf C3).
    capped = cfg.attn_logit_softcap > 0.0
    neg_inf = jnp.asarray(-1e4 if capped else jnp.finfo(sm_dt).min / 2,
                          sm_dt)
    if mask is not None:
        # Precomputed (Sq, Skv) mask: batch- and head-free broadcast.
        logits = jnp.where(mask[None, None], logits, neg_inf)
    else:
        # causal + sliding-window: 0 <= q_pos - k_pos (< window if set)
        delta = q_pos[:, :, None] - k_pos[:, None, :]    # (B, q, kv_len)
        valid = delta >= 0
        valid = valid & jnp.where(window > 0, delta < window, True)
        logits = jnp.where(valid[:, None, :, :], logits, neg_inf)
    if capped:
        ex = jnp.exp(logits)
        probs = (ex / jnp.sum(ex, axis=-1, keepdims=True)).astype(cdt)
    else:
        probs = jax.nn.softmax(logits, axis=-1).astype(cdt)
    out = jnp.einsum("bhqs,bshk->bqhk", probs, vx)
    out = jnp.einsum("bqhk,hkd->bqd", out, params["wo"].astype(cdt))
    out = hint_attn_out(out)
    return out, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(rng, d: int, d_ff: int):
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": normal_init(ks[0], (d, d_ff), d),
        "w_up": normal_init(ks[1], (d, d_ff), d),
        "w_down": normal_init(ks[2], (d_ff, d), d_ff),
    }


def mlp(params, x: jax.Array, dtype) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      params["w_down"].astype(dtype))
