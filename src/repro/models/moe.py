"""Mixture-of-Experts FFN: shared + fine-grained routed experts.

Dispatch is **group-local capacity-gather** (GShard-style groups = batch
rows): routing, sort and capacity assignment happen independently per
sequence, so under pjit with batch sharded over the data axes every sort
and scatter is shard-local — no distributed sort. (The first version
sorted the *global* flattened token axis; XLA lowered that to a
distributed sort costing TiB/step of all-reduce + all-to-all on
mixtral-8x22b — see EXPERIMENTS.md §Perf iteration B1.)

The one-hot GShard dispatch einsum is avoided too: its (T, E, C_cap)
tensor is O(T²·cf) memory, while the gather path materializes only the
expanded tokens (E, C_cap, d) ≈ top_k·cf·T rows. Tokens beyond per-group
expert capacity are dropped (standard), counted in metrics.

Covers deepseek-moe (64 routed top-6 + 2 shared, softmax→topk) and mixtral
(8 routed top-2, topk→softmax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import normal_init


def init_moe(rng, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    params = {
        "router": normal_init(ks[0], (d, e), d),
        "w_gate": normal_init(ks[1], (e, d, f), d),
        "w_up": normal_init(ks[2], (e, d, f), d),
        "w_down": normal_init(ks[3], (e, f, d), f),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kg, ku, kd = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": normal_init(kg, (d, fs), d),
            "w_up": normal_init(ku, (d, fs), d),
            "w_down": normal_init(kd, (fs, d), fs),
        }
    return params


def _capacity(cfg: ModelConfig, group_tokens: int) -> int:
    cap = int(group_tokens * cfg.top_k * cfg.capacity_factor
              / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)   # round up to 8


def _route(cfg: ModelConfig, params, xt):
    """xt: (G, S, d) -> (gate_vals, top_idx) each (G, S, k), + raw logits."""
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if cfg.router_norm == "softmax_topk":          # deepseek-moe
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    else:                                          # mixtral: topk -> softmax
        top_logits, top_idx = jax.lax.top_k(logits, cfg.top_k)
        gate_vals = jax.nn.softmax(top_logits, axis=-1)
    return gate_vals, top_idx, logits


def moe_ffn(params, cfg: ModelConfig, x: jax.Array):
    """x: (B, S, d) -> (B, S, d), plus aux metrics dict.

    Groups = batch rows: all sorts/scatters are along the last axis of
    (B, ...) arrays, i.e. local to whichever shard owns the row.
    """
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cdt = jnp.dtype(cfg.dtype)
    cap = _capacity(cfg, s)

    gate_vals, top_idx, logits = _route(cfg, params, x)

    sk = s * k
    flat_expert = top_idx.reshape(bsz, sk)                  # (B, S*k)
    # token of assignment j is j // k:
    flat_token = jnp.broadcast_to(
        (jnp.arange(sk) // k)[None], (bsz, sk)).astype(jnp.int32)
    flat_gate = gate_vals.reshape(bsz, sk)

    order = jnp.argsort(flat_expert, axis=-1)               # per-row sort
    se = jnp.take_along_axis(flat_expert, order, -1)
    stok = jnp.take_along_axis(flat_token, order, -1)
    sgate = jnp.take_along_axis(flat_gate, order, -1)
    # Position of each assignment within its expert (per row).
    start = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    pos_in_e = jnp.arange(sk)[None] - jnp.take_along_axis(start, se, -1)
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)    # overflow slot

    rows = jnp.arange(bsz)[:, None]
    slot_token = jnp.zeros((bsz, e * cap + 1), jnp.int32).at[
        rows, slot].set(stok, mode="drop")
    slot_gate = jnp.zeros((bsz, e * cap + 1), jnp.float32).at[
        rows, slot].set(jnp.where(keep, sgate, 0.0), mode="drop")
    slot_token = slot_token[:, :-1].reshape(bsz, e, cap)
    slot_gate = slot_gate[:, :-1].reshape(bsz, e, cap)

    xe = jnp.take_along_axis(
        x, slot_token.reshape(bsz, e * cap)[..., None], axis=1)
    xe = xe.reshape(bsz, e, cap, d) * (slot_gate[..., None] != 0.0)

    # Fold (B, cap) into one token dim so the expert matmuls are plain 3-D
    # batched GEMMs: GSPMD partitions the 4-D two-batch-dim einsum's
    # BACKWARD badly (it all-reduces a (E, f, B_full, cap) intermediate —
    # 20 GiB/layer on mixtral — instead of the (E,d,f) dW; see
    # EXPERIMENTS.md §Perf iteration B2).
    xt_e = xe.transpose(1, 0, 2, 3).reshape(e, bsz * cap, d)
    # FSDP hint: gather bf16 expert weights over the data axes up front,
    # keeping TP on the expert dim (deepseek-moe) or the d_ff dim (mixtral).
    # No-op without an installed hint context — §Perf B3.
    from repro.parallel import hints
    w_g = hints.hint_gathered_weight(params["w_gate"].astype(cdt), (0, 2))
    w_u = hints.hint_gathered_weight(params["w_up"].astype(cdt), (0, 2))
    w_d = hints.hint_gathered_weight(params["w_down"].astype(cdt), (0, 1))
    # Keep the expert activations token-sharded (else GSPMD replicates the
    # compute once the weights look replicated) — §Perf B4.
    g = hints.hint_expert_act(
        jnp.einsum("etd,edf->etf", xt_e.astype(cdt), w_g), 1, (0, 2))
    u = hints.hint_expert_act(
        jnp.einsum("etd,edf->etf", xt_e.astype(cdt), w_u), 1, (0, 2))
    yt = hints.hint_expert_act(
        jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, w_d), 1, (0,))
    ye = yt.reshape(e, bsz, cap, d).transpose(1, 0, 2, 3)

    weighted = ye.astype(jnp.float32) * slot_gate[..., None]
    out = jnp.zeros((bsz, s, d), jnp.float32).at[
        rows, slot_token.reshape(bsz, e * cap)].add(
        weighted.reshape(bsz, e * cap, d))

    metrics = {
        "moe_dropped_frac":
            1.0 - jnp.sum(keep.astype(jnp.float32)) / (bsz * sk),
        "moe_router_entropy": -jnp.mean(jnp.sum(
            jax.nn.softmax(logits, -1) * jax.nn.log_softmax(logits, -1),
            -1)),
    }

    if cfg.n_shared_experts:
        sp = params["shared"]
        sg = jnp.einsum("gsd,df->gsf", x.astype(cdt),
                        sp["w_gate"].astype(cdt))
        su = jnp.einsum("gsd,df->gsf", x.astype(cdt),
                        sp["w_up"].astype(cdt))
        out = out + jnp.einsum("gsf,fd->gsd", jax.nn.silu(sg) * su,
                               sp["w_down"].astype(cdt)).astype(jnp.float32)

    return out.astype(x.dtype), metrics


def moe_ffn_dense_oracle(params, cfg: ModelConfig, x: jax.Array):
    """O(T·E) reference: every token through every expert, gated. Used by
    tests to validate the capacity-gather dispatch (with cf large enough
    that nothing drops)."""
    bsz, s, d = x.shape
    t = bsz * s
    xt = x.reshape(t, d).astype(jnp.float32)
    logits = xt @ params["router"].astype(jnp.float32)
    if cfg.router_norm == "softmax_topk":
        probs = jax.nn.softmax(logits, -1)
        gate_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    else:
        top_logits, top_idx = jax.lax.top_k(logits, cfg.top_k)
        gate_vals = jax.nn.softmax(top_logits, -1)
    gates = jnp.zeros((t, cfg.n_experts)).at[
        jnp.arange(t)[:, None], top_idx].set(gate_vals)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"].astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xt, params["w_up"].astype(jnp.float32))
    ye = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u,
                    params["w_down"].astype(jnp.float32))
    out = jnp.einsum("te,ted->td", gates, ye)
    if cfg.n_shared_experts:
        sp = params["shared"]
        sg = xt @ sp["w_gate"].astype(jnp.float32)
        su = xt @ sp["w_up"].astype(jnp.float32)
        out = out + (jax.nn.silu(sg) * su) @ sp["w_down"].astype(jnp.float32)
    return out.reshape(bsz, s, d).astype(x.dtype)
