"""ShapeDtypeStruct input stand-ins for every model entry point.

``input_specs(cfg, shape)`` builds the exact abstract inputs for
train/prefill/decode so the dry-run can ``jit(...).lower(**specs)`` without
allocating anything. For [audio]/[vlm] archs the frontend is a stub: specs
provide token ids over the codec vocab / precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, seq_len: int, batch: int
                      ) -> Dict[str, Any]:
    """Inputs of train_step: token ids + labels (next tokens) + rng."""
    specs = {
        "tokens": sds((batch, seq_len), jnp.int32),
        "labels": sds((batch, seq_len), jnp.int32),
        "mask": sds((batch, seq_len), jnp.float32),
    }
    if cfg.modality == "vision":
        nv = cfg.num_vision_tokens
        assert nv < seq_len
        specs["tokens"] = sds((batch, seq_len - nv), jnp.int32)
        specs["vision_embeds"] = sds((batch, nv, cfg.d_model), jnp.bfloat16)
        specs["positions"] = sds((3, batch, seq_len), jnp.int32)
    return specs


def prefill_input_specs(cfg: ModelConfig, seq_len: int, batch: int
                        ) -> Dict[str, Any]:
    specs = {"tokens": sds((batch, seq_len), jnp.int32)}
    if cfg.modality == "vision":
        nv = cfg.num_vision_tokens
        specs["tokens"] = sds((batch, seq_len - nv), jnp.int32)
        specs["vision_embeds"] = sds((batch, nv, cfg.d_model), jnp.bfloat16)
        specs["positions"] = sds((3, batch, seq_len), jnp.int32)
    return specs


def decode_input_specs(cfg: ModelConfig, seq_len: int, batch: int
                       ) -> Dict[str, Any]:
    """serve_step: one new token against a cache of length seq_len."""
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, seq_len))
    specs = {
        "token": sds((batch, 1), jnp.int32),
        "cache": cache,
        "cache_pos": sds((), jnp.int32),
    }
    if cfg.mrope_sections:
        specs["positions"] = sds((3, batch, 1), jnp.int32)
    return specs


def params_specs(cfg: ModelConfig):
    """Abstract parameter tree (no allocation) via eval_shape."""
    return jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
