"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Training uses the chunked SSD algorithm: within a chunk the recurrence is a
masked, decay-weighted matmul (attention-shaped, MXU-friendly); across chunks
a short `lax.scan` carries the (H, N, P) state. ``ssd_sequential`` is the
step-by-step oracle used by tests; ``ssm_decode_step`` is the O(1)-per-token
serving path.

Shapes: x (B,S,H,P) heads×head_dim, dt (B,S,H), A (H,) negative,
B/C (B,S,N) (single group), D (H,). State: (B,H,N,P).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import normal_init, rmsnorm


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_sequential(x, dt, a_neg, b_mat, c_mat, d_skip):
    """Step-by-step SSD reference (oracle for the chunked path).

    Returns (y, final_state). All fp32.
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    decay = jnp.exp(dt * a_neg)                       # (B,S,H)
    xbar = x * dt[..., None]                          # (B,S,H,P)

    def step(state, inp):
        dec_t, xb_t, b_t, c_t = inp
        state = state * dec_t[..., None, None] + \
            jnp.einsum("bn,bhp->bhnp", b_t, xb_t)
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y_t

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(xbar, 1, 0),
          jnp.moveaxis(b_mat, 1, 0), jnp.moveaxis(c_mat, 1, 0))
    state, ys = jax.lax.scan(step, init, xs)
    y = jnp.moveaxis(ys, 0, 1) + x * d_skip[None, None, :, None]
    return y, state


def ssd_chunked(x, dt, a_neg, b_mat, c_mat, d_skip, chunk: int,
                initial_state=None):
    """Chunked SSD (the Mamba-2 training algorithm). Returns (y, state).

    Arbitrary S: the tail is padded with dt = 0 steps (decay = 1, zero input
    contribution), which leaves the state invariant — exact, not approximate.
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        y, state = ssd_chunked(x, dt, a_neg, b_mat, c_mat, d_skip, chunk,
                               initial_state)
        return y[:, :s], state
    nc, q = s // chunk, chunk

    da = (dt * a_neg).reshape(bsz, nc, q, h)          # (B,nc,Q,H)
    xbar = (x * dt[..., None]).reshape(bsz, nc, q, h, p)
    bm = b_mat.reshape(bsz, nc, q, n)
    cm = c_mat.reshape(bsz, nc, q, n)

    seg = jnp.cumsum(da, axis=2)                      # (B,nc,Q,H)
    # --- intra-chunk: masked decay-weighted "attention" ---
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cm, bm)        # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, l_mat, xbar)

    # --- chunk states ---
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)   # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bm, decay_to_end, xbar)
    chunk_decay = jnp.exp(seg[:, :, -1, :])           # (B,nc,H)

    # --- inter-chunk scan (carry state across chunks) ---
    def step(h_prev, inp):
        s_c, dec_c = inp
        h_new = h_prev * dec_c[:, :, None, None] + s_c
        return h_new, h_prev

    init = (jnp.zeros((bsz, h, n, p), jnp.float32)
            if initial_state is None else initial_state)
    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    final_state, h_prevs = jax.lax.scan(step, init, xs)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)             # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cm, jnp.exp(seg), h_prevs)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + x * d_skip[None, None, :, None]
    return y, final_state


# ---------------------------------------------------------------------------
# Mamba-2 block (in_proj -> conv1d -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def init_ssm_block(rng, cfg: ModelConfig):
    d, di, n = cfg.d_model, cfg.ssm_inner, cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(rng, 4)
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "w_in": normal_init(ks[0], (d, 2 * di + 2 * n + h), d),
        "conv_w": normal_init(ks[1], (cfg.ssm_conv_width, conv_dim),
                              cfg.ssm_conv_width),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),        # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": {"scale": jnp.zeros((di,), jnp.float32)},
        "w_out": normal_init(ks[3], (di, d), di),
    }


def _split_in(cfg, proj):
    di, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv1d, width W: y_t = sum_w w[w]*x_{t-W+1+w}."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * conv_w[i][None, None, :]
              for i in range(w))
    return jax.nn.silu(out + conv_b)


def ssm_block(params, cfg: ModelConfig, x: jax.Array,
              cache: Tuple[jax.Array, jax.Array] = None,
              decode: bool = False):
    """Returns (out (B,S,d), new_cache=(conv_state, ssm_state))."""
    di, n, h, p = (cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads,
                   cfg.ssm_head_dim)
    cdt = jnp.dtype(cfg.dtype)
    bsz, s, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cdt))
    z, xbc, dt_raw = _split_in(cfg, proj)
    xbc = xbc.astype(jnp.float32)

    if not decode:
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        conv_state = None  # filled by prefill wrapper below
        xs = xbc[..., :di].reshape(bsz, s, h, p)
        bm = xbc[..., di:di + n]
        cm = xbc[..., di + n:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        a_neg = -jnp.exp(params["a_log"])
        init_state = cache[1] if cache is not None else None
        y, state = ssd_chunked(xs, dt, a_neg, bm, cm, params["d_skip"],
                               cfg.ssm_chunk, initial_state=init_state)
        width = cfg.ssm_conv_width
        # conv state for serving: last (width-1) *pre-conv* inputs. A
        # prompt shorter than the window left-pads with zeros — exactly
        # the fresh-cache contents those positions held.
        pre = jnp.einsum("bsd,de->bse", x,
                         params["w_in"].astype(cdt))[..., di:di + di + 2 * n]
        pre = jnp.pad(pre, ((0, 0), (max(0, width - 1 - s), 0), (0, 0)))
        conv_state = pre[:, -(width - 1):, :].astype(jnp.float32)
    else:
        assert s == 1 and cache is not None
        conv_prev, ssm_state = cache
        width = cfg.ssm_conv_width
        seq = jnp.concatenate([conv_prev, xbc], axis=1)   # (B, width, conv)
        conv_out = jax.nn.silu(
            jnp.einsum("bwc,wc->bc", seq, params["conv_w"])
            + params["conv_b"])[:, None, :]
        xs = conv_out[..., :di].reshape(bsz, 1, h, p)
        bm = conv_out[..., di:di + n]
        cm = conv_out[..., di + n:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        a_neg = -jnp.exp(params["a_log"])
        decay = jnp.exp(dt[:, 0] * a_neg)                 # (B,H)
        xbar = xs[:, 0] * dt[:, 0, :, None]               # (B,H,P)
        state = (ssm_state * decay[..., None, None]
                 + jnp.einsum("bn,bhp->bhnp", bm[:, 0], xbar))
        y = (jnp.einsum("bn,bhnp->bhp", cm[:, 0], state)
             + xs[:, 0] * params["d_skip"][None, :, None])[:, None]
        conv_state = seq[:, 1:, :]

    y = y.reshape(bsz, s, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)))
    out = jnp.einsum("bse,ed->bsd", y.astype(cdt),
                     params["w_out"].astype(cdt))
    return out, (conv_state, state)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, n = cfg.ssm_inner, cfg.ssm_state
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * n), dtype)
    state = jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim), dtype)
    return conv, state
