"""Non-blocking generator refresh (DESIGN.md §3).

The training loop must not stall every accelerator for the duration of a
generator fit (the paper's "Step 1"), but it also must stay *bit-exact
recoverable*: a run that checkpoints and resumes mid-refresh has to end up
with exactly the parameters of an uninterrupted run. The protocol:

1. **Submit** at a schedule-determined step ``s``: the loop snapshots the
   (immutable) train state, persists it as a ``gensnap_<s>`` artifact next
   to the checkpoints, and hands the fit to a background thread. Training
   continues on the stale generator.
2. **Swap** at the *recorded* step ``s + gen_swap_delay``: the loop blocks
   (usually a no-op — the fit finished long ago) and installs the new head
   state. The swap step is a pure function of the config, never of thread
   timing, so data/rng streams are unaffected by how long the fit took.
3. **Resume**: if a restart lands inside the (submit, swap] window, the
   loop reloads the ``gensnap`` artifact and re-runs the fit — the fit
   functions in :mod:`repro.genfit` are deterministic in (state, config),
   so the replayed swap installs bit-identical parameters at the same
   step.

``AsyncRefresher`` is the small thread harness behind step 1/2; the
orchestration lives in :func:`repro.train.loop.run_loop`.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional, Tuple

from repro.obs import NULL_REGISTRY
from repro.resilience import faults

SNAP_PREFIX = "gensnap_"


class RefreshTimeout(TimeoutError):
    """The watchdog gave up on a hung background fit."""


class _Job:
    """One submission's private result slots. The worker writes only to
    its own job, so a hung thread abandoned by the watchdog can never
    clobber a *later* submission's state when it finally wakes up."""

    __slots__ = ("result", "error", "done", "step", "thread", "wall_s")

    def __init__(self, step: int):
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.done = False
        self.step = step
        self.thread: Optional[threading.Thread] = None
        self.wall_s: Optional[float] = None


class AsyncRefresher:
    """One-in-flight background fit with retries, a hang watchdog, and
    exception propagation (DESIGN.md §13 genfit degradation ladder).

    ``submit(state, step)`` starts ``fit_fn(state)`` on a worker thread;
    the worker retries transient failures ``retries`` times with
    exponential backoff (``backoff_s * 2**attempt``) before recording the
    last error. ``result()`` joins — bounded by ``timeout_s`` when set —
    and returns, or raises: the worker's final exception for a failed
    fit, :class:`RefreshTimeout` for a hung one (the stuck daemon thread
    is abandoned, not joined; per-job result slots keep it harmless).
    The *caller* decides what failure means — the training loop keeps
    the stale generator and re-arms the SNR trigger rather than dying.

    jax arrays are immutable, so the snapshot needs no copying; XLA
    releases the GIL during execution, so training steps overlap the fit
    on CPU too.
    """

    def __init__(self, fit_fn: Callable[[Any], Any], retries: int = 0,
                 backoff_s: float = 0.05,
                 timeout_s: Optional[float] = None):
        self._fit_fn = fit_fn
        self._retries = retries
        self._backoff_s = backoff_s
        self._timeout_s = timeout_s
        self._job: Optional[_Job] = None
        self._last_step: Optional[int] = None
        # Wall time of the most recently *completed* fit (None until one
        # finishes) — the `fit_wall_s` field of the gen_swap event.
        self.last_fit_seconds: Optional[float] = None

    @property
    def in_flight(self) -> bool:
        return self._job is not None

    @property
    def submit_step(self) -> Optional[int]:
        # Survives result()/failure so the failure handler can name the
        # submission it is cleaning up after.
        return self._job.step if self._job is not None else self._last_step

    def submit(self, state, step: int) -> None:
        assert self._job is None, "refresh already in flight"
        job = _Job(step)

        def work():
            t0 = time.perf_counter()
            for attempt in range(self._retries + 1):
                try:
                    # Site "genfit/fit": one invocation per *attempt*, so
                    # a scheduled transient raise is absorbed by a retry.
                    faults.fire("genfit/fit")
                    job.result = self._fit_fn(state)
                    job.wall_s = time.perf_counter() - t0
                    self.last_fit_seconds = job.wall_s
                    job.error = None
                    break
                except BaseException as e:    # surfaced via result()
                    job.error = e
                    if attempt < self._retries:
                        time.sleep(self._backoff_s * (2 ** attempt))
            job.done = True

        job.thread = threading.Thread(
            target=work, name=f"gen-refresh@{step}", daemon=True)
        self._job = job
        self._last_step = step
        job.thread.start()

    def ready(self) -> bool:
        return self._job is not None and self._job.done

    def result(self) -> Tuple[Any, int]:
        """Join the worker and return (head_state, submit_step)."""
        job = self._job
        assert job is not None, "no refresh in flight"
        job.thread.join(self._timeout_s)
        if not job.done and job.thread.is_alive():
            # Hung fit: abandon the daemon thread (its writes land in
            # its own job, now unreachable) and report the watchdog.
            self._job = None
            raise RefreshTimeout(
                f"generator fit submitted at step {job.step} exceeded "
                f"watchdog timeout {self._timeout_s}s")
        self._job = None
        if job.error is not None:
            raise job.error
        return job.result, job.step


def refresh_on_snr(step: int, fit_step: int, snr_ewma: float,
                   snr_ref: float, threshold: float, patience: int) -> bool:
    """SNR-driven refresh trigger (DESIGN.md §9).

    Fires when the online signal-mass EWMA has degraded below
    ``threshold`` x the post-install reference level. ``fit_step`` is the
    *install* step of the current generator (submit step + swap delay for
    async refreshes); ``patience`` steps must elapse after the install
    before the trigger can fire, which also gives the reference time to be
    armed (the loop freezes ``snr_ref`` = EWMA ``patience`` steps after
    install). Both ``snr_ewma`` and ``snr_ref`` are < 0 while unset, so
    the trigger is inert until a generator is installed AND the reference
    is armed — a fresh generator never fires.
    """
    return (fit_step >= 0 and snr_ref > 0 and snr_ewma >= 0
            and step - fit_step >= patience
            and snr_ewma < threshold * snr_ref)


def swap_event(step: int, old_fit_step: int, new_fit_step: int,
               fit_wall_s: Optional[float], registry=None) -> dict:
    """Structured record of a generator swap (emitted on EVERY install —
    warmup, periodic, SNR-triggered, blocking or async).

    ``old_fit_step`` is the submit step of the generator being replaced
    (-1 for the warmup install), ``new_fit_step`` the submit step of the
    incoming one, ``fit_wall_s`` the background/blocking fit's wall time
    (None when a replayed resume raced past the measurement), and
    ``steps_stale_at_swap`` = step - new_fit_step: how many optimizer
    steps the discriminator advanced between the snapshot the fit saw
    and the install — the staleness the paper's alternating scheme
    tolerates, and the quantity to watch when tuning ``gen_swap_delay``.

    Also folds the swap into ``registry``: ``genfit/swaps`` counter,
    ``genfit/fit_wall_s`` and ``genfit/staleness_at_swap`` histograms.
    Returns the JSONL-ready ``gen_swap`` event dict.
    """
    reg = registry or NULL_REGISTRY
    stale = step - new_fit_step
    reg.counter("genfit/swaps").inc()
    if fit_wall_s is not None:
        reg.histogram("genfit/fit_wall_s").observe(fit_wall_s)
    reg.histogram("genfit/staleness_at_swap",
                  bounds=[1, 2, 4, 8, 16, 32, 64, 128, 256, 512,
                          1024]).observe(stale)
    return {"event": "gen_swap", "step": step,
            "old_fit_step": old_fit_step, "new_fit_step": new_fit_step,
            "fit_wall_s": fit_wall_s, "steps_stale_at_swap": stale}


def latest_snapshot_step(directory: str) -> Optional[int]:
    """Largest step with a complete ``gensnap`` artifact (None if none).

    SNR-triggered submits are data-dependent, not config-determined, so a
    resume cannot recompute the submit step the way the periodic schedule
    can (``LoopConfig.last_submit_before``) — it recovers it from the
    artifact that the submit persisted.
    """
    import os

    from repro.checkpoint.checkpoint import MANIFEST
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if not name.startswith(SNAP_PREFIX):
            continue
        try:
            s = int(name[len(SNAP_PREFIX):])
        except ValueError:
            continue
        if os.path.exists(os.path.join(directory, name, MANIFEST)):
            steps.append(s)
    return max(steps) if steps else None


def snapshot_path_exists(directory: str, step: int) -> bool:
    import os

    from repro.checkpoint.checkpoint import MANIFEST
    return os.path.exists(os.path.join(
        directory, f"{SNAP_PREFIX}{step:08d}", MANIFEST))


def save_snapshot(directory: str, step: int, pytree) -> str:
    """Persist the submit-time state under ``gensnap_<step>`` (atomic,
    ignored by checkpoint GC and the LATEST pointer)."""
    from repro.checkpoint import save_checkpoint
    return save_checkpoint(directory, step, pytree, keep=0,
                           prefix=SNAP_PREFIX, update_latest=False)


def load_snapshot(directory: str, step: int, tree_like):
    from repro.checkpoint import restore_checkpoint
    state, _ = restore_checkpoint(directory, tree_like, step=step,
                                  prefix=SNAP_PREFIX)
    return state


def drop_snapshot(directory: str, step: int) -> None:
    """Remove a consumed ``gensnap`` artifact (post-swap cleanup)."""
    import os
    import shutil
    shutil.rmtree(os.path.join(directory, f"{SNAP_PREFIX}{step:08d}"),
                  ignore_errors=True)
