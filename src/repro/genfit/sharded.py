"""Sharded generator fitting: independent subtrees fanned out (DESIGN.md §3).

Below any split depth ``D`` the 2^D subtrees of the generator are fully
independent fitting problems — they share no nodes, no labels, and no data
points. ``fit_tree_sharded`` exploits this: the top ``D`` levels run as one
level-parallel sweep over the full data set, then each subtree is fitted by
an independent :func:`~repro.genfit.levels.fit_tree_levelwise` call on its
own label/point subset and the results are spliced back into the global
node arrays.

Fan-out is pluggable: pass any ``concurrent.futures``-style executor to
overlap subtree fits (XLA releases the GIL during execution, so a thread
pool buys real overlap on CPU), and/or restrict this process to a
round-robin share of subtrees via ``shard_index/shard_count``
(:func:`repro.parallel.round_robin_shard`) for multi-host fitting — each
host fits its share and the (tiny) node parameters are merged by the
caller or exchanged with one all-gather; see DESIGN.md §3 for the
multi-host wiring.

Subtree point sets are padded to pow-2 buckets with zero-weight rows so
every subtree reuses the same compiled level pieces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.tree import PAD_LOGIT, padded_size
from repro.core.tree_fit import FitConfig
from repro.genfit.levels import (_fit_levels, _prep_data,
                                 fit_tree_levelwise, pack_tree)

_PT_BUCKET_MIN = 1024


def _bucket_points(x, y, wgt, n_bucket: int):
    """Right-pad a subtree's point set with zero-weight rows (invisible to
    every reduction) so point counts quantize to shared jit shapes."""
    pad = n_bucket - len(y)
    if pad <= 0:
        return x, y, wgt
    k = x.shape[1]
    return (np.concatenate([x, np.zeros((pad, k), x.dtype)]),
            np.concatenate([y, np.zeros(pad, y.dtype)]),
            np.concatenate([wgt, np.zeros(pad, wgt.dtype)]))


def _subtree_cfg(cfg: FitConfig, j: int) -> FitConfig:
    """Deterministic per-subtree seed (independent of fit order)."""
    return dataclasses.replace(cfg, seed=cfg.seed + 100003 * (j + 1))


def fit_one_subtree(x, y, wgt, perm, slot_of_label, num_labels: int,
                    c_pad: int, split_depth: int, j: int,
                    cfg: FitConfig):
    """Fit subtree ``j`` (leaf slots [j·S, (j+1)·S)) on its own points.

    Returns ``(w_sub, b_sub, leaf_labels)``: the subtree's S−1 node
    parameters in local level order and the global label id at each of its
    S leaves (−1 for padding leaves).
    """
    s_leaves = c_pad >> split_depth
    lo = j * s_leaves
    sub_slots = perm[lo:lo + s_leaves]
    real = sub_slots[sub_slots < num_labels]
    k = x.shape[1]
    if len(real) == 0:
        # Unreachable subtree (forced away above the split): park all mass
        # on the left spine.
        return (np.zeros((s_leaves - 1, k), np.float32),
                np.full((s_leaves - 1,), -PAD_LOGIT, np.float32),
                np.full((s_leaves,), -1, np.int64))
    # Points whose label lives in this subtree, in original order.
    pt_mask = (slot_of_label[y] >= lo) & (slot_of_label[y] < lo + s_leaves)
    local_of_global = np.full((num_labels,), -1, np.int64)
    local_of_global[real] = np.arange(len(real))
    xs, ys, ws = (x[pt_mask], local_of_global[y[pt_mask]], wgt[pt_mask])
    n_bucket = _PT_BUCKET_MIN
    while n_bucket < len(ys):
        n_bucket *= 2
    xs, ys, ws = _bucket_points(xs, ys, ws, n_bucket)
    sub = fit_tree_levelwise(xs, ys, len(real), sample_weight=ws,
                             config=_subtree_cfg(cfg, j), c_pad=s_leaves)
    l2l = np.asarray(sub.leaf_to_label, np.int64)
    occupied = np.asarray(sub.label_to_leaf)[l2l] == np.arange(s_leaves)
    leaf_labels = np.where(occupied, real[l2l], -1)
    return (np.asarray(sub.w), np.asarray(sub.b), leaf_labels)


def fan_out_subtrees(x, y, wgt, perm, slot_of_label, num_labels: int,
                     c_pad: int, split_depth: int, subtree_ids,
                     cfg: FitConfig, executor=None):
    """Fit the given subtrees (via ``executor.map`` when provided, else
    serially) and return ``[(j, w_sub, b_sub, leaf_labels), ...]`` ready
    for :func:`splice_subtrees`. Shared by the cold sharded fit and the
    drift-triggered refresh so the two fan-out paths cannot diverge."""

    def fit_j(j):
        return (j, *fit_one_subtree(x, y, wgt, perm, slot_of_label,
                                    num_labels, c_pad, split_depth, j,
                                    cfg))

    mapper = executor.map if executor is not None else map
    return list(mapper(fit_j, subtree_ids))


def splice_subtrees(w_all, b_all, perm, results, split_depth: int,
                    c_pad: int, num_labels: int):
    """Write subtree fit results into the global node/permutation arrays.

    ``results``: iterable of ``(j, w_sub, b_sub, leaf_labels)``. Padding
    leaves (−1) are re-assigned fresh global padding ids afterwards so
    ``perm`` stays a permutation of [0, C_pad).
    """
    s_leaves = c_pad >> split_depth
    sub_depth = s_leaves.bit_length() - 1
    for j, w_sub, b_sub, leaf_labels in results:
        for t in range(sub_depth):
            n_t = 1 << t
            g_base = (1 << (split_depth + t)) - 1 + j * n_t
            l_base = n_t - 1
            w_all[g_base:g_base + n_t] = w_sub[l_base:l_base + n_t]
            b_all[g_base:g_base + n_t] = b_sub[l_base:l_base + n_t]
        perm[j * s_leaves:(j + 1) * s_leaves] = leaf_labels
    # Re-assign padding ids (any bijection over the free slots works).
    free = perm < 0
    used = np.zeros((c_pad,), bool)
    used[perm[~free]] = True
    perm[free] = np.nonzero(~used)[0]
    return w_all, b_all, perm


def fit_tree_sharded(features, labels, num_labels: int,
                     sample_weight=None,
                     config: Optional[FitConfig] = None,
                     split_depth: int = 2,
                     executor=None,
                     shard_index: int = 0, shard_count: int = 1,
                     _return_parts: bool = False):
    """Level-parallel fit with the bottom subtrees fanned out.

    The top ``split_depth`` levels are fitted on the full data; the 2^D
    independent subtrees are then fitted via ``executor.map`` (defaults to
    serial) and spliced. With ``shard_count > 1`` only the round-robin
    share of this shard is fitted and the partial ``(w, b, perm)`` arrays
    are returned for cross-host merging (rows owned by other shards stay
    zero) — single-shard callers always get a complete :class:`Tree`.
    """
    from repro.parallel import round_robin_shard

    cfg = config or FitConfig()
    x, y, wgt = _prep_data(features, labels, num_labels, sample_weight)
    c_pad = padded_size(num_labels)
    depth = c_pad.bit_length() - 1
    split_depth = max(0, min(split_depth, depth))
    w_all, b_all, perm, slot = _fit_levels(
        x, y, wgt, num_labels, c_pad, cfg, n_levels=split_depth)
    if split_depth == depth:
        return pack_tree(w_all, b_all, perm, num_labels)
    mine = round_robin_shard(1 << split_depth, shard_index, shard_count)
    results = fan_out_subtrees(x, y, wgt, perm, slot, num_labels, c_pad,
                               split_depth, mine, cfg, executor=executor)
    w_all, b_all, perm = splice_subtrees(
        w_all, b_all, perm, results, split_depth, c_pad, num_labels)
    if _return_parts or shard_count > 1:
        return w_all, b_all, perm
    return pack_tree(w_all, b_all, perm, num_labels)
