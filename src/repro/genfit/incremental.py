"""Incremental generator refresh: warm-start refits (DESIGN.md §3).

A mid-training refresh rarely needs to re-derive the tree *structure*: the
label→leaf assignment encodes which labels are confusable, which drifts
slowly, while the node parameters (w, b) chase the moving hidden-state
distribution. :func:`refit_params` therefore keeps the previous tree's
split assignments and re-solves only the per-node logistic parameters —
one batched warm-started Newton pass per level, no discrete steps, no
power-iteration inits — typically converging in 1–3 iterations per level.

:func:`refresh_tree` adds drift awareness on top: it compares the snapshot
label distribution against the previous fit's (conditioned per subtree at
``split_depth``) and triggers *subtree-local full refits* — discrete steps
included — only where the distribution actually moved (total-variation
distance above ``drift_threshold``), splicing the refitted subtrees into
the warm-refit tree. Both paths are deterministic functions of (previous
tree, snapshot data, config), which is what lets the training loop replay
an async refresh bit-exactly after a checkpoint resume.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import PAD_LOGIT, Tree, padded_size
from repro.core.tree_fit import FitConfig
from repro.genfit.levels import (_cfg_key, _prep_data, _seg_sum_fn,
                                 make_newton_pieces, pack_tree,
                                 run_newton)
from repro.genfit.sharded import fan_out_subtrees, splice_subtrees


@functools.lru_cache(maxsize=512)
def _get_refit_pieces(n: int, c_pad: int, k: int, level: int,
                      cfg_key: Tuple):
    reg, _, max_newton, newton_tol, use_kernel = cfg_key
    depth = c_pad.bit_length() - 1
    shift = depth - level
    nseg = 1 << level
    d = k + 1
    seg2 = _seg_sum_fn(use_kernel)
    newton_pieces = make_newton_pieces(nseg, d, reg, max_newton,
                                       newton_tol, seg2)
    leaves = jnp.arange(c_pad, dtype=jnp.int32)
    node_of_leaf = leaves >> shift
    side_of_leaf = ((leaves >> (shift - 1)) & 1).astype(jnp.float32)

    @jax.jit
    def prep(y, wgt, leaf_of_label, real_leaf):
        leaf_pt = leaf_of_label[y]
        seg = leaf_pt >> shift
        zeta = jnp.where((leaf_pt >> (shift - 1)) & 1 == 1, 1.0,
                         -1.0).astype(jnp.float32)
        realf = real_leaf.astype(jnp.float32)
        right_real = jax.ops.segment_sum(realf * side_of_leaf,
                                         node_of_leaf, num_segments=nseg)
        left_real = jax.ops.segment_sum(realf * (1.0 - side_of_leaf),
                                        node_of_leaf, num_segments=nseg)
        npts = jax.ops.segment_sum((wgt > 0).astype(jnp.float32), seg,
                                   num_segments=nseg)
        # Padding-forced nodes keep their forced decision; nodes with no
        # data keep their previous parameters (better than a cold zero).
        frozen = (right_real == 0) | (left_real == 0) | (npts == 0)
        return dict(seg=seg, zeta=zeta, frozen=frozen,
                    right_real=right_real, left_real=left_real,
                    has_real=(right_real + left_real) > 0)

    @jax.jit
    def finalize(theta, right_real, left_real, has_real):
        w_lvl, b_lvl = theta[:, :k], theta[:, k]
        force = (right_real == 0) | ((left_real == 0) & has_real)
        w_lvl = jnp.where(force[:, None], 0.0, w_lvl)
        b_lvl = jnp.where(right_real == 0, -PAD_LOGIT, b_lvl)
        b_lvl = jnp.where((left_real == 0) & has_real, PAD_LOGIT, b_lvl)
        return jnp.concatenate([w_lvl, b_lvl[:, None]], axis=-1)

    return prep, finalize, newton_pieces


def real_leaf_mask(tree: Tree, num_labels: int) -> np.ndarray:
    """(C_pad,) bool: which leaves hold a real label (padding leaves alias
    label 0 but fail the round-trip)."""
    l2l = np.asarray(tree.leaf_to_label, np.int64)
    return np.asarray(tree.label_to_leaf)[l2l] == np.arange(len(l2l))


def perm_from_tree(tree: Tree, num_labels: int) -> np.ndarray:
    """Recover the slot permutation (perm[leaf] = label id, with distinct
    padding ids ≥ num_labels on padding leaves)."""
    c_pad = 1 << tree.depth
    real = real_leaf_mask(tree, num_labels)
    perm = np.where(real, np.asarray(tree.leaf_to_label, np.int64), -1)
    perm[~real] = num_labels + np.arange(int((~real).sum()))
    return perm


def refit_params(tree: Tree, features, labels, num_labels: int,
                 sample_weight=None,
                 config: Optional[FitConfig] = None) -> Tree:
    """Warm-start refit: keep the tree structure, re-solve (w, b).

    One batched Newton pass per level, warm-started from the previous
    parameters — O(log C) phases with no discrete steps. Nodes without
    data keep their previous parameters; padding forcing is re-derived
    from the (unchanged) leaf occupancy.
    """
    cfg = config or FitConfig()
    key = _cfg_key(cfg)
    x, y, wgt = _prep_data(features, labels, num_labels, sample_weight)
    depth = tree.depth
    c_pad = 1 << depth
    assert c_pad >= padded_size(num_labels)
    k = x.shape[1]
    assert k == tree.feature_dim, (k, tree.feature_dim)

    xj = jnp.asarray(x, jnp.float32)
    xb = jnp.concatenate([xj, jnp.ones((len(x), 1), jnp.float32)], -1)
    d = k + 1
    outer = (xb[:, :, None] * xb[:, None, :]).reshape(-1, d * d)
    yj = jnp.asarray(y, jnp.int32)
    wj = jnp.asarray(wgt, jnp.float32)
    l2l = jnp.asarray(tree.label_to_leaf, jnp.int32)
    real_leaf = jnp.asarray(real_leaf_mask(tree, num_labels))

    w_prev = np.asarray(tree.w)
    b_prev = np.asarray(tree.b)
    w_all, b_all = w_prev.copy(), b_prev.copy()
    _, _, max_newton, _, _ = key
    for level in range(depth):
        prep, finalize, newton_pieces = _get_refit_pieces(
            len(x), c_pad, k, level, key)
        aux = prep(yj, wj, l2l, real_leaf)
        n_lvl = 1 << level
        lo = n_lvl - 1
        theta = jnp.asarray(
            np.concatenate([w_prev[lo:lo + n_lvl],
                            b_prev[lo:lo + n_lvl, None]], axis=-1))
        theta = run_newton(newton_pieces, theta, aux["frozen"], xb, outer,
                           aux["zeta"], wj, aux["seg"],
                           np.asarray(aux["seg"]), max_newton)
        th = np.asarray(finalize(theta, aux["right_real"],
                                 aux["left_real"], aux["has_real"]))
        w_all[lo:lo + n_lvl] = th[:, :k]
        b_all[lo:lo + n_lvl] = th[:, k]
    return Tree(w=jnp.asarray(w_all), b=jnp.asarray(b_all),
                label_to_leaf=tree.label_to_leaf,
                leaf_to_label=tree.leaf_to_label)


def label_counts(labels, num_labels: int, sample_weight=None
                 ) -> np.ndarray:
    y = np.asarray(labels).reshape(-1)
    w = (None if sample_weight is None
         else np.asarray(sample_weight, np.float64).reshape(-1))
    return np.bincount(y, weights=w, minlength=num_labels).astype(
        np.float64)


def subtree_drift(prev_counts: np.ndarray, counts: np.ndarray, tree: Tree,
                  split_depth: int) -> np.ndarray:
    """Total-variation distance between the *conditional* label
    distributions of each depth-``split_depth`` subtree, previous fit vs
    now. Empty-then and empty-now subtrees drift 0; newly populated ones
    drift 1 (they were never fitted on data)."""
    depth = tree.depth
    split_depth = max(0, min(split_depth, depth))
    leaf = np.asarray(tree.label_to_leaf, np.int64)
    sub = leaf >> (depth - split_depth)
    n_sub = 1 << split_depth
    drifts = np.zeros((n_sub,))
    for j in range(n_sub):
        sel = sub == j
        a, b = prev_counts[sel], counts[sel]
        sa, sb = a.sum(), b.sum()
        if sa == 0 and sb == 0:
            continue
        if sa == 0 or sb == 0:
            drifts[j] = 1.0
            continue
        drifts[j] = 0.5 * np.abs(a / sa - b / sb).sum()
    return drifts


def refresh_tree(prev_tree: Tree, features, labels, num_labels: int,
                 sample_weight=None,
                 config: Optional[FitConfig] = None,
                 prev_counts: Optional[np.ndarray] = None,
                 drift_threshold: Optional[float] = None,
                 split_depth: int = 3,
                 executor=None) -> Tuple[Tree, np.ndarray]:
    """Incremental refresh: warm parameter refit everywhere, plus full
    subtree-local refits where the label distribution drifted.

    Returns ``(tree, counts)``; feed ``counts`` back as ``prev_counts``
    at the next refresh. With ``drift_threshold=None`` (or no
    ``prev_counts``) this is a pure parameter refit. Deterministic in its
    inputs, so an interrupted async refresh can be replayed exactly.
    """
    cfg = config or FitConfig()
    tree = refit_params(prev_tree, features, labels, num_labels,
                        sample_weight=sample_weight, config=cfg)
    counts = label_counts(labels, num_labels, sample_weight)
    if drift_threshold is None or prev_counts is None:
        return tree, counts
    depth = tree.depth
    c_pad = 1 << depth
    split_depth = max(1, min(split_depth, depth))
    drifts = subtree_drift(prev_counts, counts, tree, split_depth)
    drifted = [int(j) for j in np.nonzero(drifts > drift_threshold)[0]]
    if not drifted:
        return tree, counts
    x, y, wgt = _prep_data(features, labels, num_labels, sample_weight)
    perm = perm_from_tree(tree, num_labels)
    slot_of_label = np.zeros((c_pad,), np.int64)
    slot_of_label[perm] = np.arange(c_pad)
    w_all, b_all = np.asarray(tree.w).copy(), np.asarray(tree.b).copy()

    results = fan_out_subtrees(x, y, wgt, perm, slot_of_label, num_labels,
                               c_pad, split_depth, drifted, cfg,
                               executor=executor)
    w_all, b_all, perm = splice_subtrees(w_all, b_all, perm, results,
                                         split_depth, c_pad, num_labels)
    return pack_tree(w_all, b_all, perm, num_labels), counts
