"""repro.genfit — scalable generator fitting (DESIGN.md §3).

The training-side subsystem around the paper's §3 generator: level-parallel
batched fitting (O(log C) sequential phases; :mod:`~repro.genfit.levels`),
warm-start/drift-triggered incremental refreshes
(:mod:`~repro.genfit.incremental`), subtree-sharded fan-out
(:mod:`~repro.genfit.sharded`), and the non-blocking refresh harness used
by the training loop (:mod:`~repro.genfit.refresh`).
:func:`repro.core.tree_fit.fit_tree` remains the sequential reference
oracle that the property suite pins these against.
"""
from repro.genfit.incremental import (label_counts, refit_params,
                                      refresh_tree, subtree_drift)
from repro.genfit.levels import fit_tree_levelwise
from repro.genfit.refresh import AsyncRefresher
from repro.genfit.sharded import fit_tree_sharded

__all__ = ["AsyncRefresher", "fit_tree_levelwise", "fit_tree_sharded",
           "label_counts", "refit_params", "refresh_tree",
           "subtree_drift"]
