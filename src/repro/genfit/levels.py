"""Level-parallel generator-tree fitting (DESIGN.md §3).

The reference fit (:func:`repro.core.tree_fit.fit_tree`) is a host-side
recursion: one Python-stack Newton solve per node, 2C−1 of them, O(C)
sequential phases. This module re-derives the same alternating
discrete/continuous optimization as a **level-synchronous batched sweep**:
every node at one depth is solved in a single vectorized pass, so fitting
has O(log C) sequential phases and every phase is a handful of
segment-summed reductions plus one batched (k+1)×(k+1) Newton solve.

Key formulation choices (all load-bearing):

* **Flat slot space.** The label→leaf permutation under construction is a
  single ``perm`` array of ``C_pad`` slots; node membership at level ``l``
  is ``slot >> shift`` with ``shift = depth − l``, so per-level state is
  dense arrays, never per-node Python objects.
* **Segment-summed sufficient statistics.** The discrete step's Δ_y scores
  (Eq. 9), the Newton gradient/Hessian (Eq. 8), and the Armijo objective
  are all ``segment_sum`` reductions over points keyed by node (or label)
  id — O(N·k) per level regardless of node count. ``FitConfig.use_kernel``
  routes the 2-D reductions through the Pallas ``segment_stats`` kernel
  (:mod:`repro.kernels.segment_scores`).
* **Balanced split as a rank rule.** Sorting slots by ``(node, −Δ)`` with a
  stable sort makes "top half goes right, padding sinks left, and padding
  back-fills the right half when fewer than half the labels are real"
  all collapse to ``rank_within_node < m/2`` (padding Δ = −inf ties keep
  slot order). This reproduces the reference partition rule exactly.
* **Batched Newton with per-node damping.** All nodes of a level share one
  vectorized damped-Newton iteration built to touch (N,)-sized data as few
  times as possible: per-point logits are carried across iterations, the
  whole Armijo halving grid is evaluated from one directional pass, and
  directions are a matvec against a periodically-refreshed inverse Hessian
  (hand-rolled batched SPD inverse — per-matrix LAPACK dispatch is the CPU
  bottleneck at 32k nodes). Per-node adaptivity survives batching: nodes
  freeze individually on stable (or 2-cycling) partitions, frozen nodes'
  points are compacted out of later sweeps, intermediate alternations run
  capped solves on (shallow-level) stride-subsampled points, and one
  full-precision polish fits the final partition per level.

The jitted pieces are compiled once per (N, C_pad, level-width) and cached;
the level index itself is static per piece, which keeps each piece small.
The reference recursion stays the oracle: the property suite pins held-out
tree log-likelihood parity (tests/test_genfit.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import PAD_LOGIT, Tree, padded_size
from repro.core.tree_fit import FitConfig

# fp32 Newton cannot hit the reference's fp64 1e-8 step tolerance; clamp so
# converged nodes actually retire instead of oscillating at machine eps.
_MIN_TOL_F32 = 1e-5


def _cfg_key(cfg: FitConfig) -> Tuple:
    return (float(cfg.reg), int(cfg.max_alternations), int(cfg.max_newton),
            max(float(cfg.newton_tol), _MIN_TOL_F32),
            bool(getattr(cfg, "use_kernel", False)))


def _seg_sum_fn(use_kernel: bool):
    """Segment reduction for 2-D (N, D) statistics; kernel-routable."""
    if use_kernel:
        from repro.kernels.ops import segment_stats

        def seg2(vals, seg, num_segments):
            return segment_stats(vals, seg, num_segments)
        return seg2
    return lambda vals, seg, num_segments: jax.ops.segment_sum(
        vals, seg, num_segments=num_segments)


# Refresh the inverted Hessian every this many Newton iterations. The
# per-node objective is concave, so a direction from *any* SPD matrix is
# an ascent direction and Armijo backtracking keeps monotone ascent — a
# stale inverse only trades a few extra (cheap) gradient steps for
# skipping the (N, d²) Hessian reduction + inversion, the two dominant
# costs.
_HESS_EVERY = 5
# Armijo step grid: t = 2^0 … 2^-9. The reference halves sequentially (up
# to 40×) and takes the first accepted step; evaluating the whole grid in
# one fused pass picks the same step whenever it lies within 10 halvings
# (beyond that the node is in its numerical plateau and retires).
_LS_GRID = 10
# Intermediate alternations only need an *improved* theta, not a converged
# one — the partition is about to be re-sorted anyway. Each level runs
# capped Newton solves between discrete steps and one full-precision
# polish on the final partition (see _run_level).
_ALT_NEWTON = 3


def batched_inv_psd(a: jax.Array) -> jax.Array:
    """Batched SPD inverse for small static d, without per-matrix LAPACK.

    ``jnp.linalg.inv/cholesky`` dispatch one LAPACK call per matrix on
    CPU (~1.5 s for 32k 17×17 matrices); this unrolled Cholesky →
    triangular-inverse → Lᵀ⁻¹L⁻¹ runs as ~3·d vectorized ops over the
    batch and is bandwidth-bound instead.
    """
    n, d, _ = a.shape
    # Cholesky, column by column: chol[:, i, j] = L[i, j].
    chol = jnp.zeros((n, d, 0), a.dtype)
    for j in range(d):
        prior = chol[:, j, :]                                  # (n, j)
        s = a[:, j, j] - jnp.sum(prior * prior, -1)
        ljj = jnp.sqrt(jnp.maximum(s, 1e-30))
        rest = a[:, j + 1:, j]
        if j:
            rest = rest - jnp.einsum("nik,nk->ni", chol[:, j + 1:, :],
                                     prior)
        col = jnp.concatenate(
            [jnp.zeros((n, j), a.dtype), ljj[:, None],
             rest / ljj[:, None]], axis=1)
        chol = jnp.concatenate([chol, col[:, :, None]], axis=2)
    # Rows of L⁻¹ by forward substitution against the identity.
    eye = jnp.eye(d, dtype=a.dtype)
    linv = jnp.zeros((n, 0, d), a.dtype)
    for i in range(d):
        row = jnp.broadcast_to(eye[i], (n, d))
        if i:
            row = row - jnp.einsum("nk,nkj->nj", chol[:, i, :i], linv)
        linv = jnp.concatenate(
            [linv, (row / chol[:, i, i][:, None])[:, None, :]], axis=1)
    return jnp.einsum("nki,nkj->nij", linv, linv)       # (LLᵀ)⁻¹


def make_newton_pieces(nseg: int, d: int, reg: float, max_newton: int,
                       newton_tol: float, seg2):
    """Batched damped (quasi-)Newton ascent on the per-node objective
    (Eq. 8).

    Returns ``(newton_start, refactor, newton_iter)`` jitted closures; the
    caller drives the outer iteration from the host so it can stop the
    whole level as soon as every node has retired (slope ≤ 0, line-search
    grid exhausted, step below ``newton_tol``, or objective plateau),
    calling ``refactor`` every ``_HESS_EVERY`` iterations.

    The iteration is built to touch (N,)-sized data as few times as
    possible: the per-point logit ``z = xb·θ[seg]`` is carried across
    iterations (updated as ``z + t·dz``), the whole Armijo grid is
    evaluated from one ``dz`` pass (no re-gathers per trial step), and
    directions are a single batched matvec against the cached inverse
    Hessian. ``outer`` is the flattened (N, d²) ``xb⊗xb`` table —
    constant across the whole fit, precomputed once.
    """
    eye = jnp.eye(d, dtype=jnp.float32)
    tgrid = (0.5 ** jnp.arange(_LS_GRID, dtype=jnp.float32))  # (T,)

    @jax.jit
    def newton_start(theta, xb, zeta, wgt, seg, frozen):
        z = jnp.sum(xb * theta[seg], axis=-1)
        per = jax.ops.segment_sum(wgt * jax.nn.log_sigmoid(zeta * z), seg,
                                  num_segments=nseg)
        obj = per - reg * jnp.sum(theta * theta, axis=-1)
        active = ~frozen
        return z, obj, active, jnp.any(active)

    @jax.jit
    def refactor(z, outer, zeta, wgt, seg):
        s = jax.nn.sigmoid(jnp.clip(zeta * z, -60.0, 60.0))
        hcoef = wgt * s * (1.0 - s)
        hess = (seg2(hcoef[:, None] * outer, seg, nseg).reshape(nseg, d, d)
                + (2.0 * reg + 1e-10) * eye)
        return batched_inv_psd(hess)

    @jax.jit
    def newton_iter(theta, z, obj, active, inv, xb, zeta, wgt, seg):
        s = jax.nn.sigmoid(jnp.clip(zeta * z, -60.0, 60.0))
        gcoef = wgt * zeta * (1.0 - s)
        grad = seg2(gcoef[:, None] * xb, seg, nseg) - 2.0 * reg * theta
        direction = jnp.einsum("nij,nj->ni", inv, grad)
        slope = jnp.sum(grad * direction, axis=-1)
        act = active & jnp.isfinite(slope) & (slope > 0.0)

        # Whole Armijo grid from one directional-logit pass.
        dz = jnp.sum(xb * direction[seg], axis=-1)              # (N,)
        zc = z[:, None] + tgrid[None, :] * dz[:, None]          # (N, T)
        per = jax.ops.segment_sum(
            wgt[:, None] * jax.nn.log_sigmoid(zeta[:, None] * zc), seg,
            num_segments=nseg)                                  # (nseg, T)
        # ‖θ + t·d‖² expanded to three per-node scalars (avoids the
        # (nseg, T, d) candidate tensor).
        th_sq = jnp.sum(theta * theta, -1)
        th_d = jnp.sum(theta * direction, -1)
        d_sq = jnp.sum(direction * direction, -1)
        objc = per - reg * (th_sq[:, None]
                            + 2.0 * tgrid[None, :] * th_d[:, None]
                            + (tgrid ** 2)[None, :] * d_sq[:, None])
        ok = objc >= obj[:, None] + 1e-4 * tgrid[None, :] * slope[:, None]
        found = jnp.any(ok, axis=-1)
        first = jnp.argmax(ok, axis=-1)                  # first accepted t
        t = jnp.where(found, tgrid[first], 0.0)
        obj_new = jnp.take_along_axis(objc, first[:, None], 1)[:, 0]
        act = act & found
        upd = act & found
        theta = jnp.where(upd[:, None], theta + t[:, None] * direction,
                          theta)
        z = jnp.where(upd[seg], z + t[seg] * dz, z)
        new_obj = jnp.where(upd, obj_new, obj)
        step_inf = jnp.max(jnp.abs(t[:, None] * direction), axis=-1)
        act = act & (step_inf >= newton_tol)
        # fp32 plateau stop: once the accepted step no longer moves the
        # objective by a relative 1e-6, further iterations only crawl on
        # rounding noise — retire the node.
        act = act & ((new_obj - obj) >= 1e-6 * (jnp.abs(obj) + 1.0))
        return theta, z, new_obj, act, jnp.any(act)

    return newton_start, refactor, newton_iter


class _LevelPieces:
    """Jitted per-level building blocks; one instance per
    (N, C_pad, k, level) problem shape. ``num_labels`` is a *traced*
    argument so subtree fits with varying real-label counts share these
    compiled pieces."""

    def __init__(self, n: int, c_pad: int, k: int,
                 level: int, cfg_key: Tuple):
        reg, _, max_newton, newton_tol, use_kernel = cfg_key
        depth = c_pad.bit_length() - 1
        shift = depth - level
        nseg = 1 << level
        m = c_pad >> level
        half = m >> 1
        d = k + 1
        seg2 = _seg_sum_fn(use_kernel)
        self.nseg, self.m = nseg, m
        slots = jnp.arange(c_pad, dtype=jnp.int32)
        node_of_slot = slots >> shift
        (self.newton_start, self.refactor,
         self.newton_iter) = make_newton_pieces(
            nseg, d, reg, max_newton, newton_tol, seg2)

        @jax.jit
        def prep(y, wgt, perm, slot_of_label, num_labels):
            node_of_point = (slot_of_label >> shift)[y]
            is_pad_slot = perm >= num_labels
            n_real = jax.ops.segment_sum(
                (~is_pad_slot).astype(jnp.float32), node_of_slot,
                num_segments=nseg)
            # Count only positively-weighted points: zero-weight rows are
            # no-ops in every reduction (the subtree fitters pad point
            # counts to pow-2 buckets with weight-0 rows).
            npts = jax.ops.segment_sum((wgt > 0).astype(jnp.float32),
                                       node_of_point, num_segments=nseg)
            # Trivial nodes (all padding, or real labels but no data) keep
            # the natural slot-order split and never iterate.
            trivial = (n_real == 0) | (npts == 0)
            natural = (slots & (m - 1)) >= half
            split0 = jnp.where(trivial[node_of_slot], natural, False)
            return dict(node_of_point=node_of_point,
                        is_pad_slot=is_pad_slot, n_real=n_real,
                        trivial=trivial, split0=split0)

        @jax.jit
        def init_theta(s_lab, perm, trivial, v0, v_restart):
            # Per-node dominant eigvec of the centered per-label
            # feature-sum matrix (power iteration, batched over nodes).
            s_slot = s_lab[perm]
            mean = jax.ops.segment_sum(
                s_slot, node_of_slot, num_segments=nseg) / float(m)
            sc = s_slot - mean[node_of_slot]

            def pi_body(_, v):
                t = jnp.sum(sc * v[node_of_slot], axis=-1)
                u = jax.ops.segment_sum(t[:, None] * sc, node_of_slot,
                                        num_segments=nseg)
                nrm = jnp.linalg.norm(u, axis=-1, keepdims=True)
                return jnp.where(nrm < 1e-12, 0.01 * v_restart,
                                 u / jnp.maximum(nrm, 1e-30))

            v = v0 / (jnp.linalg.norm(v0, axis=-1, keepdims=True) + 1e-12)
            v = jax.lax.fori_loop(0, 20, pi_body, v)
            theta0 = jnp.concatenate(
                [v, jnp.zeros((nseg, 1), jnp.float32)], axis=-1)
            return jnp.where(trivial[:, None], 0.0, theta0)

        @jax.jit
        def discrete(theta, split, split_prev, frozen, xb, y, wgt, perm,
                     slot_of_label, node_of_point, is_pad_slot):
            # Δ_y = Σ_{x∈D_y} (w·x + b) (Eq. 9); top half goes right.
            z = jnp.sum(xb * theta[node_of_point], axis=-1)
            delta = seg2((wgt * z)[:, None], y, c_pad)[:, 0]
            delta_slot = jnp.where(is_pad_slot, -jnp.inf, delta[perm])
            o1 = jnp.argsort(-delta_slot, stable=True)
            order = o1[jnp.argsort(node_of_slot[o1], stable=True)]
            new_split = jnp.zeros((c_pad,), bool).at[order].set(
                (slots & (m - 1)) < half)
            new_split = jnp.where(frozen[node_of_slot], split, new_split)
            # Freeze on a stable partition (the reference's per-node break)
            # OR on a 2-cycle (new == two alternations ago): oscillating
            # nodes would otherwise burn every remaining alternation
            # flip-flopping between two equal-quality partitions.
            changed1 = jax.ops.segment_sum(
                (new_split != split).astype(jnp.int32), node_of_slot,
                num_segments=nseg) > 0
            changed2 = jax.ops.segment_sum(
                (new_split != split_prev).astype(jnp.int32), node_of_slot,
                num_segments=nseg) > 0
            frozen = frozen | ~changed1 | ~changed2
            side_pt = new_split[slot_of_label][y]
            zeta = jnp.where(side_pt, 1.0, -1.0).astype(jnp.float32)
            return new_split, frozen, zeta, jnp.all(frozen)

        @jax.jit
        def finalize(theta, split, perm, is_pad_slot, n_real):
            # Force decisions away from padding-only children (paper §3).
            right_real = jax.ops.segment_sum(
                (~is_pad_slot & split).astype(jnp.float32), node_of_slot,
                num_segments=nseg)
            left_real = jax.ops.segment_sum(
                (~is_pad_slot & ~split).astype(jnp.float32), node_of_slot,
                num_segments=nseg)
            has_real = n_real > 0
            w_lvl, b_lvl = theta[:, :k], theta[:, k]
            force = (right_real == 0) | ((left_real == 0) & has_real)
            w_lvl = jnp.where(force[:, None], 0.0, w_lvl)
            b_lvl = jnp.where(right_real == 0, -PAD_LOGIT, b_lvl)
            b_lvl = jnp.where((left_real == 0) & has_real, PAD_LOGIT,
                              b_lvl)
            # Permute slots: left-side labels first, stable within side
            # (matches the reference's concat([lab[~ζ], lab[ζ]]) order).
            o1p = jnp.argsort(split.astype(jnp.int32), stable=True)
            order2 = o1p[jnp.argsort(node_of_slot[o1p], stable=True)]
            new_perm = perm[order2]
            new_slot = jnp.zeros((c_pad,), jnp.int32).at[new_perm].set(
                slots)
            theta_out = jnp.concatenate([w_lvl, b_lvl[:, None]], axis=-1)
            return theta_out, new_perm, new_slot

        self.prep, self.init_theta = prep, init_theta
        self.discrete, self.finalize = discrete, finalize


@functools.lru_cache(maxsize=512)
def _get_pieces(n: int, c_pad: int, k: int, level: int,
                cfg_key: Tuple) -> _LevelPieces:
    return _LevelPieces(n, c_pad, k, level, cfg_key)


def _compact(n_total: int, idx: np.ndarray, xb, outer, zeta, wgt, seg):
    """Gather the points of still-active nodes into a padded pow-4 bucket.

    Newton sweeps then touch only those points: segment sums are over the
    same point subsequence in the same order, so active nodes' statistics
    are bit-identical to the uncompacted sweep, while frozen nodes' points
    stop costing O(N) per iteration. Padding rows carry weight 0 (they
    contribute exactly 0 to every reduction). Pow-4 buckets bound the
    number of jit retraces to ≤ 4 per level.
    """
    n_b = n_total
    while n_b // 4 >= max(len(idx), 1024):
        n_b //= 4
    if n_b >= n_total:
        return None
    pad = n_b - len(idx)
    idx_j = jnp.asarray(np.concatenate([idx, np.zeros(pad, np.int64)]),
                        jnp.int32)
    valid = jnp.arange(n_b) < len(idx)
    return (jnp.take(xb, idx_j, 0), jnp.take(outer, idx_j, 0),
            jnp.take(zeta, idx_j, 0),
            jnp.where(valid, jnp.take(wgt, idx_j, 0), 0.0),
            jnp.take(seg, idx_j, 0))


# Intermediate (capped) Newton solves subsample shallow levels down to
# this many points per node: a split hyperplane fitted on 4k points is
# statistically indistinguishable from one fitted on 128k, and the final
# partition is polished on the full data anyway.
_SUB_TARGET = 4096


def run_newton(newton_pieces, theta, frozen, xb, outer, zeta, wgt, seg,
               seg_host: np.ndarray, max_newton: int,
               subsample_target: int = 0):
    """Drive one batched Newton solve from the host: compact away frozen
    nodes' points, then iterate (refreshing the Hessian factor every
    ``_HESS_EVERY`` steps) until every node retires or ``max_newton``.

    ``subsample_target > 0`` stride-samples the active points down to
    ~``subsample_target`` per node (weights scaled by the stride so the
    data/ridge balance is preserved) — used for intermediate alternation
    solves at shallow levels, never for the polish.
    """
    newton_start, refactor, newton_iter = newton_pieces
    n_total = seg_host.shape[0]
    active_pts = ~np.asarray(frozen)[seg_host]
    idx = np.nonzero(active_pts)[0]
    stride = 1
    if subsample_target:
        # Level width (not the active-node count) keeps the stride
        # deterministic and conservative.
        stride = max(1, len(idx) // (int(frozen.shape[0])
                                     * subsample_target))
    packed = None
    if stride > 1:
        packed = _compact(n_total, idx[::stride], xb, outer, zeta,
                          wgt * np.float32(stride), seg)
    if packed is None:
        packed = _compact(n_total, idx, xb, outer, zeta, wgt, seg)
    xb_a, outer_a, zeta_a, wgt_a, seg_a = (
        packed if packed is not None else (xb, outer, zeta, wgt, seg))
    z, obj, active, any_active = newton_start(
        theta, xb_a, zeta_a, wgt_a, seg_a, frozen)
    it = 0
    inv = None
    while bool(any_active) and it < max_newton:
        if it % _HESS_EVERY == 0:
            inv = refactor(z, outer_a, zeta_a, wgt_a, seg_a)
        theta, z, obj, active, any_active = newton_iter(
            theta, z, obj, active, inv, xb_a, zeta_a, wgt_a, seg_a)
        it += 1
    return theta


def _run_level(pieces: _LevelPieces, xb, outer, y, wgt, s_lab, perm,
               slot_of_label, num_labels, v0, v_restart, cfg_key: Tuple):
    """Host-driven alternation for one level: discrete re-partition, then
    batched Newton until every node retires (early exit on host)."""
    _, max_alt, max_newton, _, _ = cfg_key
    aux = pieces.prep(y, wgt, perm, slot_of_label, num_labels)
    theta = pieces.init_theta(s_lab, perm, aux["trivial"], v0, v_restart)
    split, frozen = aux["split0"], aux["trivial"]
    split_prev = split
    seg = aux["node_of_point"]
    seg_host = np.asarray(seg)
    newton_pieces = (pieces.newton_start, pieces.refactor,
                     pieces.newton_iter)
    zeta = None
    for _ in range(max_alt):
        new_split, frozen, zeta, all_frozen = pieces.discrete(
            theta, split, split_prev, frozen, xb, y, wgt, perm,
            slot_of_label, seg, aux["is_pad_slot"])
        split_prev, split = split, new_split
        if bool(all_frozen):
            break
        # Capped solve: intermediate alternations only need improvement.
        theta = run_newton(newton_pieces, theta, frozen, xb, outer, zeta,
                           wgt, seg, seg_host,
                           min(_ALT_NEWTON, max_newton),
                           subsample_target=_SUB_TARGET)
    if zeta is not None:
        # Full-precision polish of every data-carrying node on the final
        # partition (the capped intermediate solves leave theta improved
        # but not converged).
        theta = run_newton(newton_pieces, theta, aux["trivial"], xb,
                           outer, zeta, wgt, seg, seg_host, max_newton)
    return pieces.finalize(theta, split, perm, aux["is_pad_slot"],
                           aux["n_real"])


def _prep_data(features, labels, num_labels, sample_weight):
    x = np.asarray(features, np.float32)
    y = np.asarray(labels, np.int64)
    assert x.ndim == 2 and y.shape == (x.shape[0],)
    assert y.size == 0 or (0 <= y.min() and y.max() < num_labels)
    wgt = (np.ones(len(y), np.float32) if sample_weight is None
           else np.asarray(sample_weight, np.float32))
    return x, y, wgt


def _fit_levels(x, y, wgt, num_labels: int, c_pad: int, cfg: FitConfig,
                n_levels: int, perm0=None):
    """Run the level sweep for ``n_levels`` levels from the root.

    Returns host arrays ``(w_all, b_all, perm, slot_of_label)`` with node
    rows beyond the fitted levels left at zero (the sharded fitter fills
    them from subtree fits).
    """
    k = x.shape[1]
    key = _cfg_key(cfg)
    rng = np.random.default_rng(cfg.seed)

    xj = jnp.asarray(x, jnp.float32)
    xb = jnp.concatenate([xj, jnp.ones((x.shape[0], 1), jnp.float32)],
                         axis=-1)
    d = k + 1
    # xb⊗xb, flattened: the Hessian's per-point table, constant across the
    # whole fit — computed once instead of once per Newton iteration.
    outer = (xb[:, :, None] * xb[:, None, :]).reshape(-1, d * d)
    yj = jnp.asarray(y, jnp.int32)
    wj = jnp.asarray(wgt, jnp.float32)
    # Per-label weighted feature sums: level-independent, computed once.
    s_lab = jax.ops.segment_sum(xj * wj[:, None], yj, num_segments=c_pad)
    perm = (jnp.arange(c_pad, dtype=jnp.int32) if perm0 is None
            else jnp.asarray(perm0, jnp.int32))
    slot_of_label = jnp.zeros((c_pad,), jnp.int32).at[perm].set(
        jnp.arange(c_pad, dtype=jnp.int32))

    w_all = np.zeros((c_pad - 1, k), np.float32)
    b_all = np.zeros((c_pad - 1,), np.float32)
    for level in range(n_levels):
        pieces = _get_pieces(x.shape[0], c_pad, k, level, key)
        n_lvl = 1 << level
        v0 = jnp.asarray(rng.standard_normal((n_lvl, k)), jnp.float32)
        v_restart = jnp.asarray(rng.standard_normal((n_lvl, k)),
                                jnp.float32)
        theta, perm, slot_of_label = _run_level(
            pieces, xb, outer, yj, wj, s_lab, perm, slot_of_label,
            jnp.int32(num_labels), v0, v_restart, key)
        th = np.asarray(theta)
        w_all[n_lvl - 1:2 * n_lvl - 1] = th[:, :k]
        b_all[n_lvl - 1:2 * n_lvl - 1] = th[:, k]
    return (w_all, b_all, np.array(perm, np.int64),
            np.array(slot_of_label, np.int64))


def pack_tree(w_all, b_all, perm, num_labels: int) -> Tree:
    """Assemble a :class:`Tree` from level arrays + final slot
    permutation (``perm[leaf] = label``, padding ids ≥ num_labels)."""
    from repro.core.tree import validate

    label_to_leaf = np.zeros((num_labels,), np.int64)
    label_to_leaf[perm[perm < num_labels]] = np.nonzero(
        perm < num_labels)[0]
    leaf_to_label = np.where(perm < num_labels, perm, 0)
    return validate(Tree(
        w=jnp.asarray(w_all, jnp.float32),
        b=jnp.asarray(b_all, jnp.float32),
        label_to_leaf=jnp.asarray(label_to_leaf, jnp.int32),
        leaf_to_label=jnp.asarray(leaf_to_label, jnp.int32),
    ), num_labels)


def fit_tree_levelwise(features, labels, num_labels: int,
                       sample_weight=None,
                       config: Optional[FitConfig] = None,
                       c_pad: Optional[int] = None) -> Tree:
    """Level-parallel fit — same objective/partition rules as
    :func:`repro.core.tree_fit.fit_tree`, O(log C) sequential phases.

    ``c_pad`` forces the padded leaf count (a power of two
    ≥ ``padded_size(num_labels)``); the sharded/incremental fitters use it
    to fit subtrees whose leaf count exceeds their real-label count.
    """
    cfg = config or FitConfig()
    x, y, wgt = _prep_data(features, labels, num_labels, sample_weight)
    c_pad = c_pad or padded_size(num_labels)
    assert c_pad >= padded_size(num_labels) and (c_pad & (c_pad - 1)) == 0
    depth = c_pad.bit_length() - 1
    w_all, b_all, perm, _ = _fit_levels(x, y, wgt, num_labels, c_pad, cfg,
                                        n_levels=depth)
    return pack_tree(w_all, b_all, perm, num_labels)
