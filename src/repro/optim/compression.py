"""Compressed numeric storage: int8 gradient compression with error
feedback (DP all-reduce) and quantized optimizer-accumulator storage
(DESIGN.md §11).

Gradient compression: at 1000+ nodes the gradient all-reduce is the
dominant inter-pod collective; 8-bit quantization cuts its bytes 4x (fp32)
/ 2x (bf16). Error feedback (Seide et al. 2014; Karimireddy et al. 2019
"EF-SGD") accumulates the quantization residual locally and re-injects it
next step, preserving convergence (tested in tests/test_compression.py).
`compress -> (psum over data axes) -> decompress` is linear, so quantized
all-reduce == all-reduce of quantized values; the shard_map wiring lives in
repro.parallel.collectives.

Accumulator storage: at C = 100M labels the (C, K) fp32 optimizer slabs —
not the gradient — are the memory wall. ``store_rows`` / ``load_rows``
convert between fp32 *compute* values and a compact *storage*
representation: plain bf16 arrays (2 bytes/elt, ~3 decimal digits — enough
for second moments whose only job is a sqrt-denominator), or
:class:`QuantizedRows` (int8 payload + fp32 per-row scale, 1 byte/elt).
All optimizer math stays fp32; quantization happens only at the
gather/scatter boundary, so it composes with the sparse O(touched-rows)
update path unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: Any      # residual pytree, same structure as grads


def init_ef_state(grads_like: Any) -> EFState:
    return EFState(error=jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_error_feedback(grads: Any, ef: EFState
                                 ) -> Tuple[Any, Any, EFState]:
    """Returns (q_tree int8, scale_tree, new_ef). The caller all-reduces the
    int8 payload (plus the tiny scale scalars) and divides by the replica
    count after decompression."""
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, ef.error)
    qs = jax.tree.map(_quantize_leaf, corrected)
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_error = jax.tree.map(
        lambda c, q, s: c - _dequantize_leaf(q, s), corrected, q_tree,
        s_tree)
    return q_tree, s_tree, EFState(error=new_error)


def decompress(q_tree: Any, s_tree: Any) -> Any:
    return jax.tree.map(_dequantize_leaf, q_tree, s_tree)


# ---------------------------------------------------------------------------
# Quantized optimizer-state storage (bf16 / int8 + per-row scale).
# ---------------------------------------------------------------------------


class QuantizedRows(NamedTuple):
    """int8 storage for a (C, ...) accumulator: per-row symmetric scale.

    q:     (C, ...) int8 payload.
    scale: (C,) fp32, ``x ≈ q * scale[row]``. Rows of all zeros carry
           scale 1 so dequantization is always well-defined.
    """
    q: jax.Array
    scale: jax.Array


def is_quantized_rows(x) -> bool:
    return isinstance(x, QuantizedRows)


def quantize_rows(x: jax.Array) -> QuantizedRows:
    """Symmetric per-row (leading-axis) int8 quantization, fp32 in."""
    x32 = x.astype(jnp.float32)
    axes = tuple(range(1, x32.ndim))
    amax = jnp.max(jnp.abs(x32), axis=axes) if axes else jnp.abs(x32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    s_full = scale.reshape(scale.shape + (1,) * len(axes))
    q = jnp.clip(jnp.round(x32 / s_full), -127, 127).astype(jnp.int8)
    return QuantizedRows(q=q, scale=scale)


def dequantize_rows(qr: QuantizedRows) -> jax.Array:
    s_full = qr.scale.reshape(
        qr.scale.shape + (1,) * (qr.q.ndim - qr.scale.ndim))
    return qr.q.astype(jnp.float32) * s_full


def store_rows(x32: jax.Array, state_dtype: str) -> Any:
    """fp32 compute value -> storage representation.

    state_dtype: "fp32" (identity), "bf16" (plain bf16 array), or "int8"
    (QuantizedRows). 1-D leaves under int8 fall back to bf16: a per-row
    scale on a (C,) vector is a scale per *element* — all cost, no
    compression win over bf16.
    """
    if state_dtype == "fp32":
        return x32
    if state_dtype == "bf16" or (state_dtype == "int8" and x32.ndim < 2):
        return x32.astype(jnp.bfloat16)
    if state_dtype == "int8":
        return quantize_rows(x32)
    raise ValueError(f"unknown state_dtype {state_dtype!r}")


def load_rows(x: Any) -> jax.Array:
    """Storage representation -> fp32 compute value."""
    if isinstance(x, QuantizedRows):
        return dequantize_rows(x)
    return x.astype(jnp.float32)
