"""int8 gradient compression with error feedback, for the DP all-reduce.

At 1000+ nodes the gradient all-reduce is the dominant inter-pod collective;
8-bit quantization cuts its bytes 4x (fp32) / 2x (bf16). Error feedback
(Seide et al. 2014; Karimireddy et al. 2019 "EF-SGD") accumulates the
quantization residual locally and re-injects it next step, preserving
convergence (tested in tests/test_compression.py).

`compress -> (psum over data axes) -> decompress` is linear, so quantized
all-reduce == all-reduce of quantized values; the shard_map wiring lives in
repro.parallel.collectives.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: Any      # residual pytree, same structure as grads


def init_ef_state(grads_like: Any) -> EFState:
    return EFState(error=jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_error_feedback(grads: Any, ef: EFState
                                 ) -> Tuple[Any, Any, EFState]:
    """Returns (q_tree int8, scale_tree, new_ef). The caller all-reduces the
    int8 payload (plus the tiny scale scalars) and divides by the replica
    count after decompression."""
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, ef.error)
    qs = jax.tree.map(_quantize_leaf, corrected)
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_error = jax.tree.map(
        lambda c, q, s: c - _dequantize_leaf(q, s), corrected, q_tree,
        s_tree)
    return q_tree, s_tree, EFState(error=new_error)


def decompress(q_tree: Any, s_tree: Any) -> Any:
    return jax.tree.map(_dequantize_leaf, q_tree, s_tree)
