"""SparseRows: the O(B·K·n_neg) head-gradient carrier (DESIGN.md §8).

A sampled-head training step touches at most ``B·(1 + n_neg)`` rows of the
(C, K) output embedding, yet dense autodiff materializes the full (C, K)
gradient (the candidate-score gather backprops as a scatter-add into a
zero-initialized dense array) and the optimizer then walks every row.
``SparseRows`` replaces that dense leaf in the gradient pytree: deduplicated
touched-row ids plus the per-row ``(dw, db)`` coefficients, so the optimizer
can apply O(U·K) row updates (repro.optim.optimizers) and the whole update
cost is independent of C.

Invariants:
  * ``ids`` are unique; slots beyond the number of distinct touched rows
    carry the sentinel ``num_rows`` (out of range — every consumer scatters
    with ``mode="drop"`` / relies on their zero coefficients).
  * duplicate occurrences (a negative drawn twice, or colliding with the
    positive) have been *summed* into one row, so ``to_dense`` equals the
    dense autodiff gradient and ``sq_norm`` is the true global-norm
    contribution (untouched rows have exactly zero dense gradient).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class SparseRows(NamedTuple):
    """Sparse row gradient: ``dL/dw[ids] = dw``, ``dL/db[ids] = db``.

    ids: (U,) int32, unique; sentinel ``num_rows`` marks dead slots.
    dw:  (U, K) fp32 row gradients (zero on dead slots).
    db:  (U,)   fp32 bias gradients (zero on dead slots), or None when
         the table has no bias vector (the input-embedding gather).
    """
    ids: jax.Array
    dw: jax.Array
    db: Optional[jax.Array] = None

    @property
    def num_rows_hint(self) -> int:
        # Sentinel value == the row count the producer saw; only used by
        # tests/debug helpers (consumers scatter with mode="drop").
        return int(self.ids.shape[0])


def is_sparse(x) -> bool:
    return isinstance(x, SparseRows)


def accumulate_rows(ids: jax.Array, coeff: jax.Array, h: jax.Array,
                    num_rows: int) -> SparseRows:
    """Dedupe occurrence-level gradients into per-unique-row sums.

    Per-occurrence t the head gradient is a rank-1 term
    ``dL/dw[ids[t]] += coeff[t] * h[t]``, ``dL/db[ids[t]] += coeff[t]``.
    ids: (T,) int32 (duplicates allowed); coeff: (T,); h: (T, K);
    ``num_rows`` = row count of the dense table (the sentinel id).

    Returns a SparseRows with U = T slots (the static worst case); unused
    slots carry id ``num_rows`` and zero coefficients, so the result is
    exactly the dense gradient restricted to its nonzero rows.
    """
    t = ids.shape[0]
    uniq, inv = jnp.unique(ids.astype(jnp.int32), size=t,
                           fill_value=num_rows, return_inverse=True)
    inv = inv.reshape(-1)
    coeff = coeff.astype(jnp.float32)
    db = jax.ops.segment_sum(coeff, inv, num_segments=t)
    dw = jax.ops.segment_sum(coeff[:, None] * h.astype(jnp.float32), inv,
                             num_segments=t)
    return SparseRows(ids=uniq.astype(jnp.int32), dw=dw, db=db)


def accumulate_embed_rows(ids: jax.Array, dh: jax.Array,
                          num_rows: int) -> SparseRows:
    """Dedupe per-occurrence embedding cotangents into per-row sums.

    The input-embedding gather ``h0 = embed[tokens]`` backprops as a
    scatter-add of the cotangent rows ``dh`` into the touched token rows —
    the same shape of computation as the head, minus the bias and the
    rank-1 structure. ids: (T,) int32 token ids (duplicates allowed);
    dh: (T, K) cotangent rows. Returns a bias-free SparseRows (db=None).
    """
    t = ids.shape[0]
    uniq, inv = jnp.unique(ids.astype(jnp.int32), size=t,
                           fill_value=num_rows, return_inverse=True)
    inv = inv.reshape(-1)
    dw = jax.ops.segment_sum(dh.astype(jnp.float32), inv, num_segments=t)
    return SparseRows(ids=uniq.astype(jnp.int32), dw=dw, db=None)


def to_dense(sparse: SparseRows, w_shape: Tuple[int, ...]
             ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Materialize the (C, K) / (C,) dense gradients (tests/fallbacks)."""
    c = w_shape[0]
    dw = jnp.zeros(w_shape, jnp.float32).at[sparse.ids].add(
        sparse.dw, mode="drop")
    if sparse.db is None:
        return dw, None
    db = jnp.zeros((c,), jnp.float32).at[sparse.ids].add(
        sparse.db, mode="drop")
    return dw, db


def sq_norm(sparse: SparseRows) -> jax.Array:
    """Sum of squares == the dense gradient's (rows are deduped)."""
    sq = jnp.sum(jnp.square(sparse.dw))
    if sparse.db is not None:
        sq = sq + jnp.sum(jnp.square(sparse.db))
    return sq


def scale(sparse: SparseRows, s: jax.Array) -> SparseRows:
    return SparseRows(ids=sparse.ids, dw=sparse.dw * s,
                      db=None if sparse.db is None else sparse.db * s)
