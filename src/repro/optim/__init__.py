from repro.optim.compression import (EFState, QuantizedRows,
                                     compress_with_error_feedback,
                                     decompress, dequantize_rows,
                                     init_ef_state, load_rows,
                                     quantize_rows, store_rows)
from repro.optim.optimizers import (OptimizerConfig, OptState, Sm3Cover,
                                    apply_updates, clip_by_global_norm,
                                    global_norm, head_state_bytes,
                                    init_opt_state, schedule, tree_nbytes)
from repro.optim.sparse import (SparseRows, accumulate_embed_rows,
                                accumulate_rows)

__all__ = ["EFState", "QuantizedRows", "compress_with_error_feedback",
           "decompress", "dequantize_rows", "init_ef_state", "load_rows",
           "quantize_rows", "store_rows", "OptimizerConfig", "OptState",
           "Sm3Cover", "apply_updates", "clip_by_global_norm",
           "global_norm", "head_state_bytes", "init_opt_state", "schedule",
           "tree_nbytes", "SparseRows", "accumulate_embed_rows",
           "accumulate_rows"]
