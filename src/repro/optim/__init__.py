from repro.optim.compression import (EFState, compress_with_error_feedback,
                                     decompress, init_ef_state)
from repro.optim.optimizers import (OptimizerConfig, OptState, apply_updates,
                                    clip_by_global_norm, global_norm,
                                    init_opt_state, schedule)
from repro.optim.sparse import SparseRows, accumulate_rows

__all__ = ["EFState", "compress_with_error_feedback", "decompress",
           "init_ef_state", "OptimizerConfig", "OptState", "apply_updates",
           "clip_by_global_norm", "global_norm", "init_opt_state",
           "schedule", "SparseRows", "accumulate_rows"]
