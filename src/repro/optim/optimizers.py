"""Optimizers (pure-jax, optax-free): Adagrad (paper §5), AdamW, global-norm
clipping, LR schedules. State is a pytree mirroring params, so it inherits
param sharding under pjit (ZeRO-style optimizer-state sharding for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
Grads = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adagrad"           # adagrad | adamw | sgd
    learning_rate: float = 0.01
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    adagrad_init: float = 0.0       # initial accumulator (paper uses 0)
    clip_norm: float = 0.0          # 0 = off
    warmup_steps: int = 0
    decay_steps: int = 0            # cosine decay horizon; 0 = constant


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # 1st moment (adamw) or None
    nu: Any          # 2nd moment / adagrad accumulator


def init_opt_state(cfg: OptimizerConfig, params: Params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
    if cfg.name == "adamw":
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))
    if cfg.name == "adagrad":
        return OptState(step=jnp.zeros((), jnp.int32), mu=None,
                        nu=jax.tree.map(
                            lambda p: jnp.full_like(
                                p, cfg.adagrad_init, jnp.float32), params))
    if cfg.name == "sgd":
        return OptState(step=jnp.zeros((), jnp.int32), mu=None, nu=None)
    raise ValueError(cfg.name)


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    lr = jnp.asarray(cfg.learning_rate, jnp.float32)
    s = step.astype(jnp.float32)
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, (s + 1.0) / cfg.warmup_steps)
    if cfg.decay_steps:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(1, cfg.decay_steps - cfg.warmup_steps), 0, 1)
        lr = lr * (0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
    return lr


def global_norm(grads: Grads) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Grads, max_norm: float
                        ) -> Tuple[Grads, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(cfg: OptimizerConfig, params: Params, grads: Grads,
                  state: OptState) -> Tuple[Params, OptState, dict]:
    """One optimizer step. Returns (params, state, metrics)."""
    metrics = {}
    if cfg.clip_norm:
        grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = norm
    lr = schedule(cfg, state.step)
    metrics["lr"] = lr

    if cfg.name == "adagrad":
        nu = jax.tree.map(
            lambda n, g: n + jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        updates = jax.tree.map(
            lambda g, n: -lr * g.astype(jnp.float32)
            / (jnp.sqrt(n) + cfg.eps), grads, nu)
        new_state = OptState(step=state.step + 1, mu=None, nu=nu)
    elif cfg.name == "adamw":
        t = (state.step + 1).astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: cfg.beta1 * m
            + (1 - cfg.beta1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda n, g: cfg.beta2 * n
            + (1 - cfg.beta2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1.0 - cfg.beta1 ** t
        bc2 = 1.0 - cfg.beta2 ** t
        updates = jax.tree.map(
            lambda m, n, p: -lr * ((m / bc1)
                                   / (jnp.sqrt(n / bc2) + cfg.eps)
                                   + cfg.weight_decay
                                   * p.astype(jnp.float32)),
            mu, nu, params)
        new_state = OptState(step=state.step + 1, mu=mu, nu=nu)
    elif cfg.name == "sgd":
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        new_state = OptState(step=state.step + 1, mu=None, nu=None)
    else:
        raise ValueError(cfg.name)

    new_params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)
    return new_params, new_state, metrics
