"""Optimizers (pure-jax, optax-free): Adagrad (paper §5), AdamW, SM3,
global-norm clipping, LR schedules. State is a pytree mirroring params, so
it inherits param sharding under pjit (ZeRO-style optimizer-state sharding
for free).

Gradient pytrees may carry :class:`repro.optim.sparse.SparseRows` leaves in
place of a ``{"w": (C, K), "b": (C,)}`` subtree (the sampled-head path,
DESIGN.md §8) or a bare ``(V, K)`` table (the input-embedding gather).
Those are applied as O(U·K) row updates — gather the touched rows of param
+ accumulator state, run the *same* per-leaf update math the dense path
uses, scatter back.

Memory-cheap head state (DESIGN.md §11):

* ``sm3`` keeps one (C,) row cover + one (K,) column cover instead of a
  full (C, K) second-moment slab (Anil et al., "Memory-Efficient Adaptive
  Optimization"), in the *monotone-max* variant (covers never decrease) so
  the sparse touched-rows update is exactly the dense update.
* ``state_dtype`` ("fp32" | "bf16" | "int8") selects the *storage*
  representation of head accumulators — compute is always fp32, conversion
  happens only at the gather/scatter boundary (repro.optim.compression).
  "int8" applies to first moments only; second moments always degrade to
  bf16 (:func:`_nu_sd` — linear int8 under 1/sqrt(nu) diverges).
* AdamW rows carry per-row ``last``-touched steps; rows idle for ``gap``
  steps replay their missed zero-gradient updates (momentum decay, bias
  correction, decoupled weight decay) on next touch, so lazy sparse AdamW
  matches dense AdamW (exactly up to the replay horizon, ~1e-9 beyond).

Per-leaf rules: a leaf whose path contains a component named "head" (or
every leaf, when the params tree has no "head" component — the standalone
linear-head case) uses ``head_name``/``state_dtype``; everything else uses
``name`` with fp32 state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import compression
from repro.optim import sparse as sparse_lib
from repro.optim.compression import QuantizedRows
from repro.optim.sparse import SparseRows

Params = Any
Grads = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adagrad"           # adagrad | adamw | sgd | sm3
    learning_rate: float = 0.01
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    adagrad_init: float = 0.0       # initial accumulator (paper uses 0)
    clip_norm: float = 0.0          # 0 = off
    warmup_steps: int = 0
    decay_steps: int = 0            # cosine decay horizon; 0 = constant
    head_name: Optional[str] = None  # head-leaf rule override (e.g. "sm3")
    state_dtype: str = "fp32"       # head accumulator storage: fp32|bf16|int8
    lazy_horizon: int = 0           # adamw catch-up replay cap; 0 = auto


class Sm3Cover(NamedTuple):
    """Factored second moment for a (C, K) table: ν_ij ≈ min(row_i, col_j).

    row: (C,) in the configured storage dtype (bf16 under bf16/int8 modes).
    col: (K,) fp32 always — K elements are too small to be worth shrinking,
         and the column cover is the one piece every update reads.
    """
    row: jax.Array
    col: jax.Array


_STATE_BOXES = (Sm3Cover, QuantizedRows)


def _is_state_leaf(x) -> bool:
    return x is None or isinstance(x, _STATE_BOXES)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any           # 1st moment (adamw) or None
    nu: Any           # 2nd moment / adagrad accumulator / Sm3Cover leaves
    last: Any = None  # per-row int32 last-touched step (adamw) or None


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
        else:
            names.append(str(p))
    return tuple(names)


def _is_head_path(path) -> bool:
    return "head" in _path_names(path)


def _leaf_rules(cfg: OptimizerConfig, paths):
    """Resolve (rule_name, state_dtype) per param leaf (module docstring)."""
    heads = [_is_head_path(p) for p in paths]
    any_head = any(heads)
    out = []
    for h in heads:
        is_head = h or not any_head
        out.append(((cfg.head_name or cfg.name) if is_head else cfg.name,
                    cfg.state_dtype if is_head else "fp32"))
    return out


def _state_leaves(tree, n: int):
    """Flatten a state tree into n leaves aligned with the param leaves.

    State trees mirror the params *structure* but hold None / Sm3Cover /
    QuantizedRows at leaf positions; the custom is_leaf keeps those as
    single aligned entries instead of dropping (None) or decomposing
    (NamedTuple boxes) them.
    """
    if tree is None:
        return [None] * n
    leaves = jax.tree.leaves(tree, is_leaf=_is_state_leaf)
    assert len(leaves) == n, (len(leaves), n)
    return leaves


def _nu_sd(sd: str) -> str:
    """Storage dtype for second moments: int8 degrades to bf16.

    Linear per-row int8 zeroes every entry below rowmax/127, and nu
    enters the update through 1/(sqrt(nu)+eps) — a zeroed entry turns a
    tiny accumulator into a ~1/eps step and the loss diverges within
    steps (8-bit optimizers need a nonlinear quantile map here, not a
    linear scale). First moments enter linearly and tolerate int8, so
    ``state_dtype="int8"`` means int8 mu + bf16 nu.
    """
    return "bf16" if sd == "int8" else sd


def init_opt_state(cfg: OptimizerConfig, params: Params) -> OptState:
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    rules = _leaf_rules(cfg, [p for p, _ in flat_p])
    mu, nu, last = [], [], []
    for (path, p), (name, sd) in zip(flat_p, rules):
        if name == "adamw":
            mu.append(compression.store_rows(
                jnp.zeros(p.shape, jnp.float32), sd))
            nu.append(compression.store_rows(
                jnp.zeros(p.shape, jnp.float32), _nu_sd(sd)))
            last.append(jnp.zeros(p.shape[:1], jnp.int32))
        elif name == "adagrad":
            mu.append(None)
            nu.append(compression.store_rows(
                jnp.full(p.shape, cfg.adagrad_init, jnp.float32),
                _nu_sd(sd)))
            last.append(None)
        elif name == "sm3":
            mu.append(None)
            if p.ndim == 2:
                nu.append(Sm3Cover(
                    row=compression.store_rows(
                        jnp.zeros(p.shape[:1], jnp.float32), _nu_sd(sd)),
                    col=jnp.zeros(p.shape[1:2], jnp.float32)))
            else:
                # 1-D / 3-D+ leaves: SM3's per-element cover degenerates
                # to the full Adagrad accumulator.
                nu.append(compression.store_rows(
                    jnp.zeros(p.shape, jnp.float32), _nu_sd(sd)))
            last.append(None)
        elif name == "sgd":
            mu.append(None)
            nu.append(None)
            last.append(None)
        else:
            raise ValueError(name)
    unflatten = jax.tree_util.tree_unflatten

    def pack(leaves):
        if all(x is None for x in leaves):
            return None
        return unflatten(treedef, leaves)

    return OptState(step=jnp.zeros((), jnp.int32), mu=pack(mu),
                    nu=pack(nu), last=pack(last))


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    lr = jnp.asarray(cfg.learning_rate, jnp.float32)
    s = step.astype(jnp.float32)
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, (s + 1.0) / cfg.warmup_steps)
    if cfg.decay_steps:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(1, cfg.decay_steps - cfg.warmup_steps), 0, 1)
        lr = lr * (0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
    return lr


def _norm_is_leaf(x) -> bool:
    return sparse_lib.is_sparse(x) or isinstance(x, _STATE_BOXES)


def global_norm(grads: Grads) -> jax.Array:
    """fp32 global norm over dense, SparseRows, Sm3Cover, and
    QuantizedRows leaves (quantized leaves are dequantized first, so the
    norm is of the *values*, not the int8 payload)."""
    leaves = jax.tree.leaves(grads, is_leaf=_norm_is_leaf)
    sq = []
    for g in leaves:
        if sparse_lib.is_sparse(g):
            sq.append(sparse_lib.sq_norm(g))
        elif isinstance(g, QuantizedRows):
            sq.append(jnp.sum(jnp.square(compression.dequantize_rows(g))))
        elif isinstance(g, Sm3Cover):
            sq.append(jnp.sum(jnp.square(g.row.astype(jnp.float32)))
                      + jnp.sum(jnp.square(g.col.astype(jnp.float32))))
        else:
            sq.append(jnp.sum(jnp.square(g.astype(jnp.float32))))
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))


def clip_by_global_norm(grads: Grads, max_norm: float
                        ) -> Tuple[Grads, jax.Array]:
    norm = global_norm(grads)
    scl = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    clipped = jax.tree.map(
        lambda g: sparse_lib.scale(g, scl) if sparse_lib.is_sparse(g)
        else g * scl, grads, is_leaf=sparse_lib.is_sparse)
    return clipped, norm


def _lazy_horizon(cfg: OptimizerConfig) -> int:
    """Replay depth after which the momentum term is < 1e-9 of its start
    (197 steps at beta1=0.9); beyond it the closed-form tail is applied."""
    if cfg.lazy_horizon:
        return int(cfg.lazy_horizon)
    if cfg.beta1 <= 0:
        return 0
    return min(int(math.ceil(math.log(1e-9) / math.log(cfg.beta1))), 1024)


def _rows(x: jax.Array, ndim: int) -> jax.Array:
    """Broadcast a per-row vector against an ndim-rank row block."""
    if x.ndim >= ndim:
        return x
    return x.reshape(x.shape + (1,) * (ndim - x.ndim))


def _adamw_catch_up(cfg: OptimizerConfig, lr_now, t_i, p, m, v, last,
                    live=None):
    """Replay the AdamW steps a row missed while untouched (DESIGN.md §11).

    A row idle since per-row step ``last`` missed ``gap = t-1-last``
    updates in which its gradient was exactly zero but momentum decay,
    bias correction, and decoupled weight decay still moved it. Replays
    the first min(gap, horizon) missed steps per row in one fori_loop
    whose trip count is the batch-max gap (dynamic bound — lowers to a
    while loop), then applies the closed-form tail for any remainder: by
    then the momentum term has decayed below 1e-9 of its starting value,
    so only the pure decay factors (b1^extra, b2^extra, (1-lr·wd)^extra)
    survive. Exact for gap <= horizon; the tail additionally assumes a
    constant LR over the skipped range.

    p/m/v fp32 (any rank); ``last`` int32 aligned to axis 0; ``live``
    optionally masks rows out of the replay (sharded non-owned rows).
    Returns (p, m, v) caught up to step t_i - 1.
    """
    h = _lazy_horizon(cfg)
    gap = jnp.maximum(t_i - 1 - last, 0)
    if live is not None:
        gap = jnp.where(live, gap, 0)
    nd = p.ndim

    if h > 0:
        def body(j, carry):
            p, m, v = carry
            s = (last + 1 + j).astype(jnp.float32)  # absolute step, per row
            on = _rows(j < gap, nd)
            m2 = cfg.beta1 * m
            v2 = cfg.beta2 * v
            bc1 = _rows(1.0 - cfg.beta1 ** s, nd)
            bc2 = _rows(1.0 - cfg.beta2 ** s, nd)
            d = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            lr_s = _rows(schedule(cfg, s - 1.0), nd)
            p2 = p - lr_s * (d + cfg.weight_decay * p)
            return (jnp.where(on, p2, p), jnp.where(on, m2, m),
                    jnp.where(on, v2, v))

        n_replay = jnp.minimum(jnp.max(gap), h)
        p, m, v = jax.lax.fori_loop(0, n_replay, body, (p, m, v))

    extra = _rows(jnp.maximum(gap - h, 0).astype(jnp.float32), nd)
    m = m * cfg.beta1 ** extra
    v = v * cfg.beta2 ** extra
    p = p * (1.0 - lr_now * cfg.weight_decay) ** extra
    return p, m, v


def _leaf_update(cfg: OptimizerConfig, name: str, lr, t, p, g, m, n):
    """The per-leaf update rule, shared verbatim by the dense path (whole
    arrays) and the sparse path (gathered rows): returns (p', m', n').
    m/n are fp32 compute values (already dequantized); "sm3" here is the
    non-factored degenerate case (1-D / 3-D+ leaves) == Adagrad."""
    g32 = g.astype(jnp.float32)
    if name in ("adagrad", "sm3"):
        n2 = n + jnp.square(g32)
        u = -lr * g32 / (jnp.sqrt(n2) + cfg.eps)
        m2 = None
    elif name == "adamw":
        m2 = cfg.beta1 * m + (1 - cfg.beta1) * g32
        n2 = cfg.beta2 * n + (1 - cfg.beta2) * jnp.square(g32)
        bc1 = 1.0 - cfg.beta1 ** t
        bc2 = 1.0 - cfg.beta2 ** t
        u = -lr * ((m2 / bc1) / (jnp.sqrt(n2 / bc2) + cfg.eps)
                   + cfg.weight_decay * p.astype(jnp.float32))
    elif name == "sgd":
        u = -lr * g32
        m2 = n2 = None
    else:
        raise ValueError(name)
    return (p.astype(jnp.float32) + u).astype(p.dtype), m2, n2


def _sm3_dense_update(cfg: OptimizerConfig, lr, p, g, cover: Sm3Cover,
                      sd: str):
    """Dense SM3 on a (C, K) table. ν'_ij = min(row_i, col_j) + g²_ij;
    covers take the monotone max with their previous value, which keeps
    them valid upper bounds and makes the sparse path exact (untouched
    rows have ν' <= row_i everywhere, so their cover cannot move)."""
    g32 = g.astype(jnp.float32)
    r = compression.load_rows(cover.row)
    c = cover.col
    nu = jnp.minimum(r[:, None], c[None, :]) + jnp.square(g32)
    u = -lr * g32 / (jnp.sqrt(nu) + cfg.eps)
    p2 = (p.astype(jnp.float32) + u).astype(p.dtype)
    r2 = jnp.maximum(r, nu.max(axis=1))
    c2 = jnp.maximum(c, nu.max(axis=0))
    return p2, Sm3Cover(row=compression.store_rows(r2, _nu_sd(sd)), col=c2)


def _sparse_node_update(cfg: OptimizerConfig, name: str, sd: str, lr,
                        t_f, t_i, sparse: SparseRows, leaves, ms, ns,
                        lasts, mesh=None):
    """O(U·K) row update for the leaves touched by a SparseRows grad.

    Generalized over 1-leaf (embedding table, db=None) and 2-leaf (head
    {w, b}) nodes and over plain / factored (Sm3Cover) / quantized
    (QuantizedRows) accumulator storage, plus per-row ``last`` bookkeeping
    for the exact lazy-AdamW catch-up. One gather → row math → one
    scatter covers params AND all their state in a single pass (under a
    mesh, a single shard_map — sharded_rows_update, shard-local). Row-
    indexed state (accumulators, quantized payload + per-row scale, SM3
    row cover, ``last``) rides the gather/scatter; the SM3 *column* cover
    is replicated and recombined by max (its update is a monotone max, so
    a pmax over shards is exact). Sentinel ids (== C, the dedupe fill)
    clamp on the gather and drop on the scatter; their coefficients are
    zero, and a clamped row's ν' = min(row, col) <= col can never raise
    the column cover. Returns (new_p, new_m, new_n, new_last) lists.
    """
    vals = (sparse.dw,) if len(leaves) == 1 else (sparse.dw, sparse.db)
    assert all(v is not None for v in vals), "missing sparse component"

    # Decompose per-leaf state into row-indexed arrays (gather/scatter)
    # and replicated arrays (SM3 col covers), with a python-side spec so
    # row_math can re-walk the same order inside shard_map.
    dense, reps, spec = [], [], []
    for p, m, n, l in zip(leaves, ms, ns, lasts):
        ent = {}
        dense.append(p)
        if isinstance(m, QuantizedRows):
            ent["m"] = "q"
            dense += [m.q, m.scale]
        elif m is not None:
            ent["m"] = "arr"
            dense.append(m)
        else:
            ent["m"] = None
        if isinstance(n, Sm3Cover):
            ent["n"] = "sm3"
            dense.append(n.row)
            reps.append(n.col)
        elif isinstance(n, QuantizedRows):
            ent["n"] = "q"
            dense += [n.q, n.scale]
        elif n is not None:
            ent["n"] = "arr"
            dense.append(n)
        else:
            ent["n"] = None
        ent["last"] = l is not None
        if l is not None:
            dense.append(l)
        spec.append(ent)

    def row_math(rows, vals_l, reps_in, mine):
        rows = list(rows)
        reps_in = list(reps_in)
        out_rows, out_reps = [], []
        for ent, v in zip(spec, vals_l):
            p_r = rows.pop(0)
            if ent["m"] == "q":
                mq, msc = rows.pop(0), rows.pop(0)
                m_r = mq.astype(jnp.float32) * _rows(msc, mq.ndim)
            elif ent["m"] == "arr":
                m_r = rows.pop(0).astype(jnp.float32)
            else:
                m_r = None
            n_r = c_full = None
            if ent["n"] == "sm3":
                r_r = rows.pop(0).astype(jnp.float32)
                c_full = reps_in.pop(0)
            elif ent["n"] == "q":
                nq, nsc = rows.pop(0), rows.pop(0)
                n_r = nq.astype(jnp.float32) * _rows(nsc, nq.ndim)
            elif ent["n"] == "arr":
                n_r = rows.pop(0).astype(jnp.float32)
            else:
                pass
            l_r = rows.pop(0) if ent["last"] else None

            g32 = v.astype(jnp.float32)
            if ent["n"] == "sm3":
                nu_f = (jnp.minimum(r_r[:, None], c_full[None, :])
                        + jnp.square(g32))
                u = -lr * g32 / (jnp.sqrt(nu_f) + cfg.eps)
                out_rows.append(p_r.astype(jnp.float32) + u)
                out_rows.append(jnp.maximum(r_r, nu_f.max(axis=1)))
                contrib = (nu_f if mine is None
                           else jnp.where(_rows(mine, nu_f.ndim), nu_f,
                                          0.0))
                out_reps.append(jnp.maximum(c_full, contrib.max(axis=0)))
                continue

            p32 = p_r.astype(jnp.float32)
            if name == "adamw" and l_r is not None:
                p32, m_r, n_r = _adamw_catch_up(
                    cfg, lr, t_i, p32, m_r, n_r, l_r, live=mine)
            p2, m2, n2 = _leaf_update(cfg, name, lr, t_f, p32, g32, m_r,
                                      n_r)
            out_rows.append(p2)
            if ent["m"] == "q":
                qm = compression.quantize_rows(m2)
                out_rows += [qm.q, qm.scale]
            elif ent["m"] == "arr":
                out_rows.append(m2)
            if ent["n"] == "q":
                qn = compression.quantize_rows(n2)
                out_rows += [qn.q, qn.scale]
            elif ent["n"] == "arr":
                out_rows.append(n2)
            if ent["last"]:
                out_rows.append(jnp.full_like(l_r, t_i))
        return tuple(out_rows), tuple(out_reps)

    tp = mesh.shape["model"] if mesh is not None else 1
    if mesh is not None and all(d.shape[0] % tp == 0 for d in dense):
        from repro.parallel.collectives import sharded_rows_update
        out_rows, out_reps = sharded_rows_update(
            mesh, row_math, sparse.ids, vals, dense, rep_arrays=reps,
            with_mask=True)
    else:
        rows = tuple(d[sparse.ids] for d in dense)
        new_rows, out_reps = row_math(rows, vals, tuple(reps), None)
        out_rows = tuple(
            d.at[sparse.ids].set(r.astype(d.dtype), mode="drop")
            for d, r in zip(dense, new_rows))

    out_rows = list(out_rows)
    out_reps = list(out_reps)
    new_p, new_m, new_n, new_l = [], [], [], []
    for ent in spec:
        new_p.append(out_rows.pop(0))
        if ent["m"] == "q":
            new_m.append(QuantizedRows(q=out_rows.pop(0),
                                       scale=out_rows.pop(0)))
        elif ent["m"] == "arr":
            new_m.append(out_rows.pop(0))
        else:
            new_m.append(None)
        if ent["n"] == "sm3":
            new_n.append(Sm3Cover(row=out_rows.pop(0),
                                  col=out_reps.pop(0)))
        elif ent["n"] == "q":
            new_n.append(QuantizedRows(q=out_rows.pop(0),
                                       scale=out_rows.pop(0)))
        elif ent["n"] == "arr":
            new_n.append(out_rows.pop(0))
        else:
            new_n.append(None)
        new_l.append(out_rows.pop(0) if ent["last"] else None)
    return new_p, new_m, new_n, new_l


def apply_updates(cfg: OptimizerConfig, params: Params, grads: Grads,
                  state: OptState, mesh=None) -> Tuple[Params, OptState,
                                                       dict]:
    """One optimizer step. Returns (params, state, metrics).

    ``grads`` may carry SparseRows leaves in place of a {"w", "b"} param
    subtree or a bare row table (see module docstring); ``mesh`` routes
    their row updates shard-local when the touched table is vocab-sharded
    over 'model'.
    """
    metrics = {}
    if cfg.clip_norm:
        grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = norm
    lr = schedule(cfg, state.step)
    metrics["lr"] = lr
    t_f = (state.step + 1).astype(jnp.float32)
    t_i = (state.step + 1).astype(jnp.int32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_flatten_with_path(
        grads, is_leaf=sparse_lib.is_sparse)[0]
    n_leaves = len(flat_p)
    flat_m = _state_leaves(state.mu, n_leaves)
    flat_n = _state_leaves(state.nu, n_leaves)
    flat_l = _state_leaves(state.last, n_leaves)
    rules = _leaf_rules(cfg, [p for p, _ in flat_p])
    idx_of = {path: i for i, (path, _) in enumerate(flat_p)}

    new_p = [leaf for _, leaf in flat_p]
    new_m, new_n, new_l = list(flat_m), list(flat_n), list(flat_l)
    covered = set()
    for path, g in flat_g:
        if not sparse_lib.is_sparse(g):
            i = idx_of[path]
            name, sd = rules[i]
            p_leaf = flat_p[i][1]
            if isinstance(flat_n[i], Sm3Cover):
                new_p[i], new_n[i] = _sm3_dense_update(
                    cfg, lr, p_leaf, g, flat_n[i], sd)
            else:
                m32 = (compression.load_rows(flat_m[i])
                       if flat_m[i] is not None else None)
                n32 = (compression.load_rows(flat_n[i])
                       if flat_n[i] is not None else None)
                p_in = p_leaf
                if name == "adamw" and flat_l[i] is not None:
                    p32, m32, n32 = _adamw_catch_up(
                        cfg, lr, t_i, p_leaf.astype(jnp.float32), m32,
                        n32, flat_l[i])
                    p_in = p32
                p2, m2, n2 = _leaf_update(cfg, name, lr, t_f, p_in, g,
                                          m32, n32)
                new_p[i] = p2.astype(p_leaf.dtype)
                if m2 is not None:
                    new_m[i] = compression.store_rows(m2, sd)
                if n2 is not None:
                    new_n[i] = compression.store_rows(n2, _nu_sd(sd))
                if flat_l[i] is not None:
                    new_l[i] = jnp.full_like(flat_l[i], t_i)
            covered.add(i)
            continue
        # SparseRows stands in for a {"w": (C, K), "b": (C,)} subtree
        # (2 dense leaves, matched by rank) or a bare row table (1 leaf,
        # db=None): locate by path prefix.
        sub = [idx_of[p2] for p2, _ in flat_p if p2[:len(path)] == path]
        if len(sub) == 2:
            i_w, i_b = ((sub[0], sub[1]) if flat_p[sub[0]][1].ndim == 2
                        else (sub[1], sub[0]))
            idxs = (i_w, i_b)
        else:
            assert len(sub) == 1 and g.db is None, (path, sub)
            idxs = (sub[0],)
        name, sd = rules[idxs[0]]
        p2, m2, n2, l2 = _sparse_node_update(
            cfg, name, sd, lr, t_f, t_i, g,
            tuple(flat_p[i][1] for i in idxs),
            tuple(flat_m[i] for i in idxs),
            tuple(flat_n[i] for i in idxs),
            tuple(flat_l[i] for i in idxs), mesh=mesh)
        for j, i in enumerate(idxs):
            new_p[i], new_m[i], new_n[i] = p2[j], m2[j], n2[j]
            new_l[i] = l2[j]
            covered.add(i)
    # Fail loud on a partial gradient tree (the pre-rewrite tree.map
    # raised on structure mismatch; silently frozen params would train
    # on with no error).
    if len(covered) != n_leaves:
        missing = [flat_p[i][0] for i in range(n_leaves)
                   if i not in covered]
        raise ValueError(f"grads cover {len(covered)}/{n_leaves} "
                         f"param leaves; missing {missing[:5]}")

    unflatten = jax.tree_util.tree_unflatten

    def pack(leaves, old):
        if old is None and all(x is None for x in leaves):
            return None
        return unflatten(treedef, leaves)

    new_state = OptState(step=state.step + 1,
                         mu=pack(new_m, state.mu),
                         nu=pack(new_n, state.nu),
                         last=pack(new_l, state.last))
    return unflatten(treedef, new_p), new_state, metrics


def _leaf_nbytes(x) -> int:
    """Payload bytes of a state/param leaf (boxes count their components;
    works on concrete arrays and ShapeDtypeStructs alike)."""
    if x is None:
        return 0
    if isinstance(x, _STATE_BOXES):
        return sum(_leaf_nbytes(c) for c in x)
    return int(np.prod(x.shape)) * np.dtype(jnp.dtype(x.dtype)).itemsize


def tree_nbytes(tree) -> int:
    """Total payload bytes of a pytree (None leaves free, boxes counted
    by their actual storage — int8 payload + fp32 scales, not fp32)."""
    leaves = jax.tree.leaves(tree, is_leaf=_is_state_leaf)
    return sum(_leaf_nbytes(x) for x in leaves)


def head_state_bytes(params: Params, state: Optional[OptState]) -> int:
    """Bytes held by head param + optimizer leaves (the ISSUE's
    ``train/head_state_bytes`` gauge): param storage plus mu/nu/last in
    their storage representation. Host-side helper (returns int)."""
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    heads = [_is_head_path(p) for p, _ in flat_p]
    any_head = any(heads)
    n = len(flat_p)
    flat_m = _state_leaves(state.mu, n) if state is not None else [None] * n
    flat_n = _state_leaves(state.nu, n) if state is not None else [None] * n
    flat_l = (_state_leaves(state.last, n) if state is not None
              else [None] * n)
    total = 0
    for i, ((path, p), h) in enumerate(zip(flat_p, heads)):
        if h or not any_head:
            total += (_leaf_nbytes(p) + _leaf_nbytes(flat_m[i])
                      + _leaf_nbytes(flat_n[i]) + _leaf_nbytes(flat_l[i]))
    return total
