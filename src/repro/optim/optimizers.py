"""Optimizers (pure-jax, optax-free): Adagrad (paper §5), AdamW, global-norm
clipping, LR schedules. State is a pytree mirroring params, so it inherits
param sharding under pjit (ZeRO-style optimizer-state sharding for free).

Gradient pytrees may carry :class:`repro.optim.sparse.SparseRows` leaves in
place of a ``{"w": (C, K), "b": (C,)}`` subtree (the sampled-head path,
DESIGN.md §8). Those are applied as O(U·K) row updates — gather the touched
rows of param + accumulator state, run the *same* per-leaf update math the
dense path uses, scatter back — so Adagrad/SGD match the dense update
exactly on touched rows (untouched rows have zero gradient, hence zero
dense update) while AdamW gets the standard lazy-row treatment (momentum
decay and weight decay are applied only when a row is touched). Global-norm
clipping accounts for the sparse leaves' true norm (rows are deduped, so
their sum of squares equals the dense gradient's).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import sparse as sparse_lib
from repro.optim.sparse import SparseRows

Params = Any
Grads = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adagrad"           # adagrad | adamw | sgd
    learning_rate: float = 0.01
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    adagrad_init: float = 0.0       # initial accumulator (paper uses 0)
    clip_norm: float = 0.0          # 0 = off
    warmup_steps: int = 0
    decay_steps: int = 0            # cosine decay horizon; 0 = constant


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # 1st moment (adamw) or None
    nu: Any          # 2nd moment / adagrad accumulator


def init_opt_state(cfg: OptimizerConfig, params: Params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
    if cfg.name == "adamw":
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))
    if cfg.name == "adagrad":
        return OptState(step=jnp.zeros((), jnp.int32), mu=None,
                        nu=jax.tree.map(
                            lambda p: jnp.full_like(
                                p, cfg.adagrad_init, jnp.float32), params))
    if cfg.name == "sgd":
        return OptState(step=jnp.zeros((), jnp.int32), mu=None, nu=None)
    raise ValueError(cfg.name)


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    lr = jnp.asarray(cfg.learning_rate, jnp.float32)
    s = step.astype(jnp.float32)
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, (s + 1.0) / cfg.warmup_steps)
    if cfg.decay_steps:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(1, cfg.decay_steps - cfg.warmup_steps), 0, 1)
        lr = lr * (0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
    return lr


def global_norm(grads: Grads) -> jax.Array:
    leaves = jax.tree.leaves(grads, is_leaf=sparse_lib.is_sparse)
    sq = [sparse_lib.sq_norm(g) if sparse_lib.is_sparse(g)
          else jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))


def clip_by_global_norm(grads: Grads, max_norm: float
                        ) -> Tuple[Grads, jax.Array]:
    norm = global_norm(grads)
    scl = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    clipped = jax.tree.map(
        lambda g: sparse_lib.scale(g, scl) if sparse_lib.is_sparse(g)
        else g * scl, grads, is_leaf=sparse_lib.is_sparse)
    return clipped, norm


def _leaf_update(cfg: OptimizerConfig, lr, t, p, g, m, n):
    """The per-leaf update rule, shared verbatim by the dense path (whole
    arrays) and the sparse path (gathered rows): returns (p', m', n')."""
    g32 = g.astype(jnp.float32)
    if cfg.name == "adagrad":
        n2 = n + jnp.square(g32)
        u = -lr * g32 / (jnp.sqrt(n2) + cfg.eps)
        m2 = None
    elif cfg.name == "adamw":
        m2 = cfg.beta1 * m + (1 - cfg.beta1) * g32
        n2 = cfg.beta2 * n + (1 - cfg.beta2) * jnp.square(g32)
        bc1 = 1.0 - cfg.beta1 ** t
        bc2 = 1.0 - cfg.beta2 ** t
        u = -lr * ((m2 / bc1) / (jnp.sqrt(n2 / bc2) + cfg.eps)
                   + cfg.weight_decay * p.astype(jnp.float32))
    elif cfg.name == "sgd":
        u = -lr * g32
        m2 = n2 = None
    else:
        raise ValueError(cfg.name)
    return (p.astype(jnp.float32) + u).astype(p.dtype), m2, n2


def _sparse_node_update(cfg: OptimizerConfig, lr, t, sparse: SparseRows,
                        leaves, moments_m, moments_n, mesh=None):
    """O(U·K) row update for the {w, b} pair touched by a SparseRows grad.

    One gather → :func:`_leaf_update` on the rows → one scatter, covering
    BOTH leaves and their accumulators in a single pass (under a mesh,
    a single shard_map — repro.parallel.collectives.sharded_rows_update,
    shard-local, no all-gather). Sentinel ids (== C, the dedupe fill)
    clamp on the gather and drop on the scatter; their coefficients are
    zero so they never contaminate state. ``leaves``/``moments_*`` are
    (w_like, b_like) pairs; moment entries are None when the optimizer
    has no such state. Returns (new_leaves, new_m, new_n) pairs.
    """
    vals = (sparse.dw, sparse.db)

    def row_math(rows, vals_l):
        # rows order: [p for each leaf] + [m ...] + [n ...] (None-skipped).
        rows = list(rows)
        p_r = [rows.pop(0) for _ in leaves]
        m_r = [rows.pop(0) if m is not None else None for m in moments_m]
        n_r = [rows.pop(0) if n is not None else None for n in moments_n]
        out = [_leaf_update(cfg, lr, t, p, v, m, n)
               for p, v, m, n in zip(p_r, vals_l, m_r, n_r)]
        return tuple(x for group in zip(*out) for x in group
                     if x is not None)

    dense = ([p for p in leaves]
             + [m for m in moments_m if m is not None]
             + [n for n in moments_n if n is not None])
    tp = mesh.shape["model"] if mesh is not None else 1
    if mesh is not None and all(d.shape[0] % tp == 0 for d in dense):
        from repro.parallel.collectives import sharded_rows_update
        out = sharded_rows_update(mesh, row_math, sparse.ids, vals, dense)
    else:
        rows = tuple(d[sparse.ids] for d in dense)
        new_rows = row_math(rows, vals)
        out = tuple(d.at[sparse.ids].set(r.astype(d.dtype), mode="drop")
                    for d, r in zip(dense, new_rows))

    out = list(out)
    new_p = [out.pop(0) for _ in leaves]
    new_m = [out.pop(0) if m is not None else None for m in moments_m]
    new_n = [out.pop(0) if n is not None else None for n in moments_n]
    return new_p, new_m, new_n


def apply_updates(cfg: OptimizerConfig, params: Params, grads: Grads,
                  state: OptState, mesh=None) -> Tuple[Params, OptState,
                                                       dict]:
    """One optimizer step. Returns (params, state, metrics).

    ``grads`` may carry SparseRows leaves in place of a {"w", "b"} param
    subtree (see module docstring); ``mesh`` routes their row updates
    shard-local when the touched table is vocab-sharded over 'model'.
    """
    metrics = {}
    if cfg.clip_norm:
        grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = norm
    lr = schedule(cfg, state.step)
    metrics["lr"] = lr
    t = (state.step + 1).astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_flatten_with_path(
        grads, is_leaf=sparse_lib.is_sparse)[0]
    # mu/nu mirror params exactly, so index i lines up across all three.
    flat_m = (jax.tree.leaves(state.mu) if state.mu is not None
              else [None] * len(flat_p))
    flat_n = (jax.tree.leaves(state.nu) if state.nu is not None
              else [None] * len(flat_p))
    idx_of = {path: i for i, (path, _) in enumerate(flat_p)}

    new_p = [leaf for _, leaf in flat_p]
    new_m = list(flat_m)
    new_n = list(flat_n)
    covered = set()
    for path, g in flat_g:
        if not sparse_lib.is_sparse(g):
            i = idx_of[path]
            new_p[i], new_m[i], new_n[i] = _leaf_update(
                cfg, lr, t, flat_p[i][1], g, flat_m[i], flat_n[i])
            covered.add(i)
            continue
        # SparseRows stands in for a {"w": (C, K), "b": (C,)} subtree:
        # locate its two dense leaves by path prefix, match by rank.
        sub = [idx_of[p2] for p2, _ in flat_p if p2[:len(path)] == path]
        assert len(sub) == 2, (path, sub)
        i_w, i_b = ((sub[0], sub[1]) if flat_p[sub[0]][1].ndim == 2
                    else (sub[1], sub[0]))
        p2, m2, n2 = _sparse_node_update(
            cfg, lr, t, g,
            (flat_p[i_w][1], flat_p[i_b][1]),
            (flat_m[i_w], flat_m[i_b]), (flat_n[i_w], flat_n[i_b]),
            mesh=mesh)
        for j, i in enumerate((i_w, i_b)):
            new_p[i], new_m[i], new_n[i] = p2[j], m2[j], n2[j]
            covered.add(i)
    # Fail loud on a partial gradient tree (the pre-rewrite tree.map
    # raised on structure mismatch; silently frozen params would train
    # on with no error).
    if len(covered) != len(flat_p):
        missing = [flat_p[i][0] for i in range(len(flat_p))
                   if i not in covered]
        raise ValueError(f"grads cover {len(covered)}/{len(flat_p)} "
                         f"param leaves; missing {missing[:5]}")

    unflatten = jax.tree_util.tree_unflatten
    mu = (unflatten(jax.tree.structure(state.mu), new_m)
          if state.mu is not None else None)
    nu = (unflatten(jax.tree.structure(state.nu), new_n)
          if state.nu is not None else None)
    new_state = OptState(step=state.step + 1, mu=mu, nu=nu)
    return unflatten(treedef, new_p), new_state, metrics
