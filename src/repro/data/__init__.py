from repro.data.pipeline import HostShardedLoader, lm_batch_fn
from repro.data.synthetic import (ClusteredXCSpec, make_clustered_xc,
                                  zipf_token_stream)

__all__ = ["HostShardedLoader", "lm_batch_fn", "ClusteredXCSpec",
           "make_clustered_xc", "zipf_token_stream"]
