"""Synthetic data: (a) hierarchically-clustered extreme-classification sets
mirroring the paper's cluster argument ("dogs vs bicycles ... Boston Terrier
vs French Bulldog", §2.2), and (b) deterministic token streams for LM runs.

Everything is seeded and host-side numpy so the pipeline is reproducible and
restart-safe (a data position is a (seed, step) pair — no state to persist
beyond the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusteredXCSpec:
    """Binary-tree label hierarchy: labels are leaves of a depth-D tree;
    feature = sum of per-level cluster offsets + noise. Deeper levels have
    smaller offsets, so distinguishing siblings ("Boston Terrier vs French
    Bulldog") is the hard part — exactly the regime where adversarial
    negatives beat uniform ones."""
    num_labels: int = 1024
    feature_dim: int = 64
    depth_scale: float = 0.55     # offset shrink per level
    noise: float = 0.35
    zipf_a: float = 1.3           # label frequencies ~ zipf (long tail)
    seed: int = 0


def make_clustered_xc(spec: ClusteredXCSpec, n_train: int, n_test: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Returns (x_train, y_train, x_test, y_test)."""
    rng = np.random.default_rng(spec.seed)
    c, k = spec.num_labels, spec.feature_dim
    depth = int(np.ceil(np.log2(c)))
    # Per-level offsets: node at level l contributes scale^l * offset.
    centers = np.zeros((c, k), np.float64)
    for level in range(depth):
        n_nodes = 1 << (level + 1)
        offsets = rng.standard_normal((n_nodes, k)) * (spec.depth_scale
                                                       ** level)
        idx = (np.arange(c) >> (depth - 1 - level)) & (n_nodes - 1)
        centers += offsets[idx]
    # Long-tailed label marginal.
    ranks = np.arange(1, c + 1, dtype=np.float64)
    p = ranks ** (-spec.zipf_a)
    p /= p.sum()
    label_perm = rng.permutation(c)

    def draw(n, seed_off):
        r = np.random.default_rng(spec.seed + seed_off)
        y = label_perm[r.choice(c, size=n, p=p)]
        x = centers[y] + spec.noise * r.standard_normal((n, k))
        return x.astype(np.float32), y.astype(np.int64)

    x_tr, y_tr = draw(n_train, 1)
    x_te, y_te = draw(n_test, 2)
    return x_tr, y_tr, x_te, y_te


def zipf_token_stream(vocab_size: int, batch: int, seq_len: int, *,
                      seed: int = 0, zipf_a: float = 1.2,
                      n_clusters: int = 64) -> Iterator[np.ndarray]:
    """Deterministic clustered-bigram token stream: tokens belong to
    `n_clusters` clusters; the next token stays in the previous token's
    cluster w.p. 0.8 — gives a learnable bigram structure so the LM
    generator tree has signal to capture.

    Yields (batch, seq_len) int32 arrays; stream position is (seed, step).
    """
    c = vocab_size
    base = np.random.default_rng(seed)
    cluster_of = base.integers(0, n_clusters, c)
    members: list = [np.where(cluster_of == i)[0] for i in range(n_clusters)]
    members = [m if len(m) else np.array([0]) for m in members]
    ranks = np.arange(1, c + 1, dtype=np.float64) ** (-zipf_a)
    p_unigram = ranks / ranks.sum()
    perm = base.permutation(c)

    step = 0
    while True:
        r = np.random.default_rng((seed, step))
        toks = np.empty((batch, seq_len), np.int64)
        toks[:, 0] = perm[r.choice(c, size=batch, p=p_unigram)]
        stay = r.random((batch, seq_len)) < 0.8
        fresh = perm[r.choice(c, size=(batch, seq_len), p=p_unigram)]
        for t in range(1, seq_len):
            prev_cluster = cluster_of[toks[:, t - 1]]
            pick = r.integers(0, 1 << 30, batch)
            in_cluster = np.array(
                [members[pc][pk % len(members[pc])]
                 for pc, pk in zip(prev_cluster, pick)])
            toks[:, t] = np.where(stay[:, t], in_cluster, fresh[:, t])
        yield toks.astype(np.int32)
        step += 1
