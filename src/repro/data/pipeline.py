"""Host-sharded, prefetching data pipeline.

Designed for the multi-host setting: each host computes its slice of the
global batch from (num_hosts, host_id) — no cross-host coordination, fully
deterministic from (seed, step), so checkpoint/restart only needs the step
counter (the loader itself is stateless). A small background-thread prefetch
queue overlaps host-side generation with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class HostShardedLoader:
    """Wraps a `make_batch(step) -> np.ndarray...` function with host
    slicing + prefetch."""

    def __init__(self, make_global_batch: Callable[[int], dict],
                 global_batch: int, num_hosts: int = 1, host_id: int = 0,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % num_hosts == 0, (global_batch, num_hosts)
        self._make = make_global_batch
        self._gb = global_batch
        self._hosts = num_hosts
        self._host = host_id
        self._step = start_step
        self._prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- host slicing -----------------------------------------------------
    def _slice(self, batch: dict) -> dict:
        per = self._gb // self._hosts
        lo = self._host * per
        return {k: (v[lo:lo + per] if hasattr(v, "shape")
                    and v.shape and v.shape[0] == self._gb else v)
                for k, v in batch.items()}

    def _produce(self):
        step = self._step
        while not self._stop.is_set():
            item = (step, self._slice(self._make(step)))
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    # -- iteration --------------------------------------------------------
    def __iter__(self) -> Iterator[tuple]:
        if self._prefetch > 0:
            self._q = queue.Queue(maxsize=self._prefetch)
            self._stop.clear()
            self._thread = threading.Thread(target=self._produce,
                                            daemon=True)
            self._thread.start()
            try:
                while True:
                    yield self._q.get()
            finally:
                self.close()
        else:
            step = self._step
            while True:
                yield step, self._slice(self._make(step))
                step += 1

    def close(self):
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def seek(self, step: int):
        """Restart-safe: position the stream at `step` (post-restore)."""
        self.close()
        self._step = step


def lm_batch_fn(vocab_size: int, global_batch: int, seq_len: int,
                seed: int = 0, n_clusters: int = 64):
    """Deterministic (seed, step) -> {tokens, labels, mask} for LM training.

    Labels are next tokens; the last position is masked out.
    """
    from repro.data.synthetic import zipf_token_stream

    def make(step: int) -> dict:
        # Stateless: re-derive the stream at `step` directly.
        rng = np.random.default_rng((seed, step))
        it = zipf_token_stream(vocab_size, global_batch, seq_len + 1,
                               seed=seed * 1_000_003 + step,
                               n_clusters=n_clusters)
        toks = next(it)
        del rng
        mask = np.ones((global_batch, seq_len), np.float32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(
            np.int32), "mask": mask}

    return make
