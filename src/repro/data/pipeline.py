"""Host-sharded, prefetching data pipeline.

Designed for the multi-host setting: each host computes its slice of the
global batch from (num_hosts, host_id) — no cross-host coordination, fully
deterministic from (seed, step), so checkpoint/restart only needs the step
counter (the loader itself is stateless). A small background-thread prefetch
queue overlaps host-side generation with device compute.

Failure semantics (DESIGN.md §13): the producer thread never dies
silently. An exception in ``make_batch`` is captured and re-raised in the
*consumer* at the next ``__iter__`` pull — the training loop sees the
real error instead of hanging forever on an empty queue. ``close()``
reports whether the producer actually exited: if the join times out, the
loader is marked ``failed`` and keeps the thread reference (a leaked
thread you can see beats one that silently vanished).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from repro.resilience import faults


class ProducerError(RuntimeError):
    """The prefetch producer died; ``__cause__`` is the original error."""


class HostShardedLoader:
    """Wraps a `make_batch(step) -> np.ndarray...` function with host
    slicing + prefetch."""

    def __init__(self, make_global_batch: Callable[[int], dict],
                 global_batch: int, num_hosts: int = 1, host_id: int = 0,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % num_hosts == 0, (global_batch, num_hosts)
        self._make = make_global_batch
        self._gb = global_batch
        self._hosts = num_hosts
        self._host = host_id
        self._step = start_step
        self._prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        # True when close() could not join the producer within its grace
        # period — the loader refuses to restart until recreated.
        self.failed = False

    # -- host slicing -----------------------------------------------------
    def _slice(self, batch: dict) -> dict:
        per = self._gb // self._hosts
        lo = self._host * per
        return {k: (v[lo:lo + per] if hasattr(v, "shape")
                    and v.shape and v.shape[0] == self._gb else v)
                for k, v in batch.items()}

    def _produce(self):
        step = self._step
        try:
            while not self._stop.is_set():
                faults.fire("data/produce")
                item = (step, self._slice(self._make(step)))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:
            # Park the error, then unblock a consumer waiting on get():
            # the sentinel loses races against in-flight items but the
            # consumer re-checks _error on every pull.
            self._error = e
            try:
                self._q.put_nowait(None)
            except queue.Full:
                pass

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            self._stop.set()
            raise ProducerError(
                f"prefetch producer died: {err!r}") from err

    # -- iteration --------------------------------------------------------
    def __iter__(self) -> Iterator[tuple]:
        if self._prefetch > 0:
            assert not self.failed, \
                "loader previously failed to shut down; recreate it"
            self._q = queue.Queue(maxsize=self._prefetch)
            self._stop.clear()
            self._error = None
            self._thread = threading.Thread(target=self._produce,
                                            daemon=True)
            self._thread.start()
            try:
                while True:
                    try:
                        # Timed get: if the death sentinel lost its race
                        # against a full queue, the next timeout notices
                        # the parked error instead of blocking forever.
                        item = self._q.get(timeout=0.5)
                    except queue.Empty:
                        self._raise_if_failed()
                        continue
                    if item is None:        # producer's death sentinel
                        self._raise_if_failed()
                        continue
                    # Batches produced before the failure still flow —
                    # the error surfaces once the queue runs dry, so a
                    # crash at step N never swallows steps < N.
                    yield item
            finally:
                self.close()
        else:
            step = self._step
            while True:
                faults.fire("data/produce")
                yield step, self._slice(self._make(step))
                step += 1

    def close(self):
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            if self._thread.is_alive():
                # Producer wedged past the grace period: keep the handle
                # and poison the loader instead of silently leaking.
                self.failed = True
            else:
                self._thread = None

    def seek(self, step: int):
        """Restart-safe: position the stream at `step` (post-restore)."""
        self.close()
        self._step = step


def lm_batch_fn(vocab_size: int, global_batch: int, seq_len: int,
                seed: int = 0, n_clusters: int = 64):
    """Deterministic (seed, step) -> {tokens, labels, mask} for LM training.

    Labels are next tokens; the last position is masked out.
    """
    from repro.data.synthetic import zipf_token_stream

    def make(step: int) -> dict:
        # Stateless: re-derive the stream at `step` directly.
        rng = np.random.default_rng((seed, step))
        it = zipf_token_stream(vocab_size, global_batch, seq_len + 1,
                               seed=seed * 1_000_003 + step,
                               n_clusters=n_clusters)
        toks = next(it)
        del rng
        mask = np.ones((global_batch, seq_len), np.float32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(
            np.int32), "mask": mask}

    return make
