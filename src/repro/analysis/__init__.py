from repro.analysis.roofline import (CostReport, Roofline, collective_bytes,
                                     extrapolate_layers, report_from_compiled,
                                     roofline_terms)

__all__ = ["CostReport", "Roofline", "collective_bytes",
           "extrapolate_layers", "report_from_compiled", "roofline_terms"]
