"""Roofline terms from compiled artifacts (no hardware required).

  compute    = HLO_FLOPs   / (chips * peak_FLOP/s)
  memory     = HLO_bytes   / (chips * HBM_bw)
  collective = coll_bytes  / (chips * link_bw)

``cost_analysis()`` supplies per-device FLOPs/bytes; collective bytes are
NOT in cost_analysis, so we parse the compiled HLO and sum the operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.

CAVEAT (measured, see EXPERIMENTS.md §Dry-run): XLA's cost analysis and the
HLO text count a `while`-loop (scan) body ONCE, not per trip. The dry-run
therefore compiles unrolled L=1 and L=2 variants and extrapolates
``total = C(1) + (L-1)·(C(2) - C(1))`` — exact for layer-homogeneous stacks.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"([\w\-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string, incl. tuple shapes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from compiled HLO text.

    Strategy: build name -> output-shape-bytes for every instruction, then
    for each collective instruction sum the sizes of its operands
    (referenced by %name). '-start' variants are counted; their '-done'
    halves are skipped to avoid double counting.
    """
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2))

    out = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        kind = None
        for c in COLLECTIVES:
            if opcode == c or opcode == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        # Operands: %names inside the call parens of this line.
        call = line[line.index(opcode + "("):]
        ops = re.findall(r"%([\w\.\-]+)", call)
        byte_sum = sum(sizes.get(o, 0) for o in ops)
        if byte_sum == 0:
            byte_sum = _shape_bytes(m.group(2))   # fallback: output size
        out[kind] += byte_sum
    return out


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0            # per-device program FLOPs
    bytes_accessed: float = 0.0   # per-device HBM traffic
    collectives: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def collective_total(self) -> int:
        return sum(self.collectives.values())

    def scale_add(self, other: "CostReport", k: float) -> "CostReport":
        colls = {key: int(self.collectives.get(key, 0)
                          + k * other.collectives.get(key, 0))
                 for key in set(self.collectives) | set(other.collectives)}
        return CostReport(flops=self.flops + k * other.flops,
                          bytes_accessed=self.bytes_accessed
                          + k * other.bytes_accessed,
                          collectives=colls)


def report_from_compiled(compiled) -> CostReport:
    ca = compiled.cost_analysis() or {}
    return CostReport(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=collective_bytes(compiled.as_text()))


def extrapolate_layers(c1: CostReport, c2: CostReport, num_layers: int
                       ) -> CostReport:
    """total = C(1) + (L-1) * (C(2) - C(1)); exact for homogeneous stacks."""
    delta = c2.scale_add(c1, -1.0)
    return c1.scale_add(delta, float(num_layers - 1))


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float            # 6 * N_active * tokens
    hlo_flops_total: float        # per-device flops * chips
    chips: int

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: max of the three terms (perfect
        overlap assumption — the optimistic bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        return self.model_flops / max(self.hlo_flops_total, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_time_s * self.chips
        from repro.launch.mesh import PEAK_FLOPS_BF16
        return self.model_flops / max(denom * PEAK_FLOPS_BF16, 1.0)


def roofline_terms(report: CostReport, chips: int, model_flops: float,
                   peak_flops: Optional[float] = None,
                   hbm_bw: Optional[float] = None,
                   link_bw: Optional[float] = None) -> Roofline:
    from repro.launch import mesh as mesh_lib

    peak = peak_flops or mesh_lib.PEAK_FLOPS_BF16
    hbm = hbm_bw or mesh_lib.HBM_BW
    link = link_bw or mesh_lib.ICI_BW
    # cost_analysis is per-device: totals = per_device * chips.
    return Roofline(
        compute_s=report.flops / peak,
        memory_s=report.bytes_accessed / hbm,
        collective_s=report.collective_total / link,
        model_flops=model_flops,
        hlo_flops_total=report.flops * chips,
        chips=chips)
