"""Exporters (DESIGN.md §10): JSONL event log, Prometheus text dump,
console summary.

The JSONL log is the run's machine-readable record: one JSON object per
line, every object carrying an ``event`` type and the emitting ``step``
(serving events carry ``engine_step``). The schema is deliberately
small and append-only — downstream tooling (benchmarks, dashboards,
tests/test_obs.py) validates with :func:`validate_events`, so adding a
field is free and renaming one is a breaking change that fails CI
(``make obs-demo``).

Event types and required fields (``EVENT_SCHEMA``):

* ``step``       — per-train-step sample at ``metrics_interval``:
                   ``step``, ``loss``, ``step_time_s`` (+ ``snr_proxy``
                   / ``snr_ewma`` / ``snr_ref`` when the head emits them).
* ``compile``    — the first executed step of a process, whose wall time
                   is XLA compilation, kept OUT of step-time stats.
* ``gen_submit`` / ``gen_swap`` / ``snr_trigger`` — generator refresh
                   lifecycle (``gen_swap`` carries ``old_fit_step``,
                   ``new_fit_step``, ``fit_wall_s``,
                   ``steps_stale_at_swap``).
* ``request``    — one served request: queue wait, TTFT, total latency.
                   Requests terminated by the resilience paths (deadline
                   abort / poison isolation) carry ``status`` and null
                   out whichever of the latency triple never happened.
* ``serve_step`` — engine-iteration sample: queue depth, active lanes,
                   page occupancy.
* ``nonfinite_skip`` / ``rollback_restore`` — train-loop degradation
                   ladder (DESIGN.md §13): a skipped non-finite step;
                   a rollback-restore to ``restored_step``.
* ``gen_refresh_failed`` — a generator fit that exhausted its retries or
                   hung past the watchdog; the loop kept the stale
                   generator and re-armed the SNR trigger.
* ``summary``    — final registry snapshot (one per run, last line).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.registry import Registry

EVENT_SCHEMA: Dict[str, tuple] = {
    "step": ("step", "loss", "step_time_s"),
    "compile": ("step", "compile_time_s"),
    "gen_submit": ("step",),
    "gen_swap": ("step", "old_fit_step", "new_fit_step", "fit_wall_s",
                 "steps_stale_at_swap"),
    "gen_refresh_failed": ("step", "submit_step", "reason"),
    "snr_trigger": ("step",),
    "nonfinite_skip": ("step", "streak"),
    "rollback_restore": ("step", "restored_step"),
    "request": ("request_id", "tokens", "admission_wait_s", "ttft_s",
                "latency_s"),
    "serve_step": ("engine_step", "queue_depth", "active",
                   "page_occupancy"),
    "summary": ("metrics",),
}


class JsonlExporter:
    """Line-per-event JSON writer. Each ``emit`` writes and flushes one
    line (events are rare relative to device work, and a crashed run
    must leave a readable log). Usable as a context manager; ``emit`` on
    a closed or path-less exporter is a silent no-op so shutdown races
    (background genfit swap vs loop exit) cannot throw."""

    def __init__(self, path: Optional[str]):
        self.path = path
        self._f = open(path, "w") if path else None
        self.n_events = 0

    def emit(self, event: dict) -> None:
        if self._f is None:
            return
        assert "event" in event, f"event missing 'event' type: {event}"
        self._f.write(json.dumps(event, sort_keys=True) + "\n")
        self._f.flush()
        self.n_events += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "JsonlExporter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def validate_events(events: List[dict]) -> None:
    """Assert the JSONL schema: every event typed, required fields
    present, numeric fields numeric. Unknown event types are an error —
    the schema table IS the compatibility contract."""
    assert events, "empty event log"
    for i, ev in enumerate(events):
        assert isinstance(ev, dict) and "event" in ev, f"line {i}: {ev}"
        kind = ev["event"]
        assert kind in EVENT_SCHEMA, f"line {i}: unknown event {kind!r}"
        missing = [k for k in EVENT_SCHEMA[kind] if k not in ev]
        assert not missing, f"line {i} ({kind}): missing {missing}"
        for k, v in ev.items():
            if k.endswith(("_s", "_time")) or k in ("loss", "step"):
                assert v is None or isinstance(v, (int, float)), \
                    f"line {i} ({kind}): {k}={v!r} not numeric"


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    return ("_" + s) if s[:1].isdigit() else s


def prometheus_text(registry: Registry) -> str:
    """Prometheus text exposition of the registry. Histograms export
    ``_count`` / ``_sum`` plus quantile samples (summary-style), which
    keeps the dump dependency-free and human-diffable."""
    lines = []
    for name, snap in registry.snapshot().items():
        pname = _prom_name(name)
        kind = snap["type"]
        if kind == "counter":
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {snap['value']}")
        elif kind in ("gauge", "ewma"):
            lines.append(f"# TYPE {pname} gauge")
            v = snap["value"]
            lines.append(f"{pname} {'NaN' if v is None else v}")
        else:   # histogram -> summary exposition
            lines.append(f"# TYPE {pname} summary")
            for q, key in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
                v = snap[key]
                lines.append(f'{pname}{{quantile="{q}"}} '
                             f"{'NaN' if v is None else v}")
            lines.append(f"{pname}_sum {snap['sum']}")
            lines.append(f"{pname}_count {snap['count']}")
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Pull-based metrics endpoint: a stdlib HTTP thread serving
    :func:`prometheus_text` of a live registry at ``/metrics`` (and
    ``/`` — scrapers and health checks both land somewhere useful).

    Zero dependencies: ``http.server.ThreadingHTTPServer`` on a daemon
    thread, so a serving process exposes its engine registry without an
    agent sidecar, and the thread never blocks interpreter exit. The
    registry snapshot runs in the scrape thread; instruments are plain
    Python counters, so a torn read costs at worst one stale sample,
    never a crash. ``port=0`` binds an ephemeral port (tests); the bound
    port is on ``.port``.

    With ``health_fn`` (a zero-arg callable returning a JSON-able dict,
    e.g. ``Engine.health``) the server also answers the standard probe
    pair: ``/healthz`` — 200 with the snapshot whenever the process can
    answer at all (liveness); ``/readyz`` — 200 iff the snapshot's
    ``ready`` field is truthy, 503 otherwise (readiness: model compiled
    and the queue below the shed threshold), so a load balancer stops
    routing to a saturated or still-compiling engine without killing it.
    Without ``health_fn`` both paths 404 as before.
    """

    def __init__(self, registry: Registry, port: int,
                 host: str = "0.0.0.0", health_fn=None):
        import http.server
        import threading

        reg = registry

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):              # noqa: N802 (stdlib API name)
                path = self.path.split("?")[0]
                if health_fn is not None and path in ("/healthz",
                                                      "/readyz"):
                    try:
                        snap = health_fn()
                    except Exception as e:
                        self._reply(500, json.dumps(
                            {"error": repr(e)}).encode(),
                            "application/json")
                        return
                    code = (200 if path == "/healthz"
                            or snap.get("ready") else 503)
                    self._reply(code, json.dumps(
                        snap, sort_keys=True).encode(),
                        "application/json")
                    return
                if path not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                self._reply(200, prometheus_text(reg).encode(),
                            "text/plain; version=0.0.4")

            def _reply(self, code, body, ctype):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # scrapes are not log events
                pass

        self._httpd = http.server.ThreadingHTTPServer((host, port),
                                                      Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-server", daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_metrics_server(registry: Registry, port: int,
                         host: str = "0.0.0.0",
                         health_fn=None) -> MetricsServer:
    """Serve ``registry`` as Prometheus text on ``http://host:port/metrics``
    from a daemon thread. Returns the running server (``.port`` holds the
    bound port; ``.close()`` stops it). With ``health_fn`` the server also
    answers ``/healthz`` and ``/readyz`` (see :class:`MetricsServer`)."""
    return MetricsServer(registry, port, host, health_fn=health_fn)


def console_summary(registry: Registry, title: str = "metrics") -> str:
    """End-of-run table: one aligned line per instrument, histograms as
    count/mean/p50/p95/p99 (seconds metrics render in ms)."""

    def fmt(name, v):
        if v is None:
            return "-"
        if name.endswith("_s"):
            return f"{v * 1e3:.2f}ms"
        return f"{v:.4g}" if isinstance(v, float) else str(v)

    rows = []
    for name, snap in registry.snapshot().items():
        if snap["type"] == "counter":
            rows.append((name, f"{snap['value']}"))
        elif snap["type"] in ("gauge", "ewma"):
            rows.append((name, fmt(name, snap["value"])))
        else:
            rows.append((name, (f"n={snap['count']} "
                                f"mean={fmt(name, snap['mean'])} "
                                f"p50={fmt(name, snap['p50'])} "
                                f"p95={fmt(name, snap['p95'])} "
                                f"p99={fmt(name, snap['p99'])}")))
    if not rows:
        return f"== {title}: (empty) =="
    width = max(len(r[0]) for r in rows)
    body = "\n".join(f"  {n:<{width}}  {v}" for n, v in rows)
    return f"== {title} ==\n{body}"
