"""Dependency-free metrics registry (DESIGN.md §10).

One process-local :class:`Registry` holds named instruments:

* :class:`Counter` — monotone event counts (``genfit/swaps``);
* :class:`Gauge` — last-written value (``snr/ewma``);
* :class:`Ewma` — exponentially-weighted series (host-side smoothing for
  quantities that are not already EWMA'd on device);
* :class:`Histogram` — fixed-bucket distribution with interpolated
  p50/p95/p99 (``serve/ttft_s``). Buckets are fixed at construction so
  ``observe`` is O(log n_buckets) with zero allocation — the property
  that lets the train loop observe every step.

Disabled mode is the hot-path contract: a ``Registry(enabled=False)``
hands out shared null singletons from module scope — ``counter()`` /
``gauge()`` / ``histogram()`` / ``ewma()`` allocate nothing, store
nothing, and their mutators are empty method calls. Call sites therefore
instrument unconditionally against ``registry or NULL_REGISTRY`` instead
of branching per metric (tests/test_obs.py pins the zero-allocation
fast path with tracemalloc).

Metric names are ``/``-separated (``train/step_time_s``); the namespace
conventions (``train/*``, ``serve/*``, ``genfit/*``, ``snr/*``) are
documented in DESIGN.md §10 and asserted by the integration tests.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence


class Counter:
    """Monotone counter. ``inc`` accepts negative deltas nowhere — a
    decreasing 'counter' is a gauge."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        assert n >= 0, f"counter {self.name} cannot decrease (inc {n})"
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value (None until first ``set``)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Ewma:
    """Exponentially-weighted moving average; first update seeds it."""

    __slots__ = ("name", "alpha", "value", "count")

    def __init__(self, name: str, alpha: float = 0.1):
        self.name = name
        self.alpha = alpha
        self.value: Optional[float] = None
        self.count = 0

    def update(self, v: float) -> None:
        v = float(v)
        self.value = (v if self.value is None
                      else (1.0 - self.alpha) * self.value + self.alpha * v)
        self.count += 1

    def snapshot(self) -> dict:
        return {"type": "ewma", "value": self.value, "alpha": self.alpha,
                "count": self.count}


def exp_buckets(lo: float, hi: float, per_decade: int = 20) -> List[float]:
    """Geometric bucket upper bounds covering [lo, hi]; quantile estimates
    carry at most one bucket ratio (10^(1/per_decade)) of relative error."""
    assert 0 < lo < hi
    n = int(math.ceil(per_decade * math.log10(hi / lo)))
    return [lo * 10.0 ** (i / per_decade) for i in range(n + 1)]


def linear_buckets(lo: float, hi: float, n: int) -> List[float]:
    """``n`` equal-width bucket upper bounds over [lo, hi]."""
    assert hi > lo and n >= 1
    w = (hi - lo) / n
    return [lo + w * (i + 1) for i in range(n)]


# Seconds, 1us .. ~17min: the default for every *_s latency histogram.
DEFAULT_TIME_BUCKETS = exp_buckets(1e-6, 1e3, per_decade=20)


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles.

    ``bounds`` are ascending bucket *upper* bounds; values above the last
    bound land in an implicit +inf overflow bucket. Exact count/sum/min/
    max ride along, so ``mean`` is exact and quantile estimates are
    clamped to the observed range (a single-value histogram reports that
    value for every quantile regardless of bucket width).
    """

    __slots__ = ("name", "bounds", "counts", "count", "total",
                 "vmin", "vmax")

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = list(bounds if bounds is not None
                           else DEFAULT_TIME_BUCKETS)
        assert self.bounds == sorted(self.bounds), "bounds must ascend"
        self.counts = [0] * (len(self.bounds) + 1)   # +1: overflow
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def quantile(self, q: float) -> Optional[float]:
        """Linear interpolation inside the bucket holding rank ``q`` —
        the bucketed analogue of ``numpy.quantile(..., 'linear')``."""
        assert 0.0 <= q <= 1.0
        if not self.count:
            return None
        target = q * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c > target:
                lo = self.bounds[i - 1] if i > 0 else self.vmin
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                lo, hi = max(lo, self.vmin), min(hi, self.vmax)
                if c == 1 or hi <= lo:
                    return max(lo, min(hi, lo))
                # Ranks cum..cum+c-1 spread linearly over [lo, hi]
                # (numpy's 'linear' method restricted to the bucket).
                frac = (target - cum) / (c - 1)
                return lo + (hi - lo) * min(frac, 1.0)
            cum += c
        return self.vmax

    def snapshot(self) -> dict:
        empty = not self.count
        return {"type": "histogram", "count": self.count,
                "sum": self.total, "mean": self.mean,
                "min": None if empty else self.vmin,
                "max": None if empty else self.vmax,
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class _NullCounter:
    __slots__ = ()
    name = "<null>"
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def snapshot(self) -> dict:
        return {"type": "counter", "value": 0}


class _NullGauge:
    __slots__ = ()
    name = "<null>"
    value = None

    def set(self, v: float) -> None:
        pass

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": None}


class _NullEwma:
    __slots__ = ()
    name = "<null>"
    value = None
    count = 0

    def update(self, v: float) -> None:
        pass

    def snapshot(self) -> dict:
        return {"type": "ewma", "value": None, "alpha": 0.0, "count": 0}


class _NullHistogram:
    __slots__ = ()
    name = "<null>"
    count = 0
    mean = None

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> Optional[float]:
        return None

    def snapshot(self) -> dict:
        return {"type": "histogram", "count": 0, "sum": 0.0, "mean": None,
                "min": None, "max": None, "p50": None, "p95": None,
                "p99": None}


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_EWMA = _NullEwma()
NULL_HISTOGRAM = _NullHistogram()


class Registry:
    """Named-instrument store. ``get_or_create`` semantics: the first
    call fixes the instrument's type (and a histogram's buckets); later
    calls with the same name return the same object, and a type mismatch
    is a bug (asserted), not a silent second metric.

    ``annotate=True`` makes :func:`repro.obs.trace.span` additionally
    open a ``jax.profiler.TraceAnnotation`` per span so device profiles
    (``--profile-dir``) line up with the host phase timings.
    """

    def __init__(self, enabled: bool = True, annotate: bool = False):
        self.enabled = enabled
        self.annotate = annotate
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args, **kwargs)
            self._metrics[name] = m
        assert isinstance(m, cls), (
            f"metric {name!r} already registered as "
            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return NULL_COUNTER
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return NULL_GAUGE
        return self._get(name, Gauge)

    def ewma(self, name: str, alpha: float = 0.1) -> Ewma:
        if not self.enabled:
            return NULL_EWMA
        return self._get(name, Ewma, alpha)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        if not self.enabled:
            return NULL_HISTOGRAM
        return self._get(name, Histogram, bounds)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, dict]:
        """JSON-serializable view of every instrument (the ``summary``
        JSONL event and ``Engine.stats()['metrics']`` payload)."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}


# The shared disabled registry: call sites write
# ``reg = registry or NULL_REGISTRY`` once and then instrument
# unconditionally — no per-metric None checks on the hot path.
NULL_REGISTRY = Registry(enabled=False)
