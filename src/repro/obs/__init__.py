"""repro.obs — unified metrics, tracing, and profiling (DESIGN.md §10).

The one substrate every subsystem reports through: the train loop, the
serving engine, and the genfit refresh lifecycle all write to a
:class:`Registry` (counters / gauges / EWMAs / fixed-bucket histograms),
time their phases with :func:`span`, and export through the JSONL event
log, the Prometheus text dump, or the console summary.
"""
from repro.obs.export import (EVENT_SCHEMA, JsonlExporter, MetricsServer,
                              console_summary, prometheus_text, read_jsonl,
                              start_metrics_server, validate_events)
from repro.obs.registry import (DEFAULT_TIME_BUCKETS, NULL_COUNTER,
                                NULL_EWMA, NULL_GAUGE, NULL_HISTOGRAM,
                                NULL_REGISTRY, Counter, Ewma, Gauge,
                                Histogram, Registry, exp_buckets,
                                linear_buckets)
from repro.obs.trace import ProfileWindow, Span, current_spans, span

__all__ = [
    "Counter", "Ewma", "Gauge", "Histogram", "Registry", "NULL_REGISTRY",
    "NULL_COUNTER", "NULL_GAUGE", "NULL_EWMA", "NULL_HISTOGRAM",
    "DEFAULT_TIME_BUCKETS", "exp_buckets", "linear_buckets",
    "Span", "span", "current_spans", "ProfileWindow",
    "JsonlExporter", "read_jsonl", "validate_events", "EVENT_SCHEMA",
    "prometheus_text", "console_summary", "MetricsServer",
    "start_metrics_server",
]
