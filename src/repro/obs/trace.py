"""Host-side phase tracing (DESIGN.md §10).

:func:`span` times a named phase with ``time.perf_counter`` and records
the duration into a registry histogram of the same name — the phases the
system cares about are enumerated in the §10 namespace table
(``train/phase/step``, ``serve/phase/decode``, ``genfit/phase/fit``, …).
Spans nest: a thread-local stack tracks the open spans, so instrumented
code can ask :func:`current_spans` where it is (and tests pin that
nesting is restored even when the body raises).

Device alignment: with ``registry.annotate`` set (the launchers flip it
on together with ``--profile-dir``), every span additionally opens a
``jax.profiler.TraceAnnotation``, so the host phase boundaries appear as
named regions on the TraceMe timeline of a ``jax.profiler.trace``
capture and device activity can be attributed to the host phase that
launched it. :class:`ProfileWindow` drives that capture for a bounded
step window — profiling a 100k-step run must not write 100k steps of
trace.

Disabled fast path: when the registry is off (and not annotating),
``span()`` returns a shared no-op context manager — no Span object, no
clock read, nothing on the stack.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Tuple

from repro.obs.registry import NULL_REGISTRY, Registry

_tls = threading.local()


def _stack(create: bool = True):
    s = getattr(_tls, "spans", None)
    if s is None and create:
        s = _tls.spans = []
    return s


def current_spans() -> Tuple[str, ...]:
    """Names of the open spans on this thread, outermost first."""
    return tuple(_stack())


def _trace_annotation(name: str):
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:       # profiler unavailable: annotation is best-effort
        return None


class Span:
    """Timed phase: records seconds into ``registry.histogram(name)``."""

    __slots__ = ("name", "_hist", "_annotation", "t0", "seconds")

    def __init__(self, name: str, registry: Registry):
        self.name = name
        self._hist = registry.histogram(name)
        self._annotation = (_trace_annotation(name) if registry.annotate
                            else None)
        self.t0 = 0.0
        self.seconds: Optional[float] = None

    def __enter__(self) -> "Span":
        _stack().append(self.name)
        if self._annotation is not None:
            self._annotation.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = time.perf_counter() - self.t0
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        self._hist.observe(self.seconds)
        popped = _stack().pop()
        assert popped == self.name, f"span stack corrupt: {popped} != " \
                                    f"{self.name}"


class _NullSpan:
    __slots__ = ()
    name = "<null>"
    seconds = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


def span(name: str, registry: Optional[Registry] = None):
    """Context manager timing ``name`` into ``registry``. With a None or
    disabled registry this returns a shared no-op singleton (zero
    allocation — the train loop wraps every step unconditionally)."""
    if registry is None or not registry.enabled:
        return _NULL_SPAN
    return Span(name, registry)


class ProfileWindow:
    """Bounded ``jax.profiler`` capture driven by the training loop.

    ``tick(step)`` starts the device+host trace the first time it is
    called (the loop calls it only from steady-state steps, so XLA
    compilation never pollutes the capture) and stops it after
    ``n_steps`` ticks. Inert when ``profile_dir`` is falsy or the
    profiler is unavailable; ``stop()`` is idempotent and always safe to
    call at loop exit/preemption.
    """

    def __init__(self, profile_dir: Optional[str], n_steps: int = 5):
        self.profile_dir = profile_dir
        self.n_steps = n_steps
        self._ticks = 0
        self._running = False

    def tick(self, step: int) -> None:
        if not self.profile_dir:
            return
        if not self._running and self._ticks == 0:
            try:
                import jax.profiler
                jax.profiler.start_trace(self.profile_dir)
                self._running = True
            except Exception:
                self.profile_dir = None     # profiler unavailable: disarm
                return
        self._ticks += 1
        if self._ticks >= self.n_steps:
            self.stop()

    def stop(self) -> None:
        if self._running:
            import jax.profiler
            jax.profiler.stop_trace()
            self._running = False
            self.profile_dir = None         # one window per run
