"""Probabilistic binary decision tree — the paper's adversarial generator (§3).

The tree is *balanced* with ``C_pad = 2**depth`` leaves (``C_pad >= C``;
surplus leaves are uninhabited "padding labels" whose probability is forced to
zero, exactly as in the paper). Internal nodes are stored in **level order**
(root = 0, children of node ``i`` are ``2i+1`` and ``2i+2``) so that all node
parameters live in two dense arrays and every tree operation is a batched
gather + dot — no pointer chasing, which is the TPU-native re-think of the
paper's sequential CPU sampler.

Every operation is pure ``jax`` and differentiable where meaningful:

- ``log_prob(tree, x, y)``       — O(k·depth) per example  (paper req. (iii))
- ``sample(tree, x, rng)``       — O(k·depth) ancestral sampling (req. (ii))
- ``log_prob_all(tree, x)``      — O(k·C) level-recursive dense evaluation,
  used for the bias-removal term ``log p_n(y|x)`` over the *full* label set at
  prediction time (Eq. 5).
- ``beam_search(tree, x, beam, topk)`` — O(beam·k·depth) batched beam descent
  returning the top-``topk`` labels by ``log p_n(y|x)``, the sublinear
  candidate generator behind :func:`repro.core.heads.predictive_topk`.

Fitting (req. (i)) lives in :mod:`repro.core.tree_fit`.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

# Decision logit magnitude used to force p(padding) = 0. sigmoid(-30) ~ 9e-14.
PAD_LOGIT = 30.0


class Tree(NamedTuple):
    """Packed tree parameters (a pytree; all shapes static under jit).

    Attributes:
      w:  (n_nodes, k) per-node weight vectors; n_nodes = 2**depth - 1.
      b:  (n_nodes,)  per-node biases.
      label_to_leaf: (C,) int32 — leaf index (0..C_pad-1) of each real label.
      leaf_to_label: (C_pad,) int32 — inverse map; padding leaves hold 0.
    """

    w: jax.Array
    b: jax.Array
    label_to_leaf: jax.Array
    leaf_to_label: jax.Array

    @property
    def depth(self) -> int:
        n_nodes = self.b.shape[0]
        d = (n_nodes + 1).bit_length() - 1
        assert (1 << d) == n_nodes + 1, f"n_nodes={n_nodes} is not 2**d - 1"
        return d

    @property
    def num_labels(self) -> int:
        return self.label_to_leaf.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.w.shape[-1]


def padded_size(num_labels: int) -> int:
    """Smallest power of two >= num_labels (>= 2 so depth >= 1)."""
    return max(2, 1 << (num_labels - 1).bit_length())


def init_tree(rng: jax.Array, num_labels: int, feature_dim: int,
              scale: float = 0.01) -> Tree:
    """Random tree over labels in natural order (fitting replaces this)."""
    c_pad = padded_size(num_labels)
    depth = c_pad.bit_length() - 1
    n_nodes = c_pad - 1
    k_w, = jax.random.split(rng, 1)
    w = scale * jax.random.normal(k_w, (n_nodes, feature_dim), jnp.float32)
    b = jnp.zeros((n_nodes,), jnp.float32)
    b = _force_padding(b, num_labels, c_pad)
    label_to_leaf = jnp.arange(num_labels, dtype=jnp.int32)
    leaf_to_label = jnp.where(
        jnp.arange(c_pad) < num_labels, jnp.arange(c_pad), 0
    ).astype(jnp.int32)
    return Tree(w=w, b=b, label_to_leaf=label_to_leaf,
                leaf_to_label=leaf_to_label)


def _force_padding(b: jax.Array, num_labels: int, c_pad: int) -> jax.Array:
    """Force decisions away from padding-only subtrees (identity layout).

    With labels laid out in natural leaf order, leaves [num_labels, c_pad) are
    padding. A node whose *right* subtree is entirely padding must always go
    left (b = -PAD_LOGIT). The override pattern depends only on static sizes,
    so it is computed host-side and applied with a where — this keeps
    ``init_tree`` traceable (eval_shape in the dry-run).
    """
    import numpy as np

    depth = c_pad.bit_length() - 1
    n_nodes = c_pad - 1
    force_left = np.zeros((n_nodes,), bool)
    for level in range(depth):
        n_lvl = 1 << level
        leaves_per_child = c_pad >> (level + 1)
        for j in range(n_lvl):
            node = n_lvl - 1 + j
            right_lo = j * 2 * leaves_per_child + leaves_per_child
            if right_lo >= num_labels:        # right subtree all padding
                force_left[node] = True
    return jnp.where(jnp.asarray(force_left), -PAD_LOGIT, b)


def _node_scores(tree: Tree, x: jax.Array, idx: jax.Array) -> jax.Array:
    """z = w[idx]·x + b[idx] for a batch of node indices idx (same shape as
    x[..., 0])."""
    w = tree.w[idx]                       # (..., k)
    return jnp.sum(w * x, axis=-1) + tree.b[idx]


def log_prob(tree: Tree, x: jax.Array, y: jax.Array) -> jax.Array:
    """log p_n(y|x). x: (..., k), y: (...,) int. Returns (...,) float32.

    Cost O(depth·k) per example: one gather + dot per tree level (Eq. 7).
    """
    depth = tree.depth
    leaf = tree.label_to_leaf[y].astype(jnp.int32)

    def body(level, acc):
        # Node visited at `level` on the path to `leaf`, and the branch taken.
        idx = (1 << level) - 1 + (leaf >> (depth - level))
        bit = (leaf >> (depth - 1 - level)) & 1
        z = _node_scores(tree, x, idx)
        zeta = 2.0 * bit.astype(z.dtype) - 1.0
        return acc + jax.nn.log_sigmoid(zeta * z)

    acc0 = jnp.zeros(y.shape, jnp.float32)
    return jax.lax.fori_loop(0, depth, body, acc0)


def sample(tree: Tree, x: jax.Array, rng: jax.Array
           ) -> Tuple[jax.Array, jax.Array]:
    """Ancestral sampling y' ~ p_n(·|x). Returns (labels, log_probs).

    x: (..., k). Cost O(depth·k) per sample — the paper's O(k log C) bound.
    The log-probability of the drawn label falls out of the walk for free
    (needed for bias removal / regularizer, Eq. 5/6).
    """
    depth = tree.depth
    batch_shape = x.shape[:-1]
    u = jax.random.uniform(rng, batch_shape + (depth,), jnp.float32)

    def body(level, carry):
        idx, acc = carry                  # idx: node index within full tree
        z = _node_scores(tree, x, idx)
        go_right = u[..., level] < jax.nn.sigmoid(z)
        acc = acc + jnp.where(go_right, jax.nn.log_sigmoid(z),
                              jax.nn.log_sigmoid(-z))
        idx = 2 * idx + 1 + go_right.astype(jnp.int32)
        return idx, acc

    idx0 = jnp.zeros(batch_shape, jnp.int32)
    acc0 = jnp.zeros(batch_shape, jnp.float32)
    idx, acc = jax.lax.fori_loop(0, depth, body, (idx0, acc0))
    leaf = idx - ((1 << depth) - 1)
    label = tree.leaf_to_label[leaf]
    return label, acc


def log_prob_all(tree: Tree, x: jax.Array) -> jax.Array:
    """log p_n(y|x) for *all* real labels. x: (..., k) → (..., C).

    Level-recursive dense evaluation: level ``l`` holds 2**l partial
    log-probs; each level costs one (B,k)x(k,2**l) matmul. Total O(C·k) —
    MXU-shaped, vs O(C·depth·k) for per-leaf path walks. Used for full-vocab
    bias removal at serving time (Eq. 5).
    """
    depth = tree.depth
    batch_shape = x.shape[:-1]
    logp = jnp.zeros(batch_shape + (1,), jnp.float32)
    for level in range(depth):
        lo = (1 << level) - 1
        n_lvl = 1 << level
        w_l = jax.lax.dynamic_slice_in_dim(tree.w, lo, n_lvl, 0)   # (n,k)
        b_l = jax.lax.dynamic_slice_in_dim(tree.b, lo, n_lvl, 0)   # (n,)
        z = jnp.einsum("...k,nk->...n", x, w_l) + b_l              # (...,n)
        children = jnp.stack(
            [logp + jax.nn.log_sigmoid(-z), logp + jax.nn.log_sigmoid(z)],
            axis=-1)                                               # (...,n,2)
        logp = children.reshape(batch_shape + (2 * n_lvl,))
    # logp is over leaves; select the leaf of each real label.
    return jnp.take(logp, tree.label_to_leaf, axis=-1)


def beam_search(tree: Tree, x: jax.Array, beam: int, topk: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Top-``topk`` labels by log p_n(y|x) via batched beam descent.

    x: (..., k) → (labels, log_probs), each (..., topk). Cost
    O(beam·k·depth) = O(beam·k·log C) per example — sublinear in C, vs the
    O(C·k) dense pass of :func:`log_prob_all`. With ``beam >= C_pad`` the
    search is exhaustive and exact.

    The beam state is a set of ``beam`` frontier nodes per example; each
    level expands every node into its two children (one gather + dot per
    node) and keeps the ``beam`` highest partial log-probs via ``top_k``.
    Because sibling subtree masses sum to the parent's, a leaf can only be
    missed if its whole prefix path fell out of the beam — rare for the
    peaked conditionals the generator is fitted to produce.

    Inactive beam slots carry -inf and duplicate node 0; real paths always
    have finite log-prob (padding forcing uses finite PAD_LOGIT), so -inf
    uniquely marks dead slots. Padding leaves are masked out of the result:
    their slots return label -1 with log-prob -inf, and never a real label.

    ``beam`` and ``topk`` must be static under jit (they shape the state).
    """
    depth = tree.depth
    c_pad = 1 << depth
    if beam < 1 or topk < 1:
        raise ValueError(
            f"beam and topk must be >= 1, got beam={beam}, topk={topk}")
    beam = min(beam, c_pad)
    n_out = topk
    topk = min(topk, beam)
    batch_shape = x.shape[:-1]

    nodes0 = jnp.zeros(batch_shape + (beam,), jnp.int32)
    logp0 = jnp.full(batch_shape + (beam,), -jnp.inf, jnp.float32)
    logp0 = logp0.at[..., 0].set(0.0)

    def body(level, carry):
        del level
        nodes, logp = carry
        z = _node_scores(tree, x[..., None, :], nodes)            # (..., beam)
        cand_logp = jnp.concatenate(
            [logp + jax.nn.log_sigmoid(-z), logp + jax.nn.log_sigmoid(z)],
            axis=-1)                                              # (..., 2·beam)
        cand_nodes = jnp.concatenate([2 * nodes + 1, 2 * nodes + 2], axis=-1)
        logp, sel = jax.lax.top_k(cand_logp, beam)
        nodes = jnp.take_along_axis(cand_nodes, sel, axis=-1)
        return nodes, logp

    nodes, logp = jax.lax.fori_loop(0, depth, body, (nodes0, logp0))

    leaf = nodes - (c_pad - 1)
    label = tree.leaf_to_label[leaf]
    # A leaf is real iff the label<->leaf maps round-trip (padding leaves
    # all alias label 0); dead beam slots are caught by the -inf check.
    is_real = (tree.label_to_leaf[label] == leaf) & jnp.isfinite(logp)
    logp = jnp.where(is_real, logp, -jnp.inf)
    label = jnp.where(is_real, label, -1)
    top_logp, sel = jax.lax.top_k(logp, topk)
    top_label = jnp.take_along_axis(label, sel, axis=-1)
    if n_out > topk:   # keep the documented (..., topk) output shape
        pad = (0, n_out - topk)
        top_logp = jnp.pad(top_logp, [(0, 0)] * len(batch_shape) + [pad],
                           constant_values=-jnp.inf)
        top_label = jnp.pad(top_label, [(0, 0)] * len(batch_shape) + [pad],
                            constant_values=-1)
    return top_label, top_logp


def prob_mass_real(tree: Tree, x: jax.Array) -> jax.Array:
    """Total probability mass on real (non-padding) labels; ~1.0 by
    construction. Test/diagnostic helper."""
    return jnp.exp(jax.nn.logsumexp(log_prob_all(tree, x), axis=-1))


def validate(tree: Tree, num_labels: int) -> Tree:
    """Structural invariants: array shapes and the leaf↔label bijection.

    Cheap O(C) host-side checks run by the :mod:`repro.genfit` assemblers
    after packing/splicing (a mis-spliced subtree corrupts the permutation
    long before it shows up in likelihoods). Returns the tree for
    chaining.
    """
    import numpy as np

    c_pad = 1 << tree.depth
    assert tree.w.shape == (c_pad - 1, tree.feature_dim), tree.w.shape
    assert tree.b.shape == (c_pad - 1,), tree.b.shape
    assert tree.label_to_leaf.shape == (num_labels,)
    assert tree.leaf_to_label.shape == (c_pad,)
    l2l = np.asarray(tree.label_to_leaf)
    assert len(np.unique(l2l)) == num_labels, "label->leaf not injective"
    roundtrip = np.asarray(tree.leaf_to_label)[l2l]
    assert (roundtrip == np.arange(num_labels)).all(), (
        "leaf_to_label does not invert label_to_leaf")
    return tree
