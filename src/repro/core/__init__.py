"""Paper core: adversarial softmax approximation (Bamler & Mandt, ICLR'20).

- :mod:`repro.core.tree`      — probabilistic decision-tree generator (§3)
- :mod:`repro.core.tree_fit`  — greedy Newton / balanced-split fitting (§3)
- :mod:`repro.core.heads`     — adversarial NS + all baseline heads (§2, §5)
- :mod:`repro.core.samplers`  — NegativeSampler protocol + proposals (§2)
- :mod:`repro.core.snr`       — gradient SNR, Theorem 2 validation (§4)
"""
from repro.core.heads import (Generator, HeadConfig, HeadParams, head_loss,
                              init_head_params, make_freq_generator,
                              make_tree_generator, predictive_accuracy,
                              predictive_log_likelihood, predictive_scores,
                              predictive_topk)
from repro.core.samplers import (LshSampler, NegativeSampler, RffSampler,
                                 TreeSampler, UniformSampler, UnigramSampler,
                                 fit_lsh_sampler, fit_rff_sampler,
                                 fit_sampler, sampler_from_config,
                                 unigram_from_counts)
from repro.core.tree import (Tree, beam_search, init_tree, log_prob,
                             log_prob_all, sample)
from repro.core.tree_fit import FitConfig, fit_tree, pca_projection

__all__ = [
    "Generator", "HeadConfig", "HeadParams", "head_loss", "init_head_params",
    "make_freq_generator", "make_tree_generator", "predictive_accuracy",
    "predictive_log_likelihood", "predictive_scores", "predictive_topk",
    "LshSampler", "NegativeSampler", "RffSampler", "TreeSampler",
    "UniformSampler", "UnigramSampler", "fit_lsh_sampler", "fit_rff_sampler",
    "fit_sampler", "sampler_from_config", "unigram_from_counts",
    "Tree", "beam_search", "init_tree", "log_prob", "log_prob_all", "sample",
    "FitConfig", "fit_tree", "pca_projection",
]
