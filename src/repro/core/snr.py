"""Signal-to-noise ratio of the negative-sampling gradient (paper §4).

Validates Theorem 2 on tabular problems where the nonparametric optimum is
known in closed form (Eq. 11): xi*_{x,y} = log(p_D(y|x) / p_n(y|x)).

Conventions: the data set holds N = X distinct feature vectors (one per row
of ``p_d``), the loss is summed over the data set (Eq. A1), and the one-
sample stochastic gradient carries the factor N (Eq. A7).

  - :func:`snr_closed_form` evaluates Eq. 15 exactly:
        1/eta = N * sum_x [ C - 2 sum_y alpha_{x,y} ],
        alpha_{x,y} = p_n sigma(xi*) = p_n p_D / (p_n + p_D)   (Eq. 13).
  - :func:`snr_empirical` Monte-Carlo estimates
        1/eta = Tr[Cov[g,g] H^-1] = sum_{x,y} E[g_{x,y}^2] / alpha_{x,y}
    from sampled stochastic gradients (Eq. A8); it must agree with the
    closed form (tested), and is maximal at p_n = p_D (Theorem 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def alpha(p_d: jax.Array, p_n: jax.Array) -> jax.Array:
    """alpha_{x,y} (Eq. 13) at the nonparametric optimum."""
    return p_d * p_n / (p_d + p_n + 1e-38)


def snr_closed_form(p_d: jax.Array, p_n: jax.Array) -> jax.Array:
    """eta-bar (Eq. 12) via Eq. 15. p_d, p_n: (X, C) row-stochastic."""
    n, c = p_d.shape
    inv = n * jnp.sum(c - 2.0 * jnp.sum(alpha(p_d, p_n), axis=-1))
    return 1.0 / inv


def snr_empirical(p_d: jax.Array, p_n: jax.Array, rng: jax.Array,
                  n_samples: int = 200_000, chunk: int = 0) -> jax.Array:
    """Monte-Carlo eta-bar from stochastic gradients at the optimum,
    accumulated *streamed per sample*.

    The sum Tr[Cov H^-1] = sum_{x,y} E[g^2]/alpha is linear in the
    per-draw contributions g^2/alpha, so each sample's ratio can be added
    to a scalar directly — no dense (X, C) scatter buffer, and the
    categorical draws are chunked so peak memory is O(chunk·C) instead of
    O(S·C). At the C the repo now trains at the scatter/materialize
    buffers OOM; this path does not.

    Per-sample accumulation necessarily re-associates the float32 sums
    relative to the scatter-then-divide order of
    :func:`snr_empirical_dense` (the small-C reference), so the two agree
    to float tolerance, not bit-for-bit; given identical (rng, n_samples,
    chunk) this estimator is itself bitwise deterministic (pinned in
    tests/test_snr.py).
    """
    n, c = p_d.shape
    xi_star = jnp.log(p_d + 1e-38) - jnp.log(p_n + 1e-38)
    sig_pos = jax.nn.sigmoid(-xi_star)     # positive-term factor sigma(-xi*)
    sig_neg = jax.nn.sigmoid(xi_star)      # negative-term factor sigma(+xi*)
    logd = jnp.log(p_d + 1e-38)
    logn = jnp.log(p_n + 1e-38)
    a = alpha(p_d, p_n) + 1e-38

    if not chunk:
        # Keep the per-chunk categorical workspace (chunk, C) around 16 MB.
        chunk = int(max(64, min(8192, (1 << 22) // max(c, 1))))
    n_chunks = -(-n_samples // chunk)
    total = n_chunks * chunk

    def body(carry, i):
        kx, ky, kn = jax.random.split(jax.random.fold_in(rng, i), 3)
        xs = jax.random.randint(kx, (chunk,), 0, n)
        ys = jax.random.categorical(ky, logd[xs])
        yns = jax.random.categorical(kn, logn[xs])
        # g-hat (Eq. A8): -N sigma(-xi_{x,y}) at (x,y), +N sigma(xi_{x,y'})
        # at (x,y'); the entries coincide when y == y'.
        g_pos = -n * sig_pos[xs, ys]
        g_neg = n * sig_neg[xs, yns]
        same = ys == yns
        term = jnp.where(same,
                         (g_pos + g_neg) ** 2 / a[xs, ys],
                         g_pos ** 2 / a[xs, ys] + g_neg ** 2 / a[xs, yns])
        return carry + jnp.sum(term), None

    inv_sum, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                              jnp.arange(n_chunks))
    return 1.0 / (inv_sum / total)


def snr_empirical_dense(p_d: jax.Array, p_n: jax.Array, rng: jax.Array,
                        n_samples: int = 200_000) -> jax.Array:
    """Reference estimator with the dense (X, C) scatter accumulation —
    kept for small-C cross-checks of :func:`snr_empirical` (it OOMs at
    large C, which is why the streamed path is the default)."""
    n, c = p_d.shape
    xi_star = jnp.log(p_d + 1e-38) - jnp.log(p_n + 1e-38)
    sig_pos = jax.nn.sigmoid(-xi_star)     # positive-term factor sigma(-xi*)
    sig_neg = jax.nn.sigmoid(xi_star)      # negative-term factor sigma(+xi*)

    kx, ky, kn = jax.random.split(rng, 3)
    xs = jax.random.randint(kx, (n_samples,), 0, n)
    ys = jax.random.categorical(ky, jnp.log(p_d + 1e-38)[xs])
    yns = jax.random.categorical(kn, jnp.log(p_n + 1e-38)[xs])

    # g-hat (Eq. A8): -N sigma(-xi_{x,y}) at (x,y), +N sigma(xi_{x,y'}) at
    # (x,y'); the entries coincide when y == y'.
    g_pos = -n * sig_pos[xs, ys]
    g_neg = n * sig_neg[xs, yns]
    same = ys == yns
    sq = jnp.zeros((n, c))
    sq = sq.at[xs, ys].add(jnp.where(same, (g_pos + g_neg) ** 2, g_pos ** 2))
    sq = sq.at[xs, yns].add(jnp.where(same, 0.0, g_neg ** 2))
    second_moment = sq / n_samples          # E[g_{x,y}^2] over the full draw
    inv = jnp.sum(second_moment / (alpha(p_d, p_n) + 1e-38))
    return 1.0 / inv
