"""Greedy fitting of the adversarial generator tree (paper §3).

Maximizes the tree log-likelihood (Eq. 7) over (a) per-node logistic
parameters (w_nu, b_nu) and (b) the label→leaf assignment, by recursively
splitting the label set into equal halves:

  * continuous step — Newton ascent on the convex per-node objective
    L_nu (Eq. 8), hyperparameter-free (paper: "converges quickly to machine
    precision with Newton ascent");
  * discrete step — re-partition Y_nu by the score Delta_y (Eq. 9): the half
    of labels with the largest ``sum_{x in D_y} (w^T x + b)`` goes right.

The two steps alternate until the partition is stable (a local optimum),
then recurse into the children. Runs offline in numpy; the result is packed
into a jax :class:`~repro.core.tree.Tree`.

This per-node recursion is the **reference oracle**: O(C) sequential phases,
float64, maximally simple. Production fitting lives in
:mod:`repro.genfit` — a level-synchronous batched rewrite with O(log C)
sequential phases whose held-out likelihood the property suite pins against
this implementation (plus warm-start refresh and sharded subtree fits).

Supports per-example ``sample_weight`` so aggregated data (e.g. bigram counts
for an LM generator, see DESIGN.md §2) fits without expansion.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.tree import PAD_LOGIT, Tree, padded_size


@dataclasses.dataclass
class FitConfig:
    reg: float = 0.1              # lambda_n, quadratic regularizer (paper §3)
    max_alternations: int = 8     # discrete/continuous alternations per node
    max_newton: int = 25
    newton_tol: float = 1e-8
    seed: int = 0
    use_kernel: bool = False      # route segment reductions through Pallas
                                  # (repro.genfit level solver only)


def _log_sigmoid(z: np.ndarray) -> np.ndarray:
    return -np.logaddexp(0.0, -z)


def _newton_logistic(x: np.ndarray, zeta: np.ndarray, wgt: np.ndarray,
                     w: np.ndarray, b: float, cfg: FitConfig):
    """Damped Newton ascent on L_nu (Eq. 8) with ridge -reg*(|w|^2 + b^2).

    x: (n, k); zeta: (n,) in {-1, +1}; wgt: (n,) nonneg. The objective is
    concave; Armijo backtracking guarantees monotone ascent (plain Newton
    oscillates on separable data where the sigmoids saturate).
    """
    k = x.shape[1]
    xb = np.concatenate([x, np.ones((x.shape[0], 1))], axis=1)   # (n, k+1)
    theta = np.concatenate([w, [b]])
    eye = np.eye(k + 1)

    def objective(th):
        z = zeta * (xb @ th)
        return float(np.dot(wgt, _log_sigmoid(z)) - cfg.reg * np.dot(th, th))

    obj = objective(theta)
    for _ in range(cfg.max_newton):
        z = xb @ theta                                           # (n,)
        s = 1.0 / (1.0 + np.exp(-np.clip(zeta * z, -60, 60)))    # sigma(zeta z)
        grad = xb.T @ (wgt * zeta * (1.0 - s)) - 2.0 * cfg.reg * theta
        h_diag = wgt * s * (1.0 - s)                             # (n,)
        hess_neg = (xb.T * h_diag) @ xb + 2.0 * cfg.reg * eye    # -H, pos.def.
        try:
            direction = np.linalg.solve(hess_neg, grad)          # ascent dir
        except np.linalg.LinAlgError:
            direction = np.linalg.lstsq(hess_neg, grad, rcond=None)[0]
        slope = float(grad @ direction)
        if not np.isfinite(slope) or slope <= 0:
            break
        t = 1.0
        for _ls in range(40):
            cand = theta + t * direction
            obj_cand = objective(cand)
            if obj_cand >= obj + 1e-4 * t * slope:
                break
            t *= 0.5
        else:
            break
        theta, obj = cand, obj_cand
        if float(np.abs(t * direction).max()) < cfg.newton_tol:
            break
    return theta[:k], float(theta[k])


def _init_w(x: np.ndarray, y_local: np.ndarray, wgt: np.ndarray,
            n_labels: int, rng: np.random.Generator) -> np.ndarray:
    """Paper init: dominant eigenvector of the covariance of the per-label
    feature-sum vectors {sum_{x in D_y} x}_y. Power iteration, 20 steps."""
    k = x.shape[1]
    sums = np.zeros((n_labels, k))
    np.add.at(sums, y_local, x * wgt[:, None])
    sums = sums - sums.mean(axis=0, keepdims=True)
    v = rng.standard_normal(k)
    v /= np.linalg.norm(v) + 1e-12
    for _ in range(20):
        v = sums.T @ (sums @ v)
        nrm = np.linalg.norm(v)
        if nrm < 1e-12:
            return rng.standard_normal(k) * 0.01
        v /= nrm
    return v


def fit_tree(features: np.ndarray, labels: np.ndarray, num_labels: int,
             sample_weight: Optional[np.ndarray] = None,
             config: Optional[FitConfig] = None) -> Tree:
    """Fit the generator tree on (features, labels).

    features: (N, k) — already projected to the reduced dim k (see
      :func:`pca_projection`); labels: (N,) int in [0, num_labels).
    """
    import jax.numpy as jnp

    cfg = config or FitConfig()
    rng = np.random.default_rng(cfg.seed)
    x = np.asarray(features, np.float64)
    y = np.asarray(labels, np.int64)
    wgt = (np.ones(len(y)) if sample_weight is None
           else np.asarray(sample_weight, np.float64))
    assert x.ndim == 2 and y.shape == (x.shape[0],)

    c_pad = padded_size(num_labels)
    depth = c_pad.bit_length() - 1
    n_nodes = c_pad - 1
    k = x.shape[1]
    w_all = np.zeros((n_nodes, k))
    b_all = np.zeros((n_nodes,))
    label_to_leaf = np.zeros((num_labels,), np.int64)

    # Recursion stack: (node_index, label_subset, point_index_subset).
    # label_subset entries >= num_labels are padding labels (no data).
    root_labels = np.arange(c_pad)
    order0 = np.argsort(y, kind="stable")
    stack = [(0, root_labels, order0)]
    while stack:
        node, lab, pts = stack.pop()
        n_lab = len(lab)
        half = n_lab // 2
        is_pad = lab >= num_labels
        n_real = int((~is_pad).sum())

        if n_real == 0:
            zeta_split = np.zeros(n_lab, bool)
            zeta_split[half:] = True      # arbitrary; subtree has zero mass
            b_all[node] = -PAD_LOGIT
        elif len(pts) == 0:
            # Labels never observed: keep natural order, no parameters.
            zeta_split = np.zeros(n_lab, bool)
            zeta_split[half:] = True
        else:
            xs, ws_ = x[pts], wgt[pts]
            # Map global labels to a local dense index for aggregation.
            lab_pos = {int(l): i for i, l in enumerate(lab)}
            y_local = np.fromiter((lab_pos[int(v)] for v in y[pts]),
                                  np.int64, count=len(pts))
            w_nu = _init_w(xs, y_local, ws_, n_lab, rng)
            b_nu = 0.0
            zeta_split = np.zeros(n_lab, bool)   # True -> right child
            for _ in range(cfg.max_alternations):
                # Discrete step (Eq. 9): Delta_y = sum_{x in D_y} (w.x + b).
                z = xs @ w_nu + b_nu
                delta = np.zeros(n_lab)
                np.add.at(delta, y_local, ws_ * z)
                delta[is_pad] = -np.inf          # padding sinks to the left...
                # ...unless the right half must absorb padding (only happens
                # when n_real < half): then padding fills from the right end.
                order = np.argsort(-delta, kind="stable")
                new_split = np.zeros(n_lab, bool)
                new_split[order[:half]] = True
                if n_real <= half:
                    # All real labels fit in the right half; pack padding left.
                    new_split[:] = False
                    new_split[np.nonzero(~is_pad)[0]] = True
                    n_fill = half - n_real
                    pad_idx = np.nonzero(is_pad)[0]
                    new_split[pad_idx[:n_fill]] = True
                if np.array_equal(new_split, zeta_split):
                    break
                zeta_split = new_split
                # Continuous step: Newton ascent with the new partition.
                zeta_pts = np.where(zeta_split[y_local], 1.0, -1.0)
                w_nu, b_nu = _newton_logistic(xs, zeta_pts, ws_, w_nu, b_nu,
                                              cfg)
            w_all[node], b_all[node] = w_nu, b_nu
            # Force decisions away from padding-only children (paper §3).
            if int((~is_pad & zeta_split).sum()) == 0:
                w_all[node], b_all[node] = 0.0, -PAD_LOGIT
            if int((~is_pad & ~zeta_split).sum()) == 0:
                w_all[node], b_all[node] = 0.0, PAD_LOGIT

        left_lab, right_lab = lab[~zeta_split], lab[zeta_split]
        # `lab` is not sorted after re-splits; route points via positions.
        if len(pts):
            lab_pos = {int(l): i for i, l in enumerate(lab)}
            y_local = np.fromiter((lab_pos[int(v)] for v in y[pts]),
                                  np.int64, count=len(pts))
            go_right = zeta_split[y_local]
        else:
            go_right = np.zeros(0, bool)
        left_pts, right_pts = pts[~go_right], pts[go_right]

        level = (node + 1).bit_length() - 1
        if level + 1 == depth:                      # children are leaves
            leaf_base = 2 * node + 2 - (1 << depth)  # leaf idx of left child
            for leaf_off, l in ((0, left_lab), (1, right_lab)):
                assert len(l) == 1
                if int(l[0]) < num_labels:
                    label_to_leaf[int(l[0])] = leaf_base + leaf_off
        else:
            stack.append((2 * node + 1, left_lab, left_pts))
            stack.append((2 * node + 2, right_lab, right_pts))

    leaf_to_label = np.zeros((c_pad,), np.int64)
    leaf_to_label[label_to_leaf] = np.arange(num_labels)
    return Tree(
        w=jnp.asarray(w_all, jnp.float32),
        b=jnp.asarray(b_all, jnp.float32),
        label_to_leaf=jnp.asarray(label_to_leaf, jnp.int32),
        leaf_to_label=jnp.asarray(leaf_to_label, jnp.int32),
    )


def pca_projection(features: np.ndarray, k: int):
    """PCA to k dims (paper §3 "Technical Details"). Returns (proj, mean):
    reduced = (x - mean) @ proj, proj: (K, k)."""
    x = np.asarray(features, np.float64)
    mean = x.mean(axis=0)
    xc = x - mean
    # Covariance eigendecomposition; K is small (<= a few thousand).
    cov = (xc.T @ xc) / max(1, len(x) - 1)
    vals, vecs = np.linalg.eigh(cov)
    proj = vecs[:, ::-1][:, :k]
    return proj.astype(np.float32), mean.astype(np.float32)


def tree_log_likelihood(tree: Tree, features: np.ndarray,
                        labels: np.ndarray,
                        sample_weight: Optional[np.ndarray] = None) -> float:
    """Weighted mean log p_n(y|x) — the fitting objective (Eq. 7)/N."""
    import jax.numpy as jnp
    from repro.core.tree import log_prob

    lp = log_prob(tree, jnp.asarray(features, jnp.float32),
                  jnp.asarray(labels, jnp.int32))
    w = (np.ones(len(labels)) if sample_weight is None
         else np.asarray(sample_weight))
    return float(np.average(np.asarray(lp), weights=w))
