"""Training helper for the paper's linear extreme classifier (§5 protocol).

Adagrad + per-head learning-rate selection on a validation split — the
paper's own protocol ('we tuned the hyperparameters for each method
individually using the validation set', Table 1). Adversarial negatives
carry a stronger gradient signal and want a smaller rho than uniform ones;
comparing at one shared rho mis-ranks the methods in either direction.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import heads as heads_lib
from repro.core.heads import Generator, HeadConfig
from repro.optim import OptimizerConfig, apply_updates, init_opt_state


def train_linear_head(cfg: HeadConfig, gen: Generator, x, xg, y,
                      lr: float, steps: int, seed: int = 0,
                      batch_size: int = 256,
                      callback=None, head_update: str = "auto",
                      sampler=None):
    """Minibatch Adagrad on the head loss; returns trained params.

    Minibatching matters for fidelity: with full-batch steps every label
    receives uniform negatives each step and the SNR gap the paper exploits
    collapses. The paper's regime is C >> batch*n_neg coverage per step.
    ``callback(step, params)`` is invoked every 10 steps if given; consume
    ``params`` synchronously (e.g. ``float(acc_fn(params))``) — the step
    donates its buffers, so a retained reference is invalidated by the
    next training step and later reads raise.

    ``head_update`` (DESIGN.md §8): ``sparse`` (default for sampled heads)
    computes the analytic per-touched-row gradient and applies O(U·K)
    Adagrad row updates via ``optim.apply_updates`` — per-step cost
    independent of ``cfg.num_labels``; ``dense`` is the O(C·K) autodiff
    path (and the only option for `softmax`). Both run the same Adagrad
    math, so the trained params match on every touched row.

    ``sampler`` (a ``repro.core.samplers.NegativeSampler``) overrides the
    negative-sampling proposal the head derives from ``cfg.kind``/``gen``.
    """
    opt_cfg = OptimizerConfig(name="adagrad", learning_rate=lr, eps=1e-8)
    params = heads_lib.init_head_params(jax.random.PRNGKey(seed),
                                        cfg.num_labels, x.shape[-1])
    opt_state = init_opt_state(opt_cfg, params)
    n = x.shape[0]
    head_update = heads_lib.resolve_head_update(head_update, cfg.kind)

    # Donation lets the sparse path's row scatters update the (C, K)
    # param/accumulator buffers in place — the step is O(U·K), not an
    # O(C·K) functional copy. (params, opt_state) thread linearly here.
    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(p, opt, key):
        k_idx, k_neg = jax.random.split(key)
        idx = jax.random.randint(k_idx, (batch_size,), 0, n)
        xb, xgb, yb = x[idx], xg[idx], y[idx]
        if head_update == "sparse":
            loss, _, grads, _ = heads_lib.sparse_head_loss(
                cfg, p, gen, xb, xgb, yb, k_neg, sampler=sampler)
        else:
            loss, grads = jax.value_and_grad(
                lambda pp: heads_lib.head_loss(cfg, pp, gen, xb, xgb, yb,
                                               k_neg, sampler=sampler)[0])(p)
        p, opt, _ = apply_updates(opt_cfg, p, grads, opt)
        return p, opt, loss

    base = jax.random.PRNGKey(seed + 1)
    for s in range(steps):
        params, opt_state, _ = step(params, opt_state,
                                    jax.random.fold_in(base, s))
        if callback is not None and (s + 1) % 10 == 0:
            callback(s + 1, params)
    return params


def tune_and_train(kind: str, gen: Generator, num_labels: int,
                   x, xg, y, x_val, xg_val, y_val, *,
                   lr_grid: Sequence[float] = (0.03, 0.1, 0.3),
                   steps: int = 300, tune_steps: Optional[int] = None,
                   reg: float = 1e-4, n_neg: int = 1, sampler=None,
                   ) -> Tuple[HeadConfig, object, float]:
    """Paper §5 protocol. Returns (cfg, params, best_lr).

    ``sampler`` overrides the negative proposal for both training and the
    Eq. 5 debias in the validation accuracy (the two must agree or the
    selection is biased)."""
    cfg = HeadConfig(num_labels=num_labels, kind=kind, n_neg=n_neg,
                     reg=reg)
    tune_steps = tune_steps or max(steps // 3, 50)
    best_lr, best_acc = lr_grid[0], -1.0
    for lr in lr_grid:
        p = train_linear_head(cfg, gen, x, xg, y, lr, tune_steps,
                              sampler=sampler)
        acc = float(heads_lib.predictive_accuracy(cfg, p, gen, x_val,
                                                  xg_val, y_val,
                                                  sampler=sampler))
        if acc > best_acc:
            best_lr, best_acc = lr, acc
    params = train_linear_head(cfg, gen, x, xg, y, best_lr, steps,
                               sampler=sampler)
    return cfg, params, best_lr
