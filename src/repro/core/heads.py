"""Softmax-head strategies: the paper's adversarial negative sampling and all
baselines from §5 / appendix A.2, behind one interface.

Heads score ``C`` labels from a feature ``h in R^K`` with an affine model
``xi_y(h) = w_y . h + b_y`` (the paper's model; for LMs, ``h`` is the final
hidden state and ``(w, b)`` the output embedding). The *generator feature*
``x_gen in R^k`` fed to the auxiliary tree is passed separately (paper: a PCA
projection of the input; LM: a projection of a frozen feature snapshot —
DESIGN.md §2).

Strategies (paper reference):
  softmax         — full softmax CE, Eq. 1 (appendix A.2 baseline)
  uniform_ns      — negative sampling, uniform noise, Eq. 2   (baseline i)
  freq_ns         — unconditional empirical-frequency noise   (baseline ii)
  adversarial_ns  — **the paper**: conditional tree noise, Eq. 6 objective,
                    Eq. 5 bias removal at prediction
  nce             — NCE with the tree as base distribution    (baseline iii)
  sampled_softmax — Bengio & Senecal sampled softmax w/ logQ correction
  ove             — One-vs-Each (Titsias 2016), stochastic    (baseline v)
  augment_reduce  — A&R softmax bound, stochastic reduce step (baseline iv)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import samplers as samplers_lib
from repro.core import tree as tree_lib
from repro.core.samplers import NegativeSampler
from repro.kernels.sampled_loss import SAMPLED_KINDS, loss_and_coeffs
from repro.optim.sparse import SparseRows, accumulate_rows

HEAD_KINDS = ("softmax",) + SAMPLED_KINDS


@dataclasses.dataclass(frozen=True)
class HeadConfig:
    num_labels: int
    kind: str = "adversarial_ns"
    n_neg: int = 1          # negatives per positive (paper uses 1)
    reg: float = 0.0        # lambda in Eq. 6
    debias: bool = True     # apply Eq. 5 at prediction time
    mask_accidental: bool = True  # sampled_softmax: mask negatives == target

    def __post_init__(self):
        assert self.kind in HEAD_KINDS, self.kind


class HeadParams(NamedTuple):
    """Trainable head parameters phi (Eq. 2)."""
    w: jax.Array   # (C, K)
    b: jax.Array   # (C,)


class Generator(NamedTuple):
    """Non-trainable noise-distribution state (kept out of the optimizer;
    the paper keeps the generator constant while training the
    discriminator)."""
    tree: Optional[tree_lib.Tree] = None
    freq_log: Optional[jax.Array] = None   # (C,) log empirical frequencies
    freq_cdf: Optional[jax.Array] = None   # (C,) inclusive CDF


def init_head_params(rng: jax.Array, num_labels: int, feature_dim: int,
                     scale: float = 0.0,
                     dtype=jnp.float32) -> HeadParams:
    w = (scale * jax.random.normal(rng, (num_labels, feature_dim))
         ).astype(dtype)
    return HeadParams(w=w, b=jnp.zeros((num_labels,), dtype))


def make_freq_generator(label_counts: jax.Array) -> Generator:
    """Generator for `freq_ns`: empirical label frequencies (§2.2).

    ``freq_log`` carries 1e-12 smoothing (debiasing an observed label must
    stay finite even at count 0); ``freq_cdf`` is built from the raw
    counts so zero-count labels own an empty sampling interval — see
    :func:`repro.core.samplers.unigram_from_counts`, the single
    definition both paths share.
    """
    s = samplers_lib.unigram_from_counts(label_counts)
    return Generator(freq_log=s.freq_log, freq_cdf=s.freq_cdf)


def make_tree_generator(tree: tree_lib.Tree) -> Generator:
    return Generator(tree=tree)


# ---------------------------------------------------------------------------
# Negative sampling + noise log-probs, per strategy.
# ---------------------------------------------------------------------------

def sample_negatives(cfg: HeadConfig, gen: Generator, x_gen: jax.Array,
                     rng: jax.Array, batch_shape: Tuple[int, ...],
                     sampler: Optional[NegativeSampler] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Draw (ids, log_pn) with shapes batch_shape + (n_neg,).

    The proposal is a :class:`~repro.core.samplers.NegativeSampler`;
    with ``sampler=None`` (the compat shim) ``cfg.kind`` picks the
    proposal it historically hard-wired: uniform O(1) for
    uniform_ns/ove/augment_reduce, the unigram inverse-CDF O(log C) for
    freq_ns, tree ancestral sampling O(k log C) (paper §3) for
    adversarial_ns/nce/sampled_softmax.
    """
    if sampler is None:
        sampler = samplers_lib.sampler_from_config(cfg, gen)
    return sampler.sample(rng, x_gen, batch_shape + (cfg.n_neg,))


def noise_log_prob(cfg: HeadConfig, gen: Generator, x_gen: jax.Array,
                   y: jax.Array,
                   sampler: Optional[NegativeSampler] = None) -> jax.Array:
    """log p_n(y|x) for given labels under the proposal distribution."""
    if sampler is None:
        sampler = samplers_lib.sampler_from_config(cfg, gen)
    return sampler.log_prob(x_gen, y)


def candidate_scores(params: HeadParams, h: jax.Array, ids: jax.Array
                     ) -> jax.Array:
    """xi_{ids}(h) = w_{ids} . h + b_{ids}; ids: h.shape[:-1] + (n,).

    This is the O(K) gather-and-dot that replaces the O(K·C) logits matmul.
    The vocab-sharded fast path lives in repro.parallel.collectives.
    """
    w = params.w[ids]                                    # (..., n, K)
    return (jnp.einsum("...nk,...k->...n", w.astype(jnp.float32),
                       h.astype(jnp.float32))
            + params.b[ids].astype(jnp.float32))


def full_logits(params: HeadParams, h: jax.Array) -> jax.Array:
    """All-label scores, O(K·C): h @ W^T + b."""
    return (jnp.einsum("...k,ck->...c", h.astype(jnp.float32),
                       params.w.astype(jnp.float32))
            + params.b.astype(jnp.float32))


ScoreFn = Callable[[HeadParams, jax.Array, jax.Array], jax.Array]


def kernel_score_fn() -> ScoreFn:
    """Candidate scoring through the `gather_scores` Pallas kernel.

    Same contract as :func:`candidate_scores` (arbitrary batch dims) — the
    kernel wants flat (T, K)/(T, n) operands, so batch dims are collapsed
    around the call. On TPU each touched row streams HBM→VMEM exactly once;
    elsewhere the kernel runs in interpret mode (see repro.kernels.ops).
    """
    from repro.kernels import ops

    def fn(params: HeadParams, h: jax.Array, ids: jax.Array) -> jax.Array:
        batch_shape = ids.shape[:-1]
        n = ids.shape[-1]
        flat = ops.gather_scores(params.w, params.b,
                                 h.reshape((-1, h.shape[-1])),
                                 ids.reshape((-1, n)))
        return flat.reshape(batch_shape + (n,))

    return fn


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------

def head_loss(cfg: HeadConfig, params: HeadParams, gen: Generator,
              h: jax.Array, x_gen: jax.Array, y: jax.Array, rng: jax.Array,
              score_fn: ScoreFn = candidate_scores,
              mask: Optional[jax.Array] = None,
              sampler: Optional[NegativeSampler] = None):
    """Per-strategy training loss, mean over batch. Returns (loss, metrics).

    h: (..., K); x_gen: (..., k); y: (...,) int labels; mask: (...,) in
    {0,1} — masked-out positions (e.g. padding tokens) contribute 0.
    ``sampler`` overrides the proposal distribution (default: the one
    ``cfg.kind`` implies — see :func:`sample_negatives`).
    """
    batch_shape = y.shape
    if mask is None:
        mask = jnp.ones(batch_shape, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)

    def mean(v):
        return jnp.sum(v * mask) / denom

    metrics = {}
    if cfg.kind == "softmax":
        logits = full_logits(params, h)
        logz = jax.nn.logsumexp(logits, axis=-1)
        pos = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        loss = mean(logz - pos)
        if cfg.reg:  # score regularizer (cf. Eq. 6 with log p_n absorbed)
            loss = loss + cfg.reg * mean(jnp.mean(logits ** 2, axis=-1))
        metrics["pos_score"] = mean(pos)
        return loss, metrics

    # Sampled strategies share one candidate layout (slot 0 = positive) and
    # one objective definition: `kernels.sampled_loss.loss_and_coeffs` is
    # the per-strategy math (Eq. 2/6 logistic, NCE, logQ sampled softmax,
    # OVE, A&R), used here under autodiff for the dense gradient path and
    # re-used coefficient-for-coefficient by the sparse path
    # (:func:`sparse_head_loss`) and the fused Pallas kernel.
    y = y.astype(jnp.int32)
    ids, slot_logp, acc_hit = _sample_candidates(cfg, gen, x_gen, y, rng,
                                                 sampler=sampler)
    scores = score_fn(params, h, ids)                  # (..., 1 + n_neg)
    loss_vec, _, xi = loss_and_coeffs(
        scores, slot_logp, acc_hit, kind=cfg.kind,
        num_labels=cfg.num_labels, reg=cfg.reg, softcap=0.0,
        mask_accidental=cfg.mask_accidental)
    loss = mean(loss_vec)
    metrics.update(_sampled_metrics(cfg, xi, mean))
    return loss, metrics


def _sample_candidates(cfg: HeadConfig, gen: Generator, x_gen: jax.Array,
                       y: jax.Array, rng: jax.Array,
                       sampler: Optional[NegativeSampler] = None):
    """Candidate slots for a sampled strategy: ids (..., 1+n) with the
    positive in slot 0, stop-grad noise log-probs per slot (zeros where the
    strategy ignores them), and the accidental-hit mask."""
    neg_ids, neg_logp = sample_negatives(cfg, gen, x_gen, rng, y.shape,
                                         sampler=sampler)
    neg_ids = jax.lax.stop_gradient(neg_ids)
    neg_logp = jax.lax.stop_gradient(neg_logp)
    need_pos_logp = (cfg.kind in ("nce", "sampled_softmax")
                     or (cfg.reg and cfg.kind in ("uniform_ns", "freq_ns",
                                                  "adversarial_ns")))
    pos_logp = (jax.lax.stop_gradient(
        noise_log_prob(cfg, gen, x_gen, y, sampler=sampler))
                if need_pos_logp else jnp.zeros(y.shape, jnp.float32))
    ids = jnp.concatenate([y[..., None], neg_ids], axis=-1)
    slot_logp = jnp.concatenate([pos_logp[..., None], neg_logp], axis=-1)
    acc_hit = jnp.concatenate(
        [jnp.zeros(y.shape + (1,), bool), neg_ids == y[..., None]], axis=-1)
    return ids, slot_logp, acc_hit


def resolve_head_update(head_update: str, kind: str) -> str:
    """'auto' → sparse for sampled heads, dense for the softmax baseline.

    The single definition of the head-update policy — shared by the LM
    train step (repro.train.step) and the linear-XC trainer
    (repro.core.xc_train) so the two stacks cannot drift.
    """
    if head_update == "auto":
        return "dense" if kind == "softmax" else "sparse"
    assert head_update in ("dense", "sparse"), head_update
    if head_update == "sparse":
        assert kind != "softmax", "softmax has no sampled candidate set"
    return head_update


def _sampled_metrics(cfg: HeadConfig, xi: jax.Array, mean) -> dict:
    metrics = {"pos_score": mean(xi[..., 0])}
    if cfg.kind in ("uniform_ns", "freq_ns", "adversarial_ns", "nce"):
        metrics["neg_score"] = mean(jnp.mean(xi[..., 1:], axis=-1))
    # Online proxy of the Eq. A8/15 signal mass Σ_y α(x,y): at the
    # nonparametric optimum E_{y~p_D}[σ(-ξ)] and E_{y~p_n}[σ(ξ)] both
    # equal Σα (Eq. 13), which attains its Jensen bound 1/2 exactly when
    # p_n = p_D (Theorem 2) and decays as the proposal drifts off the data
    # distribution. Averaging the two one-sample estimates reuses the ξ
    # the sampled loss already computed — a refresh-trigger-grade signal,
    # not an η estimator (DESIGN.md §9).
    metrics["snr_proxy"] = 0.5 * (
        mean(jax.nn.sigmoid(-xi[..., 0]))
        + mean(jnp.mean(jax.nn.sigmoid(xi[..., 1:]), axis=-1)))
    return metrics


def sparse_head_loss(cfg: HeadConfig, params: HeadParams, gen: Generator,
                     h: jax.Array, x_gen: jax.Array, y: jax.Array,
                     rng: jax.Array, mask: Optional[jax.Array] = None,
                     softcap: float = 0.0, use_kernel: bool = False,
                     sampler: Optional[NegativeSampler] = None):
    """Sampled-head loss with O(B·K·n_neg) analytic gradients — no dense
    (C, K) buffer anywhere (DESIGN.md §8).

    Same sampling/rng stream, objective, and metrics as :func:`head_loss`
    (the equivalence is pinned by tests/test_sparse_update.py), but instead
    of relying on autodiff — whose backward through the candidate gather
    scatter-adds into a zero-initialized dense (C, K) array — the per-slot
    score gradients ``coeff`` come from the shared closed forms
    (`kernels.sampled_loss.loss_and_coeffs`), and the head gradient is
    assembled as deduplicated per-unique-row sums of the rank-1 terms
    ``coeff · h`` (``repro.optim.sparse.accumulate_rows``).

    Returns ``(loss, metrics, SparseRows, dh)``: loss/metrics as
    :func:`head_loss`; ``SparseRows`` the head gradient over touched rows
    (drop-in leaf for ``optim.apply_updates``); ``dh`` = dL/dh for the
    trunk backward. ``softcap`` applies the final-logit softcap (its chain
    rule is folded into ``coeff``, so the gradients stay exact);
    ``use_kernel`` routes the gather→loss→coefficient chain through the
    fused Pallas kernel (``ops.sampled_head_loss``).
    """
    assert cfg.kind != "softmax", "softmax has no sampled candidate set"
    batch_shape = y.shape
    if mask is None:
        mask = jnp.ones(batch_shape, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)

    y = y.astype(jnp.int32)
    ids, slot_logp, acc_hit = _sample_candidates(cfg, gen, x_gen, y, rng,
                                                 sampler=sampler)
    m = ids.shape[-1]
    kdim = h.shape[-1]
    h2 = h.reshape(-1, kdim)
    ids2 = ids.reshape(-1, m)

    if use_kernel:
        from repro.kernels import ops
        loss_vec, coeff, xi, dh2 = ops.sampled_head_loss(
            params.w, params.b, h2, ids2, slot_logp.reshape(-1, m),
            kind=cfg.kind, num_labels=cfg.num_labels, reg=cfg.reg,
            softcap=softcap, mask_accidental=cfg.mask_accidental)
    else:
        scores = candidate_scores(params, h2, ids2)
        loss_vec, coeff, xi = loss_and_coeffs(
            scores, slot_logp.reshape(-1, m), acc_hit.reshape(-1, m),
            kind=cfg.kind, num_labels=cfg.num_labels, reg=cfg.reg,
            softcap=softcap, mask_accidental=cfg.mask_accidental)
        dh2 = jnp.einsum("tn,tnk->tk", coeff,
                         params.w[ids2].astype(jnp.float32))

    wmask = (mask.reshape(-1) / denom).astype(jnp.float32)
    loss = jnp.sum(loss_vec * wmask)
    coeff = coeff * wmask[:, None]

    def mean(v):
        return jnp.sum(v.reshape(batch_shape) * mask) / denom

    metrics = _sampled_metrics(cfg, xi.reshape(batch_shape + (m,)), mean)
    grads = accumulate_rows(
        ids2.reshape(-1), coeff.reshape(-1),
        jnp.broadcast_to(h2[:, None, :], h2.shape[:1] + (m, kdim)
                         ).reshape(-1, kdim),
        num_rows=params.w.shape[0])
    dh = (dh2 * wmask[:, None]).reshape(h.shape).astype(jnp.float32)
    return loss, metrics, grads, dh


# ---------------------------------------------------------------------------
# Prediction (bias removal, Eq. 5).
# ---------------------------------------------------------------------------

def predictive_scores(cfg: HeadConfig, params: HeadParams, gen: Generator,
                      h: jax.Array, x_gen: jax.Array,
                      sampler: Optional[NegativeSampler] = None
                      ) -> jax.Array:
    """Unbiased predictive scores over all C labels.

    For `adversarial_ns` this is Theorem 1 / Eq. 5:
        xi_softmax = xi_ns + log p_n(y|x) + const,
    with log p_n evaluated densely for all labels in O(C·k) via the
    level-recursive tree pass. For `freq_ns` the correction is the constant-
    per-label log-frequency. Uniform corrections are argmax-irrelevant.
    A head trained against an explicit ``sampler`` is debiased by *that*
    proposal's ``log_prob_all`` — Eq. 5 holds for any proposal with full
    support, which every NegativeSampler guarantees.
    """
    scores = full_logits(params, h)
    if not cfg.debias:
        return scores
    if sampler is not None:
        return scores + sampler.log_prob_all(x_gen)
    if cfg.kind == "adversarial_ns" and gen.tree is not None:
        return scores + tree_lib.log_prob_all(gen.tree, x_gen)
    if cfg.kind == "freq_ns":
        return scores + gen.freq_log
    return scores


def rescore_candidates(cfg: HeadConfig, params: HeadParams, h: jax.Array,
                       cand: jax.Array, log_pn: jax.Array, topk: int,
                       score_fn: ScoreFn = candidate_scores
                       ) -> Tuple[jax.Array, jax.Array]:
    """Score + Eq. 5 debias a proposed candidate set, keep the top ``topk``.

    The re-scoring tail shared by :func:`predictive_topk` and the serving
    engine's candidate-cache path (repro.serve.engine) — one implementation
    so the two stay byte-identical. ``cand`` entries < 0 are dead slots and
    come back as label -1 with score -inf.
    """
    valid = cand >= 0
    xi = score_fn(params, h, jnp.maximum(cand, 0))
    scores = xi + log_pn if cfg.debias else xi
    scores = jnp.where(valid, scores, -jnp.inf)
    top, sel = jax.lax.top_k(scores, topk)
    labels = jnp.take_along_axis(cand, sel, axis=-1)
    return top, labels


def predictive_topk(cfg: HeadConfig, params: HeadParams, gen: Generator,
                    h: jax.Array, x_gen: jax.Array, topk: int,
                    beam: Optional[int] = None,
                    score_fn: ScoreFn = candidate_scores,
                    sampler: Optional[NegativeSampler] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Top-``topk`` unbiased predictive (scores, labels) without any O(C) pass.

    For `adversarial_ns`, beam search over the generator tree proposes
    ``beam`` candidates ranked by log p_n(y|x) in O(beam·k·log C); only those
    are scored (`score_fn`, an O(beam·K) gather-and-dot or the gather_scores
    Pallas kernel) and Eq. 5 debiasing is applied on the candidate set:
    final score xi_y + log p_n(y|x). The generator is trained toward p_D
    (Theorem 2), so its high-probability set is exactly the candidate set
    the debiased argmax lives in; with ``beam >= C_pad`` the result equals
    the dense :func:`predictive_scores` top-k exactly.

    Other head kinds have no conditional candidate structure and fall back
    to dense scoring + top_k. Returns (scores, labels), each (..., topk);
    slots beyond the number of live candidates carry score -inf, label -1.
    With an explicit ``sampler``, the beam path runs iff the sampler is
    tree-backed (a :class:`~repro.core.samplers.TreeSampler`); every other
    proposal falls back to dense scoring debiased by that sampler.
    """
    if sampler is not None:
        tree = getattr(sampler, "tree", None)
    else:
        tree = gen.tree if cfg.kind == "adversarial_ns" else None
    if tree is None:
        scores = predictive_scores(cfg, params, gen, h, x_gen,
                                   sampler=sampler)
        top, labels = jax.lax.top_k(scores, topk)
        return top, labels.astype(jnp.int32)
    if beam is None:
        beam = max(4 * topk, 16)
    beam = min(beam, tree_lib.padded_size(cfg.num_labels))
    cand, log_pn = tree_lib.beam_search(tree, x_gen, beam, beam)
    top, labels = rescore_candidates(cfg, params, h, cand, log_pn,
                                     min(topk, beam), score_fn=score_fn)
    if topk > beam:    # keep the documented (..., topk) output shape
        pad = [(0, 0)] * (labels.ndim - 1) + [(0, topk - beam)]
        top = jnp.pad(top, pad, constant_values=-jnp.inf)
        labels = jnp.pad(labels, pad, constant_values=-1)
    return top, labels


def predictive_log_likelihood(cfg, params, gen, h, x_gen, y,
                              mask: Optional[jax.Array] = None,
                              sampler: Optional[NegativeSampler] = None):
    """Mean test log-likelihood log softmax(scores)[y] (paper Fig. 1)."""
    scores = predictive_scores(cfg, params, gen, h, x_gen, sampler=sampler)
    logp = scores - jax.nn.logsumexp(scores, axis=-1, keepdims=True)
    pos = jnp.take_along_axis(logp, y[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(pos)
    return jnp.sum(pos * mask) / jnp.maximum(mask.sum(), 1.0)


def predictive_accuracy(cfg, params, gen, h, x_gen, y,
                        mask: Optional[jax.Array] = None,
                        sampler: Optional[NegativeSampler] = None):
    scores = predictive_scores(cfg, params, gen, h, x_gen, sampler=sampler)
    correct = (jnp.argmax(scores, axis=-1) == y).astype(jnp.float32)
    if mask is None:
        return jnp.mean(correct)
    return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1.0)
