"""Softmax-head strategies: the paper's adversarial negative sampling and all
baselines from §5 / appendix A.2, behind one interface.

Heads score ``C`` labels from a feature ``h in R^K`` with an affine model
``xi_y(h) = w_y . h + b_y`` (the paper's model; for LMs, ``h`` is the final
hidden state and ``(w, b)`` the output embedding). The *generator feature*
``x_gen in R^k`` fed to the auxiliary tree is passed separately (paper: a PCA
projection of the input; LM: a projection of a frozen feature snapshot —
DESIGN.md §2).

Strategies (paper reference):
  softmax         — full softmax CE, Eq. 1 (appendix A.2 baseline)
  uniform_ns      — negative sampling, uniform noise, Eq. 2   (baseline i)
  freq_ns         — unconditional empirical-frequency noise   (baseline ii)
  adversarial_ns  — **the paper**: conditional tree noise, Eq. 6 objective,
                    Eq. 5 bias removal at prediction
  nce             — NCE with the tree as base distribution    (baseline iii)
  sampled_softmax — Bengio & Senecal sampled softmax w/ logQ correction
  ove             — One-vs-Each (Titsias 2016), stochastic    (baseline v)
  augment_reduce  — A&R softmax bound, stochastic reduce step (baseline iv)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tree as tree_lib

HEAD_KINDS = ("softmax", "uniform_ns", "freq_ns", "adversarial_ns", "nce",
              "sampled_softmax", "ove", "augment_reduce")


@dataclasses.dataclass(frozen=True)
class HeadConfig:
    num_labels: int
    kind: str = "adversarial_ns"
    n_neg: int = 1          # negatives per positive (paper uses 1)
    reg: float = 0.0        # lambda in Eq. 6
    debias: bool = True     # apply Eq. 5 at prediction time
    mask_accidental: bool = True  # sampled_softmax: mask negatives == target

    def __post_init__(self):
        assert self.kind in HEAD_KINDS, self.kind


class HeadParams(NamedTuple):
    """Trainable head parameters phi (Eq. 2)."""
    w: jax.Array   # (C, K)
    b: jax.Array   # (C,)


class Generator(NamedTuple):
    """Non-trainable noise-distribution state (kept out of the optimizer;
    the paper keeps the generator constant while training the
    discriminator)."""
    tree: Optional[tree_lib.Tree] = None
    freq_log: Optional[jax.Array] = None   # (C,) log empirical frequencies
    freq_cdf: Optional[jax.Array] = None   # (C,) inclusive CDF


def init_head_params(rng: jax.Array, num_labels: int, feature_dim: int,
                     scale: float = 0.0,
                     dtype=jnp.float32) -> HeadParams:
    w = (scale * jax.random.normal(rng, (num_labels, feature_dim))
         ).astype(dtype)
    return HeadParams(w=w, b=jnp.zeros((num_labels,), dtype))


def make_freq_generator(label_counts: jax.Array) -> Generator:
    """Generator for `freq_ns`: empirical label frequencies (§2.2)."""
    counts = jnp.asarray(label_counts, jnp.float32) + 1e-12
    p = counts / counts.sum()
    return Generator(freq_log=jnp.log(p), freq_cdf=jnp.cumsum(p))


def make_tree_generator(tree: tree_lib.Tree) -> Generator:
    return Generator(tree=tree)


# ---------------------------------------------------------------------------
# Negative sampling + noise log-probs, per strategy.
# ---------------------------------------------------------------------------

def sample_negatives(cfg: HeadConfig, gen: Generator, x_gen: jax.Array,
                     rng: jax.Array, batch_shape: Tuple[int, ...]
                     ) -> Tuple[jax.Array, jax.Array]:
    """Draw (ids, log_pn) with shapes batch_shape + (n_neg,).

    Costs: uniform O(1); freq O(log C) (inverse-CDF); adversarial/nce/
    sampled_softmax O(k log C) (tree ancestral sampling, paper §3).
    """
    shape = batch_shape + (cfg.n_neg,)
    c = cfg.num_labels
    if cfg.kind in ("uniform_ns", "ove", "augment_reduce"):
        ids = jax.random.randint(rng, shape, 0, c)
        return ids, jnp.full(shape, -jnp.log(float(c)))
    if cfg.kind == "freq_ns":
        u = jax.random.uniform(rng, shape)
        ids = jnp.searchsorted(gen.freq_cdf, u).astype(jnp.int32)
        ids = jnp.clip(ids, 0, c - 1)
        return ids, gen.freq_log[ids]
    if cfg.kind in ("adversarial_ns", "nce", "sampled_softmax"):
        xg = jnp.broadcast_to(x_gen[..., None, :],
                              batch_shape + (cfg.n_neg, x_gen.shape[-1]))
        ids, logp = tree_lib.sample(gen.tree, xg, rng)
        return ids, logp
    raise ValueError(f"{cfg.kind} draws no negatives")


def noise_log_prob(cfg: HeadConfig, gen: Generator, x_gen: jax.Array,
                   y: jax.Array) -> jax.Array:
    """log p_n(y|x) for given labels under the strategy's noise dist."""
    if cfg.kind in ("uniform_ns", "ove", "augment_reduce"):
        return jnp.full(y.shape, -jnp.log(float(cfg.num_labels)))
    if cfg.kind == "freq_ns":
        return gen.freq_log[y]
    if cfg.kind in ("adversarial_ns", "nce", "sampled_softmax"):
        xg = jnp.broadcast_to(x_gen[..., None, :] if y.ndim == x_gen.ndim
                              else x_gen, y.shape + (x_gen.shape[-1],))
        return tree_lib.log_prob(gen.tree, xg, y)
    raise ValueError(cfg.kind)


def candidate_scores(params: HeadParams, h: jax.Array, ids: jax.Array
                     ) -> jax.Array:
    """xi_{ids}(h) = w_{ids} . h + b_{ids}; ids: h.shape[:-1] + (n,).

    This is the O(K) gather-and-dot that replaces the O(K·C) logits matmul.
    The vocab-sharded fast path lives in repro.parallel.collectives.
    """
    w = params.w[ids]                                    # (..., n, K)
    return (jnp.einsum("...nk,...k->...n", w.astype(jnp.float32),
                       h.astype(jnp.float32))
            + params.b[ids].astype(jnp.float32))


def full_logits(params: HeadParams, h: jax.Array) -> jax.Array:
    """All-label scores, O(K·C): h @ W^T + b."""
    return (jnp.einsum("...k,ck->...c", h.astype(jnp.float32),
                       params.w.astype(jnp.float32))
            + params.b.astype(jnp.float32))


ScoreFn = Callable[[HeadParams, jax.Array, jax.Array], jax.Array]


def kernel_score_fn() -> ScoreFn:
    """Candidate scoring through the `gather_scores` Pallas kernel.

    Same contract as :func:`candidate_scores` (arbitrary batch dims) — the
    kernel wants flat (T, K)/(T, n) operands, so batch dims are collapsed
    around the call. On TPU each touched row streams HBM→VMEM exactly once;
    elsewhere the kernel runs in interpret mode (see repro.kernels.ops).
    """
    from repro.kernels import ops

    def fn(params: HeadParams, h: jax.Array, ids: jax.Array) -> jax.Array:
        batch_shape = ids.shape[:-1]
        n = ids.shape[-1]
        flat = ops.gather_scores(params.w, params.b,
                                 h.reshape((-1, h.shape[-1])),
                                 ids.reshape((-1, n)))
        return flat.reshape(batch_shape + (n,))

    return fn


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------

def head_loss(cfg: HeadConfig, params: HeadParams, gen: Generator,
              h: jax.Array, x_gen: jax.Array, y: jax.Array, rng: jax.Array,
              score_fn: ScoreFn = candidate_scores,
              mask: Optional[jax.Array] = None):
    """Per-strategy training loss, mean over batch. Returns (loss, metrics).

    h: (..., K); x_gen: (..., k); y: (...,) int labels; mask: (...,) in
    {0,1} — masked-out positions (e.g. padding tokens) contribute 0.
    """
    batch_shape = y.shape
    if mask is None:
        mask = jnp.ones(batch_shape, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)

    def mean(v):
        return jnp.sum(v * mask) / denom

    metrics = {}
    if cfg.kind == "softmax":
        logits = full_logits(params, h)
        logz = jax.nn.logsumexp(logits, axis=-1)
        pos = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        loss = mean(logz - pos)
        if cfg.reg:  # score regularizer (cf. Eq. 6 with log p_n absorbed)
            loss = loss + cfg.reg * mean(jnp.mean(logits ** 2, axis=-1))
        metrics["pos_score"] = mean(pos)
        return loss, metrics

    y = y.astype(jnp.int32)
    pos_scores = score_fn(params, h, y[..., None])[..., 0]        # (...)

    if cfg.kind in ("uniform_ns", "freq_ns", "adversarial_ns", "nce"):
        neg_ids, neg_logp = sample_negatives(cfg, gen, x_gen, rng,
                                             batch_shape)
        neg_ids = jax.lax.stop_gradient(neg_ids)
        neg_logp = jax.lax.stop_gradient(neg_logp)
        neg_scores = score_fn(params, h, neg_ids)                 # (..., n)
        if cfg.kind == "nce":
            # NCE: discriminator sees xi - log(nu * p_n); learns full scores.
            ln_nu = jnp.log(float(cfg.n_neg))
            pos_logp = jax.lax.stop_gradient(
                noise_log_prob(cfg, gen, x_gen, y))
            u_pos = pos_scores - pos_logp - ln_nu
            u_neg = neg_scores - neg_logp - ln_nu
            loss = mean(-jax.nn.log_sigmoid(u_pos)
                        - jnp.sum(jax.nn.log_sigmoid(-u_neg), axis=-1))
        else:
            # Eq. 2 (n_neg-sample generalization; paper: n_neg = 1).
            loss = mean(-jax.nn.log_sigmoid(pos_scores)
                        - jnp.mean(jax.nn.log_sigmoid(-neg_scores), axis=-1))
            if cfg.reg:
                # Eq. 6: regularize the *unbiased* scores xi + log p_n.
                pos_logp = jax.lax.stop_gradient(
                    noise_log_prob(cfg, gen, x_gen, y))
                r = ((pos_scores + pos_logp) ** 2
                     + jnp.mean((neg_scores + neg_logp) ** 2, axis=-1))
                loss = loss + cfg.reg * mean(r)
        metrics["pos_score"] = mean(pos_scores)
        metrics["neg_score"] = mean(jnp.mean(neg_scores, axis=-1))
        return loss, metrics

    if cfg.kind == "sampled_softmax":
        neg_ids, neg_logp = sample_negatives(cfg, gen, x_gen, rng,
                                             batch_shape)
        neg_ids = jax.lax.stop_gradient(neg_ids)
        neg_logp = jax.lax.stop_gradient(neg_logp)
        pos_logp = jax.lax.stop_gradient(noise_log_prob(cfg, gen, x_gen, y))
        neg_scores = score_fn(params, h, neg_ids)
        # logQ-corrected logits over the candidate set {y} U negatives.
        cand = jnp.concatenate([(pos_scores - pos_logp)[..., None],
                                neg_scores - neg_logp], axis=-1)
        if cfg.mask_accidental:
            hit = (neg_ids == y[..., None])
            cand = cand.at[..., 1:].set(
                jnp.where(hit, -jnp.inf, cand[..., 1:]))
        loss = mean(jax.nn.logsumexp(cand, axis=-1) - cand[..., 0])
        metrics["pos_score"] = mean(pos_scores)
        return loss, metrics

    if cfg.kind == "ove":
        # One-vs-Each bound: -log p(y) <= sum_{y' != y} softplus(xi_y'-xi_y);
        # stochastic estimate with n uniform negatives scaled by (C-1)/n.
        neg_ids, _ = sample_negatives(cfg, gen, x_gen, rng, batch_shape)
        neg_ids = jax.lax.stop_gradient(neg_ids)
        neg_scores = score_fn(params, h, neg_ids)
        scale = (cfg.num_labels - 1) / cfg.n_neg
        pair = jax.nn.softplus(neg_scores - pos_scores[..., None])
        pair = pair * (neg_ids != y[..., None])   # exclude accidental y'=y
        loss = mean(scale * jnp.mean(pair, axis=-1))
        metrics["pos_score"] = mean(pos_scores)
        return loss, metrics

    if cfg.kind == "augment_reduce":
        # A&R softmax bound with a stochastic 'reduce' step: importance-
        # sampled partition estimate log(e^{xi_y} + (C-1) mean_j e^{xi_j}).
        neg_ids, _ = sample_negatives(cfg, gen, x_gen, rng, batch_shape)
        neg_ids = jax.lax.stop_gradient(neg_ids)
        neg_scores = score_fn(params, h, neg_ids)
        ln_rest = (jax.nn.logsumexp(neg_scores, axis=-1)
                   + jnp.log((cfg.num_labels - 1) / cfg.n_neg))
        logz = jnp.logaddexp(pos_scores, ln_rest)
        loss = mean(logz - pos_scores)
        metrics["pos_score"] = mean(pos_scores)
        return loss, metrics

    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Prediction (bias removal, Eq. 5).
# ---------------------------------------------------------------------------

def predictive_scores(cfg: HeadConfig, params: HeadParams, gen: Generator,
                      h: jax.Array, x_gen: jax.Array) -> jax.Array:
    """Unbiased predictive scores over all C labels.

    For `adversarial_ns` this is Theorem 1 / Eq. 5:
        xi_softmax = xi_ns + log p_n(y|x) + const,
    with log p_n evaluated densely for all labels in O(C·k) via the
    level-recursive tree pass. For `freq_ns` the correction is the constant-
    per-label log-frequency. Uniform corrections are argmax-irrelevant.
    """
    scores = full_logits(params, h)
    if not cfg.debias:
        return scores
    if cfg.kind == "adversarial_ns" and gen.tree is not None:
        return scores + tree_lib.log_prob_all(gen.tree, x_gen)
    if cfg.kind == "freq_ns":
        return scores + gen.freq_log
    return scores


def rescore_candidates(cfg: HeadConfig, params: HeadParams, h: jax.Array,
                       cand: jax.Array, log_pn: jax.Array, topk: int,
                       score_fn: ScoreFn = candidate_scores
                       ) -> Tuple[jax.Array, jax.Array]:
    """Score + Eq. 5 debias a proposed candidate set, keep the top ``topk``.

    The re-scoring tail shared by :func:`predictive_topk` and the serving
    engine's candidate-cache path (repro.serve.engine) — one implementation
    so the two stay byte-identical. ``cand`` entries < 0 are dead slots and
    come back as label -1 with score -inf.
    """
    valid = cand >= 0
    xi = score_fn(params, h, jnp.maximum(cand, 0))
    scores = xi + log_pn if cfg.debias else xi
    scores = jnp.where(valid, scores, -jnp.inf)
    top, sel = jax.lax.top_k(scores, topk)
    labels = jnp.take_along_axis(cand, sel, axis=-1)
    return top, labels


def predictive_topk(cfg: HeadConfig, params: HeadParams, gen: Generator,
                    h: jax.Array, x_gen: jax.Array, topk: int,
                    beam: Optional[int] = None,
                    score_fn: ScoreFn = candidate_scores
                    ) -> Tuple[jax.Array, jax.Array]:
    """Top-``topk`` unbiased predictive (scores, labels) without any O(C) pass.

    For `adversarial_ns`, beam search over the generator tree proposes
    ``beam`` candidates ranked by log p_n(y|x) in O(beam·k·log C); only those
    are scored (`score_fn`, an O(beam·K) gather-and-dot or the gather_scores
    Pallas kernel) and Eq. 5 debiasing is applied on the candidate set:
    final score xi_y + log p_n(y|x). The generator is trained toward p_D
    (Theorem 2), so its high-probability set is exactly the candidate set
    the debiased argmax lives in; with ``beam >= C_pad`` the result equals
    the dense :func:`predictive_scores` top-k exactly.

    Other head kinds have no conditional candidate structure and fall back
    to dense scoring + top_k. Returns (scores, labels), each (..., topk);
    slots beyond the number of live candidates carry score -inf, label -1.
    """
    if cfg.kind != "adversarial_ns" or gen.tree is None:
        scores = predictive_scores(cfg, params, gen, h, x_gen)
        top, labels = jax.lax.top_k(scores, topk)
        return top, labels.astype(jnp.int32)
    if beam is None:
        beam = max(4 * topk, 16)
    beam = min(beam, tree_lib.padded_size(cfg.num_labels))
    cand, log_pn = tree_lib.beam_search(gen.tree, x_gen, beam, beam)
    top, labels = rescore_candidates(cfg, params, h, cand, log_pn,
                                     min(topk, beam), score_fn=score_fn)
    if topk > beam:    # keep the documented (..., topk) output shape
        pad = [(0, 0)] * (labels.ndim - 1) + [(0, topk - beam)]
        top = jnp.pad(top, pad, constant_values=-jnp.inf)
        labels = jnp.pad(labels, pad, constant_values=-1)
    return top, labels


def predictive_log_likelihood(cfg, params, gen, h, x_gen, y,
                              mask: Optional[jax.Array] = None):
    """Mean test log-likelihood log softmax(scores)[y] (paper Fig. 1)."""
    scores = predictive_scores(cfg, params, gen, h, x_gen)
    logp = scores - jax.nn.logsumexp(scores, axis=-1, keepdims=True)
    pos = jnp.take_along_axis(logp, y[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(pos)
    return jnp.sum(pos * mask) / jnp.maximum(mask.sum(), 1.0)


def predictive_accuracy(cfg, params, gen, h, x_gen, y,
                        mask: Optional[jax.Array] = None):
    scores = predictive_scores(cfg, params, gen, h, x_gen)
    correct = (jnp.argmax(scores, axis=-1) == y).astype(jnp.float32)
    if mask is None:
        return jnp.mean(correct)
    return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1.0)
