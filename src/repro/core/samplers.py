"""Pluggable negative-sampling proposals: the ``NegativeSampler`` protocol.

The paper's Theorem 2 says gradient SNR is maximized when the proposal
p_n(y|x) matches the data distribution p_D(y|x). PR 5 factored the
*objective* out of the sampler (`kernels.sampled_loss.loss_and_coeffs`);
this module factors out the *proposal*, so the adversarial tree can be
benchmarked head-to-head against real alternatives instead of only the
uniform/unigram strawmen hard-wired into ``heads.sample_negatives``.

Every sampler implements three methods:

  sample(rng, x_gen, shape) -> (ids, log_pn)
      Draw proposal labels with the given shape (= batch_shape + (n_neg,));
      ``x_gen`` is the conditioning feature with shape batch_shape + (k,)
      (conditional samplers broadcast it over the trailing draw axis).
      ``log_pn`` is the *exact* log proposal probability of each draw —
      required for Eq. 5 debiasing and the NCE / sampled-softmax
      corrections, so approximate samplers must report the probability of
      the distribution they actually sampled from, not of the
      distribution they approximate.
  log_prob(x_gen, y) -> log p_n(y|x)
      Proposal log-probability of given labels (positive-slot debiasing).
  log_prob_all(x_gen) -> (..., C)
      Dense log p_n(·|x) for all labels — used for full-vocab Eq. 5 bias
      removal and for the protocol property tests (sums to 1).

Implementations:

  TreeSampler     — the paper's adversarial tree, O(k log C) per draw.
  UniformSampler  — uniform over labels, O(1).
  UnigramSampler  — empirical label frequencies via inverse CDF,
                    O(log C). The sampling CDF is built from *unsmoothed*
                    counts (count-0 labels get an empty interval and are
                    never drawn) while ``freq_log`` keeps the 1e-12
                    smoothing so debiasing of observed labels stays
                    finite.
  LshSampler      — signed-random-projection buckets over label
                    embeddings ("A Tale of Two Efficient and Informative
                    Negative Sampling Distributions", Daghaghi et al.):
                    negatives come from the query's bucket, mixed with an
                    eps-uniform floor so log_prob is finite everywhere.
  RffSampler      — Random Fourier (positive) feature approximation of
                    the softmax kernel (Rawat et al., sampled softmax
                    with kernel-based sampling): p_n(y|x) ∝ φ(x)·φ(e_y),
                    sampled exactly in O(D log C) via the feature-
                    component mixture, again with an eps-uniform floor.

All samplers are NamedTuples (hence jax pytrees): close over them in a
jitted train step, or pass them through pytree boundaries. Conditional
samplers built from a feature snapshot (LSH/RFF) are static during
training — unlike the tree they are not refreshed by the generator-fit
loop.
"""
from __future__ import annotations

from typing import NamedTuple, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_lib

SAMPLER_KINDS = ("tree", "uniform", "unigram", "lsh", "rff")


class NegativeSampler(Protocol):
    """Structural protocol — see the module docstring for the contract."""

    def sample(self, rng: jax.Array, x_gen: jax.Array,
               shape: Tuple[int, ...]) -> Tuple[jax.Array, jax.Array]:
        ...

    def log_prob(self, x_gen: jax.Array, y: jax.Array) -> jax.Array:
        ...

    def log_prob_all(self, x_gen: jax.Array) -> jax.Array:
        ...


def _align(x_gen: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """Broadcast conditioning features to ``shape + (k,)``.

    ``x_gen`` arrives either already per-draw (ndim-1 == len(shape)) or
    per-batch-element (one fewer dim: the trailing n_neg axis is added).
    """
    shape = tuple(shape)
    if x_gen.ndim - 1 != len(shape):
        x_gen = x_gen[..., None, :]
    return jnp.broadcast_to(x_gen, shape + (x_gen.shape[-1],))


# ---------------------------------------------------------------------------
# Tree / uniform / unigram (the proposals the head kinds used to hard-wire).
# ---------------------------------------------------------------------------

class TreeSampler(NamedTuple):
    """The paper's adversarial proposal: ancestral sampling down the
    balanced probabilistic tree, O(k log C) per draw (§3)."""
    tree: tree_lib.Tree

    def sample(self, rng, x_gen, shape):
        return tree_lib.sample(self.tree, _align(x_gen, shape), rng)

    def log_prob(self, x_gen, y):
        return tree_lib.log_prob(self.tree, _align(x_gen, y.shape), y)

    def log_prob_all(self, x_gen):
        return tree_lib.log_prob_all(self.tree, x_gen)


class UniformSampler(NamedTuple):
    """Uniform over the C real labels (baseline i)."""
    num_labels: int

    def sample(self, rng, x_gen, shape):
        ids = jax.random.randint(rng, shape, 0, self.num_labels)
        return ids, jnp.full(shape, -jnp.log(float(self.num_labels)))

    def log_prob(self, x_gen, y):
        return jnp.full(y.shape, -jnp.log(float(self.num_labels)))

    def log_prob_all(self, x_gen):
        c = self.num_labels
        return jnp.full(x_gen.shape[:-1] + (c,), -jnp.log(float(c)))


class UnigramSampler(NamedTuple):
    """Empirical label frequencies (baseline ii), inverse-CDF sampling.

    ``freq_cdf`` is the *unsmoothed* inclusive CDF normalized so the last
    entry is exactly 1.0; with ``side='right'`` a count-0 label owns an
    empty interval [cdf[i-1], cdf[i]) and can never be drawn, and a draw
    landing exactly on a boundary maps to the bucket *above* it (the one
    whose mass it belongs to). ``freq_log`` keeps the 1e-12 smoothing so
    debiasing an *observed* label (which may have count 0 under drift)
    stays finite.
    """
    freq_log: jax.Array   # (C,) smoothed log-frequencies (debiasing)
    freq_cdf: jax.Array   # (C,) unsmoothed inclusive CDF (sampling)

    def sample(self, rng, x_gen, shape):
        u = jax.random.uniform(rng, shape)
        ids = jnp.searchsorted(self.freq_cdf, u, side="right")
        ids = jnp.clip(ids, 0, self.freq_cdf.shape[0] - 1).astype(jnp.int32)
        return ids, self.freq_log[ids]

    def log_prob(self, x_gen, y):
        return self.freq_log[y]

    def log_prob_all(self, x_gen):
        return jnp.broadcast_to(self.freq_log,
                                x_gen.shape[:-1] + self.freq_log.shape)


def unigram_from_counts(label_counts) -> UnigramSampler:
    """Build a UnigramSampler from raw label counts.

    The single definition of the frequency proposal — ``heads.
    make_freq_generator`` delegates here so the compat shim and the
    protocol path cannot drift.
    """
    counts = jnp.asarray(label_counts, jnp.float32)
    smoothed = counts + 1e-12
    freq_log = jnp.log(smoothed / smoothed.sum())
    cdf = jnp.cumsum(counts)
    # Normalizing by the last entry makes it exactly 1.0 (x/x == 1 in
    # IEEE), so for any u < 1 searchsorted(side='right') returns a label
    # with positive count: zero-count labels repeat their predecessor's
    # cumulative value and never satisfy "first entry > u".
    cdf = cdf / cdf[-1]
    return UnigramSampler(freq_log=freq_log, freq_cdf=cdf)


# ---------------------------------------------------------------------------
# LSH proposal (signed random projections over label embeddings).
# ---------------------------------------------------------------------------

class LshSampler(NamedTuple):
    """Bucket-uniform proposal from signed-random-projection LSH.

    Labels are hashed by the sign pattern of ``n_bits`` random
    projections of their embeddings; a query hashes its feature with the
    same planes and draws negatives uniformly from its own bucket —
    labels whose embeddings point the same way as the query, i.e. the
    hard negatives an informative proposal should favor. The proposal is
    the mixture

        p(y|x) = eps/C + (1-eps) * [ 1{code(y)=code(x)} / |bucket(x)|
                                      (or 1/C if the bucket is empty) ]

    so ``log_prob`` is finite for every label (required by Eq. 5
    debiasing: the *positive* label is usually outside the bucket).
    The per-draw log proposal probability is exact, not approximate.
    """
    planes: jax.Array       # (k, n_bits) random hyperplanes
    label_code: jax.Array   # (C,) int32 bucket code per label
    order: jax.Array        # (C,) int32 labels sorted by code
    starts: jax.Array       # (2**n_bits + 1,) int32 bucket offsets
    eps: jax.Array          # scalar uniform-mixture weight

    @property
    def num_labels(self) -> int:
        return self.order.shape[0]

    def _code(self, x):
        bits = (x @ self.planes >= 0).astype(jnp.int32)
        pow2 = (2 ** jnp.arange(self.planes.shape[1])).astype(jnp.int32)
        return jnp.sum(bits * pow2, axis=-1)

    def _bucket_prob(self, code, member):
        """(1-eps)-component probability of a label given the query code
        and whether the label is in the query's bucket."""
        size = (self.starts[code + 1] - self.starts[code]).astype(
            jnp.float32)
        c = float(self.num_labels)
        return jnp.where(size > 0,
                         member.astype(jnp.float32)
                         / jnp.maximum(size, 1.0),
                         1.0 / c)

    def log_prob(self, x_gen, y):
        code = self._code(_align(x_gen, y.shape))
        p_sel = self._bucket_prob(code, self.label_code[y] == code)
        c = float(self.num_labels)
        return jnp.log(self.eps / c + (1.0 - self.eps) * p_sel)

    def log_prob_all(self, x_gen):
        code = self._code(x_gen)                            # (...,)
        member = self.label_code == code[..., None]         # (..., C)
        p_sel = self._bucket_prob(code[..., None], member)
        c = float(self.num_labels)
        return jnp.log(self.eps / c + (1.0 - self.eps) * p_sel)

    def sample(self, rng, x_gen, shape):
        x = _align(x_gen, shape)
        code = self._code(x)
        size = self.starts[code + 1] - self.starts[code]
        k_mix, k_off, k_uni = jax.random.split(rng, 3)
        # Draw from the bucket component with prob 1-eps (falling back to
        # uniform when the bucket is empty), else from the uniform floor.
        in_bucket = ((jax.random.uniform(k_mix, shape) >= self.eps)
                     & (size > 0))
        off = jnp.minimum(
            (jax.random.uniform(k_off, shape)
             * size.astype(jnp.float32)).astype(jnp.int32),
            jnp.maximum(size - 1, 0))
        bucket_ids = self.order[self.starts[code] + off]
        uni_ids = jax.random.randint(k_uni, shape, 0, self.num_labels)
        ids = jnp.where(in_bucket, bucket_ids, uni_ids).astype(jnp.int32)
        return ids, self.log_prob(x_gen, ids)


def fit_lsh_sampler(label_emb, n_bits: int = 8, eps: float = 0.05,
                    seed: int = 0) -> LshSampler:
    """Hash (C, k) label embeddings into 2**n_bits signed-projection
    buckets (host-side, O(C·k·n_bits))."""
    emb = np.asarray(label_emb, np.float32)
    c, k = emb.shape
    assert 1 <= n_bits <= 20, n_bits
    rng = np.random.default_rng(seed)
    planes = rng.standard_normal((k, n_bits)).astype(np.float32)
    codes = ((emb @ planes) >= 0).astype(np.int64) @ (
        2 ** np.arange(n_bits, dtype=np.int64))
    order = np.argsort(codes, kind="stable").astype(np.int32)
    counts = np.bincount(codes, minlength=2 ** n_bits)
    starts = np.zeros(2 ** n_bits + 1, np.int32)
    starts[1:] = np.cumsum(counts)
    return LshSampler(planes=jnp.asarray(planes),
                      label_code=jnp.asarray(codes, jnp.int32),
                      order=jnp.asarray(order),
                      starts=jnp.asarray(starts),
                      eps=jnp.float32(eps))


# ---------------------------------------------------------------------------
# RFF proposal (kernel-based sampled softmax).
# ---------------------------------------------------------------------------

class RffSampler(NamedTuple):
    """Positive random-feature approximation of the softmax proposal.

    With features φ(v) = exp(v·ω_d − |v|²/2) (Performer-style positive
    features; E_ω[φ(a)·φ(b)] = exp(a·b)), the kernel component of the
    proposal is

        p(y|x) ∝ Σ_d φ_d(x) z_{y,d},     z_{y,d} = φ_d(e_y),

    which is a D-component mixture: pick component d with probability
    ∝ φ_d(x)·Σ_y z_{y,d}, then draw y from the per-component CDF — exact
    sampling in O(D + log C) per draw with no O(C) work at sample time.
    Mixed with an eps/C uniform floor so log_prob is finite even where
    the feature map underflows. ``temperature`` T approximates
    softmax(x·e/T) by scaling both sides with 1/sqrt(T).

    Memory: ``log_z``/``comp_cdf`` are (C, D)/(D, C) — comparable to one
    extra head embedding; ``sample`` gathers a (batch, C) block of CDF
    rows, so this proposal is for benchmark-scale C, not the 2M-label
    regime (the tree stays O(k log C) there).
    """
    omega: jax.Array        # (k, D) random directions
    log_z: jax.Array        # (C, D) log label features
    comp_logsum: jax.Array  # (D,) log Σ_y z_{y,d}
    comp_cdf: jax.Array     # (D, C) per-component inclusive CDF (ends 1.0)
    query_scale: jax.Array  # scalar 1/sqrt(temperature)
    eps: jax.Array          # scalar uniform-mixture weight

    @property
    def num_labels(self) -> int:
        return self.log_z.shape[0]

    def _log_phi(self, x):
        xs = x.astype(jnp.float32) * self.query_scale
        return xs @ self.omega - 0.5 * jnp.sum(xs * xs, -1, keepdims=True)

    def _features(self, x):
        """exp-domain query features shifted by the global label-feature
        max (the shift cancels between numerator and normalizer)."""
        lp = self._log_phi(x)
        mx = jnp.max(lp, axis=-1, keepdims=True)
        e = jnp.exp(lp - mx)                          # (..., D)
        mz = jnp.max(self.log_z)
        den = e @ jnp.exp(self.comp_logsum - mz)      # (...,)
        return e, mz, den

    def _mixture_log_prob(self, num, den):
        c = float(self.num_labels)
        p_rff = jnp.where(den > 0, num / jnp.maximum(den, 1e-38), 1.0 / c)
        return jnp.log(self.eps / c + (1.0 - self.eps) * p_rff)

    def log_prob(self, x_gen, y):
        e, mz, den = self._features(_align(x_gen, y.shape))
        num = jnp.sum(e * jnp.exp(self.log_z[y] - mz), axis=-1)
        return self._mixture_log_prob(num, den)

    def log_prob_all(self, x_gen):
        e, mz, den = self._features(x_gen)
        num = e @ jnp.exp(self.log_z - mz).T          # (..., C)
        return self._mixture_log_prob(num, den[..., None])

    def sample(self, rng, x_gen, shape):
        x = _align(x_gen, shape)
        lp = self._log_phi(x)                         # shape + (D,)
        k_d, k_u, k_mix, k_uni = jax.random.split(rng, 4)
        d = jax.random.categorical(k_d, lp + self.comp_logsum)
        u = jax.random.uniform(k_u, shape)
        rows = self.comp_cdf[d.reshape(-1)]           # (T, C)
        rff_ids = jax.vmap(
            lambda r, uu: jnp.searchsorted(r, uu, side="right"))(
                rows, u.reshape(-1)).reshape(shape)
        rff_ids = jnp.clip(rff_ids, 0, self.num_labels - 1)
        use_rff = jax.random.uniform(k_mix, shape) >= self.eps
        uni_ids = jax.random.randint(k_uni, shape, 0, self.num_labels)
        ids = jnp.where(use_rff, rff_ids, uni_ids).astype(jnp.int32)
        return ids, self.log_prob(x_gen, ids)


def fit_rff_sampler(label_emb, n_features: int = 64, eps: float = 0.05,
                    temperature: float = 1.0, seed: int = 0) -> RffSampler:
    """Build the RFF proposal from (C, k) label embeddings (host-side,
    float64 so the per-component CDFs are well conditioned)."""
    emb = np.asarray(label_emb, np.float64)
    c, k = emb.shape
    scale = 1.0 / np.sqrt(float(temperature))
    rng = np.random.default_rng(seed)
    omega = rng.standard_normal((k, n_features))
    emb_s = emb * scale
    log_z = emb_s @ omega - 0.5 * (emb_s ** 2).sum(1, keepdims=True)
    mz = log_z.max()
    z = np.exp(log_z - mz)                       # (C, D)
    comp_sum = z.sum(0)                          # (D,)
    comp_logsum = np.log(np.maximum(comp_sum, 1e-300)) + mz
    cdf = np.cumsum(z, axis=0).T                 # (D, C)
    last = cdf[:, -1:]
    # Dividing each row by its own last entry makes it exactly 1.0, so
    # side='right' sampling never falls off the end (see UnigramSampler).
    cdf = np.where(last > 0, cdf / np.maximum(last, 1e-300),
                   (np.arange(1, c + 1, dtype=np.float64) / c)[None, :])
    return RffSampler(omega=jnp.asarray(omega, jnp.float32),
                      log_z=jnp.asarray(log_z, jnp.float32),
                      comp_logsum=jnp.asarray(comp_logsum, jnp.float32),
                      comp_cdf=jnp.asarray(cdf, jnp.float32),
                      query_scale=jnp.float32(scale),
                      eps=jnp.float32(eps))


# ---------------------------------------------------------------------------
# Construction helpers.
# ---------------------------------------------------------------------------

def class_mean_embeddings(x_gen, labels, num_labels: int) -> np.ndarray:
    """(C, k) label embeddings as class means of generator features
    (labels never observed get the zero vector)."""
    x = np.asarray(x_gen, np.float64)
    y = np.asarray(labels).reshape(-1)
    sums = np.zeros((num_labels, x.shape[-1]), np.float64)
    np.add.at(sums, y, x.reshape(-1, x.shape[-1]))
    counts = np.bincount(y, minlength=num_labels).astype(np.float64)
    return sums / np.maximum(counts, 1.0)[:, None]


def fit_sampler(kind: str, x_gen, labels, num_labels: int, seed: int = 0,
                **kwargs) -> NegativeSampler:
    """Fit a sampler of the given kind from (features, labels) snapshots.

    ``tree`` runs the full generator fit (repro.core.tree_fit); ``lsh``/
    ``rff`` embed labels as class means of ``x_gen``; ``unigram`` needs
    only label counts; ``uniform`` ignores the snapshot.
    """
    assert kind in SAMPLER_KINDS, kind
    if kind == "uniform":
        return UniformSampler(num_labels=num_labels)
    if kind == "unigram":
        counts = np.bincount(np.asarray(labels).reshape(-1),
                             minlength=num_labels).astype(np.float32)
        return unigram_from_counts(counts)
    if kind == "tree":
        from repro.core.tree_fit import FitConfig, fit_tree
        tree = fit_tree(np.asarray(x_gen, np.float32),
                        np.asarray(labels), num_labels,
                        config=kwargs.pop("config", None)
                        or FitConfig(reg=0.1, seed=seed))
        return TreeSampler(tree=tree)
    emb = class_mean_embeddings(x_gen, labels, num_labels)
    if kind == "lsh":
        return fit_lsh_sampler(emb, seed=seed, **kwargs)
    return fit_rff_sampler(emb, seed=seed, **kwargs)


def sampler_from_config(cfg, gen) -> NegativeSampler:
    """Compatibility shim: the proposal a ``HeadConfig.kind`` hard-wired
    before the protocol existed. ``gen`` is the ``heads.Generator``."""
    if cfg.kind in ("uniform_ns", "ove", "augment_reduce"):
        return UniformSampler(num_labels=cfg.num_labels)
    if cfg.kind == "freq_ns":
        return UnigramSampler(freq_log=gen.freq_log,
                              freq_cdf=gen.freq_cdf)
    if cfg.kind in ("adversarial_ns", "nce", "sampled_softmax"):
        return TreeSampler(tree=gen.tree)
    raise ValueError(f"{cfg.kind} draws no negatives")
