"""jit'd wrappers for the Pallas kernels with interpret-mode dispatch.

On this CPU container kernels run with ``interpret=True`` (the Pallas
interpreter executes the kernel body on CPU for correctness); on TPU the same
call sites compile to Mosaic. ``use_pallas(False)`` routes everything to the
pure-jnp references (repro.kernels.ref) for A/B testing.

The ``use_pallas`` flag is read at *call* time and passed into the jitted
impls as a static argument: each setting gets its own jit cache entry, so
toggling mid-process really switches the executed path (a trace-time read
would be baked into the first trace and silently ignored afterwards).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref as ref_lib
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gather_scores import gather_scores as _gather
from repro.kernels.sampled_loss import sampled_head_loss as _sampled_loss
from repro.kernels.segment_scores import segment_stats as _segstats
from repro.kernels.tree_logprob import tree_logprob_all as _treelp

_STATE = {"use_pallas": True, "interpret": None}


def use_pallas(on: bool):
    _STATE["use_pallas"] = on


def _interpret() -> bool:
    if _STATE["interpret"] is None:
        _STATE["interpret"] = jax.devices()[0].platform != "tpu"
    return _STATE["interpret"]


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "pallas"))
def _flash_impl(q, k, v, causal: bool, window: int, softcap: float,
                pallas: bool):
    if not pallas:
        return ref_lib.flash_attention_ref(q, k, v, causal=causal,
                                           window=window, softcap=softcap)
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  interpret=_interpret())


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0):
    return _flash_impl(q, k, v, causal, window, softcap,
                       _STATE["use_pallas"])


@functools.partial(jax.jit, static_argnames=("pallas",))
def _treelp_impl(w, b, x, pallas: bool):
    if not pallas:
        return ref_lib.tree_logprob_all_ref(w, b, x)
    return _treelp(w, b, x, interpret=_interpret())


def tree_logprob_all(w, b, x):
    return _treelp_impl(w, b, x, _STATE["use_pallas"])


@functools.partial(jax.jit, static_argnames=("pallas",))
def _gather_impl(w, b, h, ids, pallas: bool):
    if not pallas:
        return ref_lib.gather_scores_ref(w, b, h, ids)
    return _gather(w, b, h, ids, interpret=_interpret())


def gather_scores(w, b, h, ids):
    return _gather_impl(w, b, h, ids, _STATE["use_pallas"])


@functools.partial(jax.jit, static_argnames=(
    "kind", "num_labels", "reg", "softcap", "mask_accidental", "pallas"))
def _sampled_loss_impl(w, b, h, ids, slot_logp, kind: str, num_labels: int,
                       reg: float, softcap: float, mask_accidental: bool,
                       pallas: bool):
    if not pallas:
        return ref_lib.sampled_head_loss_ref(
            w, b, h, ids, slot_logp, kind=kind, num_labels=num_labels,
            reg=reg, softcap=softcap, mask_accidental=mask_accidental)
    return _sampled_loss(w, b, h, ids, slot_logp, kind=kind,
                         num_labels=num_labels, reg=reg, softcap=softcap,
                         mask_accidental=mask_accidental,
                         interpret=_interpret())


def sampled_head_loss(w, b, h, ids, slot_logp, *, kind: str,
                      num_labels: int, reg: float = 0.0,
                      softcap: float = 0.0, mask_accidental: bool = True):
    """Fused sampled-head loss fwd+bwd (repro.kernels.sampled_loss):
    (loss_vec, coeff, xi, dh) — slot 0 of ``ids`` is the positive."""
    return _sampled_loss_impl(w, b, h, ids, slot_logp, kind, num_labels,
                              reg, softcap, mask_accidental,
                              _STATE["use_pallas"])


@functools.partial(jax.jit, static_argnames=("num_segments", "pallas"))
def _segstats_impl(vals, seg, num_segments: int, pallas: bool):
    if not pallas:
        return ref_lib.segment_stats_ref(vals, seg, num_segments)
    return _segstats(vals, seg, num_segments, interpret=_interpret())


def segment_stats(vals, seg, num_segments: int):
    """Segment-summed fit statistics (repro.genfit hot reduction)."""
    return _segstats_impl(vals, seg, num_segments, _STATE["use_pallas"])
