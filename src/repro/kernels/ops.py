"""jit'd wrappers for the Pallas kernels with interpret-mode dispatch.

On this CPU container kernels run with ``interpret=True`` (the Pallas
interpreter executes the kernel body on CPU for correctness); on TPU the same
call sites compile to Mosaic. ``use_pallas(False)`` routes everything to the
pure-jnp references (repro.kernels.ref) for A/B testing.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref as ref_lib
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gather_scores import gather_scores as _gather
from repro.kernels.segment_scores import segment_stats as _segstats
from repro.kernels.tree_logprob import tree_logprob_all as _treelp

_STATE = {"use_pallas": True, "interpret": None}


def use_pallas(on: bool):
    _STATE["use_pallas"] = on


def _interpret() -> bool:
    if _STATE["interpret"] is None:
        _STATE["interpret"] = jax.devices()[0].platform != "tpu"
    return _STATE["interpret"]


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0):
    if not _STATE["use_pallas"]:
        return ref_lib.flash_attention_ref(q, k, v, causal=causal,
                                           window=window, softcap=softcap)
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  interpret=_interpret())


@jax.jit
def tree_logprob_all(w, b, x):
    if not _STATE["use_pallas"]:
        return ref_lib.tree_logprob_all_ref(w, b, x)
    return _treelp(w, b, x, interpret=_interpret())


@jax.jit
def gather_scores(w, b, h, ids):
    if not _STATE["use_pallas"]:
        return ref_lib.gather_scores_ref(w, b, h, ids)
    return _gather(w, b, h, ids, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("num_segments",))
def segment_stats(vals, seg, num_segments: int):
    """Segment-summed fit statistics (repro.genfit hot reduction)."""
    if not _STATE["use_pallas"]:
        return ref_lib.segment_stats_ref(vals, seg, num_segments)
    return _segstats(vals, seg, num_segments, interpret=_interpret())
