"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, scale: float | None = None):
    """q: (B,H,Sq,hd); k,v: (B,H,Skv,hd). Returns (B,H,Sq,hd) in q.dtype.

    Positions are aligned at the END: q position i corresponds to absolute
    position (Skv - Sq + i) — the decode/prefill convention.
    """
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(sq) + (skv - sq)
    k_pos = jnp.arange(skv)
    delta = q_pos[:, None] - k_pos[None, :]
    valid = jnp.ones((sq, skv), bool)
    if causal:
        valid &= delta >= 0
    if window > 0:
        valid &= delta < window
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def tree_logprob_all_ref(w, b, x):
    """Dense per-leaf tree log-probs. w: (n_nodes,k), b: (n_nodes,),
    x: (B,k) -> (B, C_pad) over leaves in natural order."""
    n_nodes = b.shape[0]
    depth = (n_nodes + 1).bit_length() - 1
    bsz = x.shape[0]
    logp = jnp.zeros((bsz, 1), jnp.float32)
    for level in range(depth):
        lo, n_lvl = (1 << level) - 1, 1 << level
        z = x.astype(jnp.float32) @ w[lo:lo + n_lvl].T.astype(jnp.float32) \
            + b[lo:lo + n_lvl]
        children = jnp.stack([logp + jax.nn.log_sigmoid(-z),
                              logp + jax.nn.log_sigmoid(z)], axis=-1)
        logp = children.reshape(bsz, 2 * n_lvl)
    return logp


def segment_stats_ref(vals, seg, num_segments: int):
    """Segment-summed statistics: vals (N,D), seg (N,) int ->
    (num_segments, D) fp32. Out-of-range ids are dropped (padding)."""
    return jax.ops.segment_sum(vals.astype(jnp.float32), seg,
                               num_segments=num_segments)


def gather_scores_ref(w, b, h, ids):
    """Sampled-head scores: w: (C,K), b: (C,), h: (T,K), ids: (T,n) ->
    (T,n) fp32."""
    rows = w[ids]                                  # (T,n,K)
    return (jnp.einsum("tnk,tk->tn", rows.astype(jnp.float32),
                       h.astype(jnp.float32))
            + b[ids].astype(jnp.float32))


def sampled_head_loss_ref(w, b, h, ids, slot_logp, *, kind: str,
                          num_labels: int, reg: float = 0.0,
                          softcap: float = 0.0, mask_accidental: bool = True):
    """The fused sampled-loss chain, unfused: gather (materializes the
    (T, m, K) rows) → einsum → per-token loss/coefficients → second gather
    for dh. Same contract as ``sampled_loss.sampled_head_loss``:
    (loss_vec (T,), coeff (T,m), xi (T,m), dh (T,K)) fp32; slot 0 is the
    positive."""
    from repro.kernels.sampled_loss import loss_and_coeffs

    scores = gather_scores_ref(w, b, h, ids)
    acc_hit = ids == ids[:, :1]
    acc_hit = acc_hit.at[:, 0].set(False)
    loss, coeff, xi = loss_and_coeffs(
        scores, slot_logp, acc_hit, kind=kind, num_labels=num_labels,
        reg=reg, softcap=softcap, mask_accidental=mask_accidental)
    dh = jnp.einsum("tn,tnk->tk", coeff, w[ids].astype(jnp.float32))
    return loss, coeff, xi, dh
