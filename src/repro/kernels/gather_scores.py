"""Sampled-head score Pallas TPU kernel: xi = w[ids]·h + b[ids].

This is the paper's O(K) replacement for the O(K·C) logits matmul: per token
only 1 + n_neg rows of the (C, K) output embedding are touched. The XLA
lowering of the same computation materializes the gathered (T, n, K) rows in
HBM before the dot; this kernel streams each row HBM→VMEM once and reduces
it immediately (row never round-trips), using scalar prefetch for the
data-dependent row indices — the TPU-native analogue of the paper's sparse
gradient update.

Grid: (T / blk_t,); ids arrive via scalar prefetch (SMEM); each step loads
its h block (blk_t, K) into VMEM, then loops over blk_t*n rows with dynamic
row loads from the HBM-resident table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, w_ref, b_ref, h_ref, o_ref, *, blk_t: int, n: int):
    it = pl.program_id(0)
    h = h_ref[...].astype(jnp.float32)                 # (blk_t, K)

    def body(j, _):
        t = j // n
        c = j % n
        row_id = ids_ref[it * blk_t * n + j]
        w_row = pl.load(w_ref, (pl.dslice(row_id, 1), slice(None)))
        b_val = pl.load(b_ref, (pl.dslice(row_id, 1),))
        score = (jnp.sum(w_row[0].astype(jnp.float32) * h[t])
                 + b_val[0].astype(jnp.float32))
        pl.store(o_ref, (pl.dslice(t, 1), pl.dslice(c, 1)),
                 score[None, None])
        return 0

    jax.lax.fori_loop(0, blk_t * n, body, 0)


def gather_scores(w, b, h, ids, *, blk_t: int = 256,
                  interpret: bool = False):
    """w: (C,K), b: (C,), h: (T,K), ids: (T,n) int32 -> (T,n) fp32."""
    t, k = h.shape
    n = ids.shape[-1]
    blk_t = min(blk_t, t)
    assert t % blk_t == 0, (t, blk_t)

    kernel = functools.partial(_kernel, blk_t=blk_t, n=n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t // blk_t,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # w stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),      # b stays in HBM
            pl.BlockSpec((blk_t, k), lambda it, ids: (it, 0)),
        ],
        out_specs=pl.BlockSpec((blk_t, n), lambda it, ids: (it, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(ids.reshape(-1).astype(jnp.int32), w, b, h)
