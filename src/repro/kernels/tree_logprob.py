"""Dense tree log-prob Pallas TPU kernel (the Eq. 5 bias-removal hot spot).

Computes log p_n(leaf | x) for ALL leaves: serving adds this (B, C) tensor to
the logits, so at gemma2 scale it is a (B, 256k) production every decode
step. The XLA path materializes every intermediate level in HBM
(sum_l 2^l = C extra floats per example); this kernel keeps the whole
recursion for a C_blk-leaf subtree in VMEM.

Key structural insight (TPU adaptation of the pointer-walking CPU code):
for an ALIGNED contiguous leaf block [c0, c0 + C_blk), the ancestry factors
as   logp(leaf) = prefix(x, c0)  +  subtree-recursion(x, nodes of block),
where the prefix chain has depth - log2(C_blk) nodes whose indices are
affine in the block id (dynamic-slice loads), and the subtree nodes occupy
one contiguous range per level (C_blk - 1 rows total). Grid: (B/blk_b,
C/blk_c); VMEM per step ~ blk_b·blk_c + blk_c·k floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, o_ref, *, depth: int, blk_c: int, k: int):
    ic = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)                 # (blk_b, k)
    blk_b = x.shape[0]
    sub_depth = blk_c.bit_length() - 1                 # levels inside block
    pre_depth = depth - sub_depth                      # levels above block

    # ---- prefix chain: ancestors of the whole leaf block ----
    prefix = jnp.zeros((blk_b, 1), jnp.float32)
    for level in range(pre_depth):
        # Node visited at `level` on the path to leaf block `ic`:
        # idx = 2^level - 1 + (leaf0 >> (depth - level)), leaf0 = ic*blk_c.
        idx = (1 << level) - 1 + (ic * blk_c >> (depth - level))
        w_row = pl.load(w_ref, (pl.dslice(idx, 1), slice(None)))   # (1,k)
        b_val = pl.load(b_ref, (pl.dslice(idx, 1),))
        z = (jax.lax.dot_general(
            x, w_row.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) + b_val)           # (blk_b,1)
        bit = (ic * blk_c >> (depth - 1 - level)) & 1
        zeta = jnp.where(bit == 1, 1.0, -1.0)
        prefix = prefix + jax.nn.log_sigmoid(zeta * z)

    # ---- in-block recursion over sub_depth levels ----
    logp = jnp.broadcast_to(prefix, (blk_b, 1))
    for level in range(sub_depth):
        n_lvl = 1 << level
        # Within full tree: nodes [2^L - 1 + ic*blk_c/2^(sub_depth-level),
        # + n_lvl) with L = pre_depth + level.
        full_level = pre_depth + level
        base = (1 << full_level) - 1 + ic * n_lvl
        w_lvl = pl.load(w_ref, (pl.dslice(base, n_lvl), slice(None)))
        b_lvl = pl.load(b_ref, (pl.dslice(base, n_lvl),))
        z = (jax.lax.dot_general(
            x, w_lvl.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) + b_lvl)   # (blk_b, n_lvl)
        left = logp + jax.nn.log_sigmoid(-z)
        right = logp + jax.nn.log_sigmoid(z)
        logp = jnp.stack([left, right], axis=-1).reshape(blk_b, 2 * n_lvl)

    o_ref[...] = logp


def tree_logprob_all(w, b, x, *, blk_b: int = 128, blk_c: int = 256,
                     interpret: bool = False):
    """w: (n_nodes, k), b: (n_nodes,), x: (B, k) -> (B, C_pad) fp32."""
    n_nodes = b.shape[0]
    depth = (n_nodes + 1).bit_length() - 1
    assert (1 << depth) == n_nodes + 1
    c_pad = 1 << depth
    bsz, k = x.shape
    blk_c = min(blk_c, c_pad)
    blk_b = min(blk_b, bsz)
    assert c_pad % blk_c == 0 and bsz % blk_b == 0
    assert (blk_c & (blk_c - 1)) == 0, "blk_c must be a power of two"

    kernel = functools.partial(_kernel, depth=depth, blk_c=blk_c, k=k)
    return pl.pallas_call(
        kernel,
        grid=(bsz // blk_b, c_pad // blk_c),
        in_specs=[
            pl.BlockSpec((blk_b, k), lambda ib, ic: (ib, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # full node table (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((blk_b, blk_c), lambda ib, ic: (ib, ic)),
        out_shape=jax.ShapeDtypeStruct((bsz, c_pad), jnp.float32),
        interpret=interpret,
    )(x, w, b)
