"""Fused sampled-head loss Pallas TPU kernel: forward + backward in one pass.

The sampled-head train step's hot chain is gather → einsum → loss →
scatter: XLA materializes the gathered (T, m, K) rows in HBM, the loss is a
handful of elementwise ops, and the backward pass re-gathers the same rows
to form ``dh`` and scatter the head gradient. This kernel streams each
touched row HBM→VMEM exactly once per step and computes *everything* that
depends on it in that pass:

  * the gather·dot candidate scores  xi = w[ids]·h + b[ids],
  * the per-token loss terms (logistic for the NS/NCE family, logQ-corrected
    logsumexp for sampled softmax, the OVE / A&R bounds),
  * the scatter coefficients  coeff = dL/d(raw score)  — for every sampled
    strategy the per-row head gradient is ``coeff · h`` (see
    :func:`loss_and_coeffs`), so coeff IS the backward pass,
  * the trunk cotangent  dh = coeff @ w[ids]  from the VMEM-resident rows.

The loss/coefficient math (:func:`loss_and_coeffs`) is plain jnp shared
verbatim between the kernel body and the pure-jnp oracle
(``repro.kernels.ref.sampled_head_loss_ref``) — the only thing the kernel
adds is the single-streaming row pipeline. Masking and the per-unique-row
deduplication live outside (``repro.optim.sparse.accumulate_rows``): they
are O(T) / O(T·m) and independent of K and C.

Grid: (T / blk_t,); ids arrive via scalar prefetch (SMEM). Each grid step
loads its h block into VMEM, gathers its blk_t·m rows into a VMEM scratch
(dynamic row loads from the HBM-resident table), then runs the vectorized
block math (VPU elementwise + one (blk_t·m, K) contraction for dh).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Head kinds with a sampled candidate set (everything except `softmax`).
SAMPLED_KINDS = ("uniform_ns", "freq_ns", "adversarial_ns", "nce",
                 "sampled_softmax", "ove", "augment_reduce")
_NS_FAMILY = ("uniform_ns", "freq_ns", "adversarial_ns")


def loss_and_coeffs(scores, slot_logp, acc_hit, *, kind: str,
                    num_labels: int, reg: float = 0.0,
                    softcap: float = 0.0, mask_accidental: bool = True
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-token sampled loss + analytic score gradients, every strategy.

    scores: (T, m) RAW candidate scores, slot 0 the positive, slots 1..m-1
    the negatives. slot_logp: (T, m) stop-grad noise log-probs (zeros where
    a strategy ignores them). acc_hit: (T, m) bool, True where a negative
    slot equals the positive id (slot 0 always False).

    Returns (loss_vec (T,), coeff (T, m), xi (T, m)) with
    ``coeff[t, j] = d loss_vec[t] / d scores[t, j]`` — the per-row head
    gradient is then ``coeff · h`` (w) and ``coeff`` (b), which is what
    makes the sparse path O(B·K·n_neg): no autodiff ever touches the
    (C, K) gather. ``xi`` are the softcapped scores (for metrics).

    The closed forms are the exact derivatives of the per-strategy
    objectives in ``repro.core.heads.head_loss`` (pinned by
    tests/test_sparse_update.py against jax.vjp over this function's own
    loss output).
    """
    scores = scores.astype(jnp.float32)
    n = scores.shape[-1] - 1
    if softcap:
        xi = softcap * jnp.tanh(scores / softcap)
        chain = 1.0 - jnp.square(xi / softcap)        # d xi / d score
    else:
        xi = scores
        chain = jnp.ones_like(scores)
    pos, neg = xi[..., 0], xi[..., 1:]

    if kind in _NS_FAMILY:
        # Eq. 2 logistic loss (+ Eq. 6 unbiased-score regularizer).
        loss = (-jax.nn.log_sigmoid(pos)
                - jnp.mean(jax.nn.log_sigmoid(-neg), axis=-1))
        g_pos = -jax.nn.sigmoid(-pos)
        g_neg = jax.nn.sigmoid(neg) / n
        if reg:
            unb = xi + slot_logp
            loss = loss + reg * (jnp.square(unb[..., 0])
                                 + jnp.mean(jnp.square(unb[..., 1:]), -1))
            g_pos = g_pos + 2.0 * reg * unb[..., 0]
            g_neg = g_neg + (2.0 * reg / n) * unb[..., 1:]
        g = jnp.concatenate([g_pos[..., None], g_neg], axis=-1)
    elif kind == "nce":
        ln_nu = jnp.log(float(n))
        u = xi - slot_logp - ln_nu
        loss = (-jax.nn.log_sigmoid(u[..., 0])
                - jnp.sum(jax.nn.log_sigmoid(-u[..., 1:]), axis=-1))
        g = jnp.concatenate([-jax.nn.sigmoid(-u[..., :1]),
                             jax.nn.sigmoid(u[..., 1:])], axis=-1)
    elif kind == "sampled_softmax":
        cand = xi - slot_logp
        if mask_accidental:
            cand = jnp.where(acc_hit, -jnp.inf, cand)
        loss = jax.nn.logsumexp(cand, axis=-1) - cand[..., 0]
        p = jax.nn.softmax(cand, axis=-1)
        g = jnp.concatenate([p[..., :1] - 1.0, p[..., 1:]], axis=-1)
    elif kind == "ove":
        ind = (~acc_hit[..., 1:]).astype(jnp.float32)
        scl = (num_labels - 1) / n
        diff = neg - pos[..., None]
        loss = scl * jnp.mean(jax.nn.softplus(diff) * ind, axis=-1)
        g_neg = (scl / n) * jax.nn.sigmoid(diff) * ind
        g = jnp.concatenate([-jnp.sum(g_neg, -1, keepdims=True), g_neg], -1)
    elif kind == "augment_reduce":
        ln_rest = (jax.nn.logsumexp(neg, axis=-1)
                   + jnp.log((num_labels - 1) / n))
        loss = jnp.logaddexp(pos, ln_rest) - pos
        a = jax.nn.sigmoid(ln_rest - pos)             # rest-mass weight
        g_neg = a[..., None] * jax.nn.softmax(neg, axis=-1)
        g = jnp.concatenate([-a[..., None], g_neg], axis=-1)
    else:
        raise ValueError(f"{kind} has no sampled candidate loss")
    return loss, g * chain, xi


def _kernel(ids_ref, w_ref, b_ref, h_ref, lp_ref, hit_ref, loss_ref,
            coeff_ref, xi_ref, dh_ref, rows_ref, brow_ref, *, blk_t: int,
            m: int, kind: str, num_labels: int, reg: float, softcap: float,
            mask_accidental: bool):
    it = pl.program_id(0)
    h = h_ref[...].astype(jnp.float32)                 # (blk_t, K)

    # Stream each touched row HBM→VMEM once; everything downstream reads
    # the VMEM-resident copy (scores on the MXU, dh on the MXU, loss/coeff
    # on the VPU) — the row never round-trips.
    def body(j, _):
        row_id = ids_ref[it * blk_t * m + j]
        pl.store(rows_ref, (pl.dslice(j, 1), slice(None)),
                 pl.load(w_ref, (pl.dslice(row_id, 1), slice(None))
                         ).astype(jnp.float32))
        pl.store(brow_ref, (pl.dslice(j // m, 1), pl.dslice(j % m, 1)),
                 pl.load(b_ref, (pl.dslice(row_id, 1),)
                         ).astype(jnp.float32)[:, None])
        return 0

    jax.lax.fori_loop(0, blk_t * m, body, 0)

    rows = rows_ref[...].reshape(blk_t, m, rows_ref.shape[-1])
    scores = jax.lax.dot_general(                      # (blk_t, m)
        rows, h, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) + brow_ref[...]
    loss, coeff, xi = loss_and_coeffs(
        scores, lp_ref[...].astype(jnp.float32), hit_ref[...] != 0,
        kind=kind, num_labels=num_labels, reg=reg, softcap=softcap,
        mask_accidental=mask_accidental)
    loss_ref[...] = loss[:, None]
    coeff_ref[...] = coeff
    xi_ref[...] = xi
    dh_ref[...] = jax.lax.dot_general(
        coeff[:, None, :], rows, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, 0, :]


def sampled_head_loss(w, b, h, ids, slot_logp, *, kind: str,
                      num_labels: int, reg: float = 0.0,
                      softcap: float = 0.0, mask_accidental: bool = True,
                      blk_t: int = 128, interpret: bool = False):
    """w: (C,K), b: (C,), h: (T,K), ids/slot_logp: (T,m) — slot 0 positive.

    Returns (loss_vec (T,), coeff (T,m), xi (T,m), dh (T,K)), all fp32.
    """
    t, k = h.shape
    m = ids.shape[-1]
    blk_t = min(blk_t, t)
    pad = (-t) % blk_t
    if pad:
        # Padding tokens score row 0 against h = 0; their outputs are
        # sliced off below (the caller's mask never sees them).
        h = jnp.concatenate([h, jnp.zeros((pad, k), h.dtype)], axis=0)
        ids = jnp.concatenate([ids, jnp.zeros((pad, m), ids.dtype)], axis=0)
        slot_logp = jnp.concatenate(
            [slot_logp, jnp.zeros((pad, m), slot_logp.dtype)], axis=0)
    t_pad = t + pad
    ids = ids.astype(jnp.int32)
    acc_hit = (ids == ids[:, :1]).astype(jnp.int32)
    acc_hit = acc_hit.at[:, 0].set(0)

    kernel = functools.partial(
        _kernel, blk_t=blk_t, m=m, kind=kind, num_labels=num_labels,
        reg=reg, softcap=softcap, mask_accidental=mask_accidental)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t_pad // blk_t,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # w stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),      # b stays in HBM
            pl.BlockSpec((blk_t, k), lambda it, ids: (it, 0)),
            pl.BlockSpec((blk_t, m), lambda it, ids: (it, 0)),
            pl.BlockSpec((blk_t, m), lambda it, ids: (it, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_t, 1), lambda it, ids: (it, 0)),
            pl.BlockSpec((blk_t, m), lambda it, ids: (it, 0)),
            pl.BlockSpec((blk_t, m), lambda it, ids: (it, 0)),
            pl.BlockSpec((blk_t, k), lambda it, ids: (it, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_t * m, k), jnp.float32),   # gathered rows
            pltpu.VMEM((blk_t, m), jnp.float32),       # gathered biases
        ],
    )
    loss, coeff, xi, dh = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((t_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((t_pad, m), jnp.float32),
            jax.ShapeDtypeStruct((t_pad, m), jnp.float32),
            jax.ShapeDtypeStruct((t_pad, k), jnp.float32),
        ],
        interpret=interpret,
    )(ids.reshape(-1), w, b, h, slot_logp.astype(jnp.float32), acc_hit)
    return loss[:t, 0], coeff[:t], xi[:t], dh[:t]
