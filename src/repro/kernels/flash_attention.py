"""Flash attention (forward) Pallas TPU kernel.

Covers the backbone's needs: causal masking, sliding windows (mixtral /
h2o-danube / gemma2 local layers / hymba), and gemma2's attention-logit
softcap — all fused, O(Sq·hd) VMEM per block, online softmax over KV blocks.

Grid: (B*H, Sq/blk_q, Skv/blk_k) with the KV dimension innermost
('arbitrary' semantics); running (m, l, acc) state lives in VMEM scratch and
is finalized on the last KV block. MXU alignment: blk_q/blk_k multiples of
128 in production (tests use smaller interpreted blocks).

Positions align at the end (q position i == absolute Skv - Sq + i), matching
both training (Sq == Skv) and decode-with-cache (Sq == 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 sq: int, skv: int, blk_q: int, blk_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (blk_q, hd)
    k = k_ref[0].astype(jnp.float32)                   # (blk_k, hd)
    v = v_ref[0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (blk_q, blk_k)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = (iq * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 0)
             + (skv - sq))
    k_pos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 1)
    delta = q_pos - k_pos
    valid = jnp.ones((blk_q, blk_k), jnp.bool_)
    if causal:
        valid &= delta >= 0
    if window > 0:
        valid &= delta < window
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_scr[...]                                # (blk_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                        # (blk_q, blk_k)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale=None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False):
    """q: (B,H,Sq,hd), k/v: (B,H,Skv,hd) -> (B,H,Sq,hd)."""
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    assert sq % blk_q == 0 and skv % blk_k == 0, (sq, skv, blk_q, blk_k)
    scale = float(scale) if scale is not None else 1.0 / (hd ** 0.5)

    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * h, skv, hd)
    vf = v.reshape(b * h, skv, hd)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=int(window),
        softcap=float(softcap), sq=sq, skv=skv, blk_q=blk_q, blk_k=blk_k)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // blk_q, skv // blk_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda bh, iq, ik: (bh, iq,
                                                                   0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            # VMEM: (blk_q,1) running max + sum, (blk_q,hd) accumulator.
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd)
