"""Batched segment-reduction Pallas TPU kernel (generator-fit hot spot).

The level-parallel generator fit (repro.genfit.levels) is built from one
primitive: *segment-summed sufficient statistics* — per-node/per-label
reductions of per-point score rows (the Δ_y Eq. 9 scores, the Newton
gradient rows, the flattened Hessian rows). On TPU an XLA scatter-add
serializes badly; this kernel instead casts the reduction as a sequence of
small one-hot matmuls: the grid walks point blocks (TPU grids execute
sequentially per core), each step builds the (blk_n, S) membership
one-hot with an iota compare — VPU work — and accumulates
``one_hotᵀ @ vals`` into the full (S, D) output block, which stays
resident in VMEM across grid steps (same output block every step). That
turns an irregular scatter into MXU-shaped dot_generals with a single
VMEM-resident accumulator.

Scope: S·D must fit in VMEM (the fit's segment counts per level are
≤ C_pad/2 nodes with D = k+1 or (k+1)² stats — a few MB at production
sizes; the wrapper asserts). Caller-visible semantics match
``jax.ops.segment_sum(vals, seg, num_segments)`` for int32 ``seg`` in
[0, S); out-of-range ids (the wrapper's padding rows) contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# VMEM budget for the resident (S, D) accumulator (fp32 bytes).
_ACC_BYTES_MAX = 8 * 1024 * 1024


def _kernel(seg_ref, vals_ref, out_ref, *, blk_n: int, s: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                                   # (blk_n, 1)
    vals = vals_ref[...].astype(jnp.float32)             # (blk_n, D)
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (blk_n, s), 1)
    onehot = (seg == seg_ids).astype(jnp.float32)        # (blk_n, S)
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (S, D)


def segment_stats(vals, seg, num_segments: int, *, blk_n: int = 512,
                  interpret: bool = False):
    """segment_sum(vals, seg) → (num_segments, D) fp32.

    vals: (N, D); seg: (N,) int32 in [0, num_segments) — rows with ids
    outside the range (used for padding) are dropped.
    """
    n, d = vals.shape
    assert seg.shape == (n,), (seg.shape, n)
    assert num_segments * d * 4 <= _ACC_BYTES_MAX, (
        f"accumulator (S={num_segments}, D={d}) exceeds the VMEM budget")
    if n == 0:
        # A zero-step grid would skip the init branch and return an
        # uninitialized buffer; match segment_sum's zeros.
        return jnp.zeros((num_segments, d), jnp.float32)
    blk_n = min(blk_n, max(n, 1))
    pad = (-n) % blk_n
    if pad:
        # Padding rows point at segment id S (matches nothing).
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad, d), vals.dtype)], axis=0)
        seg = jnp.concatenate(
            [seg, jnp.full((pad,), num_segments, jnp.int32)], axis=0)
    n_pad = n + pad

    kernel = functools.partial(_kernel, blk_n=blk_n, s=num_segments)
    return pl.pallas_call(
        kernel,
        grid=(n_pad // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk_n, d), lambda i: (i, 0)),
        ],
        # Every grid step maps the same output block: the accumulator
        # stays VMEM-resident across the (sequential) grid.
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=interpret,
    )(seg.astype(jnp.int32)[:, None], vals)
