"""Fault-tolerant checkpointing: atomic, manifest-based, mesh-independent.

Layout:   <dir>/step_000123/
            manifest.json        — step, tree structure, leaf shapes/dtypes
            arr_00000.npy ...    — one file per leaf (host numpy)
          <dir>/LATEST           — atomic pointer (rename-into-place)

Design points for 1000+ nodes:
  * Atomic commit: everything is written into a temp dir, fsync'd, then
    renamed; the LATEST pointer is updated last — a crash mid-save can never
    corrupt the restore path (power-failure-safe).
  * Mesh independence: arrays are saved as full host arrays (via
    ``jax.device_get`` which assembles sharded arrays), so a checkpoint
    written on mesh A restores onto mesh B of any shape — this is the
    elastic-rescale path (tested in tests/test_checkpoint.py).
  * Garbage collection: keep the newest ``keep`` checkpoints.
  * Integrity (DESIGN.md §13): the manifest records a CRC32 per leaf;
    :func:`verify_checkpoint` re-hashes the files, and both
    :func:`latest_step` and :func:`restore_checkpoint` (``step=None``)
    skip unverifiable entries — a corrupt or truncated newest checkpoint
    degrades to the newest *verifiable* one instead of a crash or, worse,
    a silent restore of bad bytes.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.resilience import faults

MANIFEST = "manifest.json"
LATEST = "LATEST"

# np.save writes ml_dtypes (bfloat16) arrays as raw void records ("|V2"):
# the bits survive but the dtype is lost and astype() on load explodes.
# Save such arrays as a same-width uint view with the true dtype recorded
# in the manifest; restore views them back — bit-stable round-trip for the
# bf16 head params / optimizer accumulators (DESIGN.md §11).
_VIEW_DTYPES = {"bfloat16": np.uint16}


def _to_savable(a: np.ndarray) -> np.ndarray:
    view = _VIEW_DTYPES.get(str(a.dtype))
    return a.view(view) if view is not None else a


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _tree_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 3, prefix: str = "step_",
                    update_latest: bool = True) -> str:
    """Atomically write `tree` as checkpoint `step`. Returns the path.

    A non-default ``prefix`` (with ``update_latest=False``) writes a side
    artifact that auto-resume and GC never look at — the generator-refresh
    snapshots (``gensnap_<step>``) use this so an in-flight fit can be
    replayed after a restart without perturbing the LATEST pointer.
    """
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _tree_paths(tree)
    host_leaves = jax.device_get(leaves)

    final = os.path.join(directory, f"{prefix}{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        meta = {"step": step, "treedef": str(treedef),
                "n_leaves": len(host_leaves),
                "leaves": [{"shape": list(np.shape(a)),
                            "dtype": str(np.asarray(a).dtype)}
                           for a in host_leaves]}
        for i, arr in enumerate(host_leaves):
            savable = _to_savable(np.asarray(arr))
            meta["leaves"][i]["crc32"] = int(
                zlib.crc32(np.ascontiguousarray(savable).tobytes()))
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), savable)
        # Site "checkpoint/write": arrays are on disk but the manifest —
        # the commit record — is not. A kill held here leaves an
        # unverifiable tmp dir that restore must ignore.
        faults.fire("checkpoint/write")
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        # Site "checkpoint/commit": the last instant at which a kill
        # loses this checkpoint entirely (tmp never renamed).
        faults.fire("checkpoint/commit")
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if update_latest:
        # Atomic LATEST pointer.
        ptr_tmp = os.path.join(directory, ".LATEST.tmp")
        with open(ptr_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.rename(ptr_tmp, os.path.join(directory, LATEST))
    if keep > 0:
        _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def verify_checkpoint(path: str) -> bool:
    """True iff ``path`` is a complete, uncorrupted checkpoint dir: the
    manifest parses, every leaf file exists with the recorded shape, and
    (for manifests that carry them — older ones don't) every CRC32
    matches. Never raises on damage; damage is the expected input."""
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            meta = json.load(f)
        leaves = meta["leaves"]
        if meta["n_leaves"] != len(leaves):
            return False
        for i, info in enumerate(leaves):
            arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
            if list(arr.shape) != list(info["shape"]):
                return False
            crc = info.get("crc32")
            if crc is not None and zlib.crc32(
                    np.ascontiguousarray(arr).tobytes()) != crc:
                return False
        return True
    except Exception:
        return False


def _step_dirs(directory: str, prefix: str = "step_") -> List[int]:
    """All checkpoint steps present on disk (complete or not), descending."""
    steps = []
    if os.path.isdir(directory):
        for name in os.listdir(directory):
            if name.startswith(prefix):
                try:
                    steps.append(int(name[len(prefix):]))
                except ValueError:
                    continue
    return sorted(steps, reverse=True)


def latest_step(directory: str, verify: bool = True) -> Optional[int]:
    """Newest restorable step. Prefers the LATEST pointer; if its target
    is missing or unverifiable (or the pointer itself is gone), falls
    back to the newest ``step_*`` dir that verifies — one bad artifact
    degrades the restore point, it doesn't erase the history."""
    candidates: List[int] = []
    ptr = os.path.join(directory, LATEST)
    if os.path.exists(ptr):
        with open(ptr) as f:
            name = f.read().strip()
        try:
            candidates.append(int(name.split("_")[1]))
        except (IndexError, ValueError):
            pass
    for s in _step_dirs(directory):
        if s not in candidates:
            candidates.append(s)
    for s in candidates:
        path = os.path.join(directory, f"step_{s:08d}")
        if not os.path.exists(os.path.join(path, MANIFEST)):
            continue
        if not verify or verify_checkpoint(path):
            return s
    return None


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None,
                       prefix: str = "step_") -> Tuple[Any, int]:
    """Restore into the structure of `tree_like`. If `shardings` (a pytree
    of jax.sharding.Sharding matching tree_like) is given, leaves are
    device_put with those shardings — this is how a checkpoint moves onto a
    *different* mesh (elastic restart).

    With ``step=None`` the restore point is the newest *verifiable*
    checkpoint (corrupt/truncated entries are skipped); an explicit
    ``step`` that fails verification raises rather than returning bad
    bytes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"{prefix}{step:08d}")
    if not verify_checkpoint(path):
        raise IOError(f"checkpoint {path} failed integrity verification")
    with open(os.path.join(path, MANIFEST)) as f:
        meta = json.load(f)
    leaves_like, treedef = jax.tree.flatten(tree_like)
    assert meta["n_leaves"] == len(leaves_like), (
        f"checkpoint has {meta['n_leaves']} leaves, expected "
        f"{len(leaves_like)}")
    arrays = [_from_saved(np.load(os.path.join(path, f"arr_{i:05d}.npy")),
                          info["dtype"])
              for i, info in enumerate(meta["leaves"])]
    for arr, like, info in zip(arrays, leaves_like, meta["leaves"]):
        assert tuple(arr.shape) == tuple(np.shape(like)), (
            arr.shape, np.shape(like))
    tree = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step
