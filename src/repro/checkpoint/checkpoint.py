"""Fault-tolerant checkpointing: atomic, manifest-based, mesh-independent.

Layout:   <dir>/step_000123/
            manifest.json        — step, tree structure, leaf shapes/dtypes
            arr_00000.npy ...    — one file per leaf (host numpy)
          <dir>/LATEST           — atomic pointer (rename-into-place)

Design points for 1000+ nodes:
  * Atomic commit: everything is written into a temp dir, fsync'd, then
    renamed; the LATEST pointer is updated last — a crash mid-save can never
    corrupt the restore path (power-failure-safe).
  * Mesh independence: arrays are saved as full host arrays (via
    ``jax.device_get`` which assembles sharded arrays), so a checkpoint
    written on mesh A restores onto mesh B of any shape — this is the
    elastic-rescale path (tested in tests/test_checkpoint.py).
  * Garbage collection: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"
LATEST = "LATEST"

# np.save writes ml_dtypes (bfloat16) arrays as raw void records ("|V2"):
# the bits survive but the dtype is lost and astype() on load explodes.
# Save such arrays as a same-width uint view with the true dtype recorded
# in the manifest; restore views them back — bit-stable round-trip for the
# bf16 head params / optimizer accumulators (DESIGN.md §11).
_VIEW_DTYPES = {"bfloat16": np.uint16}


def _to_savable(a: np.ndarray) -> np.ndarray:
    view = _VIEW_DTYPES.get(str(a.dtype))
    return a.view(view) if view is not None else a


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _tree_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 3, prefix: str = "step_",
                    update_latest: bool = True) -> str:
    """Atomically write `tree` as checkpoint `step`. Returns the path.

    A non-default ``prefix`` (with ``update_latest=False``) writes a side
    artifact that auto-resume and GC never look at — the generator-refresh
    snapshots (``gensnap_<step>``) use this so an in-flight fit can be
    replayed after a restart without perturbing the LATEST pointer.
    """
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _tree_paths(tree)
    host_leaves = jax.device_get(leaves)

    final = os.path.join(directory, f"{prefix}{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        meta = {"step": step, "treedef": str(treedef),
                "n_leaves": len(host_leaves),
                "leaves": [{"shape": list(np.shape(a)),
                            "dtype": str(np.asarray(a).dtype)}
                           for a in host_leaves]}
        for i, arr in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"),
                    _to_savable(np.asarray(arr)))
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if update_latest:
        # Atomic LATEST pointer.
        ptr_tmp = os.path.join(directory, ".LATEST.tmp")
        with open(ptr_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.rename(ptr_tmp, os.path.join(directory, LATEST))
    if keep > 0:
        _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, LATEST)
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.exists(os.path.join(path, MANIFEST)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None,
                       prefix: str = "step_") -> Tuple[Any, int]:
    """Restore into the structure of `tree_like`. If `shardings` (a pytree
    of jax.sharding.Sharding matching tree_like) is given, leaves are
    device_put with those shardings — this is how a checkpoint moves onto a
    *different* mesh (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"{prefix}{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        meta = json.load(f)
    leaves_like, treedef = jax.tree.flatten(tree_like)
    assert meta["n_leaves"] == len(leaves_like), (
        f"checkpoint has {meta['n_leaves']} leaves, expected "
        f"{len(leaves_like)}")
    arrays = [_from_saved(np.load(os.path.join(path, f"arr_{i:05d}.npy")),
                          info["dtype"])
              for i, info in enumerate(meta["leaves"])]
    for arr, like, info in zip(arrays, leaves_like, meta["leaves"]):
        assert tuple(arr.shape) == tuple(np.shape(like)), (
            arr.shape, np.shape(like))
    tree = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step
