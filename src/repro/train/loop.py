"""Fault-tolerant training loop.

Production behaviours, all exercised by tests:
  * periodic atomic checkpoints + auto-resume (bit-exact restart);
  * preemption handling: SIGTERM (or an injected callback) triggers an
    immediate checkpoint and a clean exit — the restart continues from the
    exact step (simulated preemption in tests/test_train_loop.py);
  * straggler monitor: per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are counted and logged — at fleet scale this
    signal feeds the controller that re-schedules slow hosts;
  * deterministic data: the loader is stateless in (seed, step), so restart
    only needs the step counter;
  * generator refresh: the adversarial tree is (re)fitted from a model
    snapshot every ``gen_refresh_steps`` (0 = fit once at
    ``gen_warmup_steps``). With ``gen_async`` the fit runs in a background
    thread (repro.genfit.refresh) while training continues on the stale
    generator, and the new generator is swapped in at the *recorded* step
    ``submit + gen_swap_delay`` — a pure function of the config, so
    checkpoint/resume replays the exact swap and stays bit-exact (the
    submit-time state is persisted as a ``gensnap`` artifact and the fit,
    being deterministic, is re-run on resume if it was in flight);
  * SNR-driven refresh (``gen_refresh_mode="snr"``): instead of a fixed
    period, a refresh is submitted when the online gradient-SNR proxy
    tracked in ``TrainState.snr_ewma`` degrades below ``snr_threshold`` x
    the post-install reference (genfit.refresh.refresh_on_snr,
    DESIGN.md §9). The trigger reads only checkpointed state, so resume
    replays the same trigger steps; the data-dependent submit step is
    recovered from the gensnap artifact on resume.
  * graceful degradation (DESIGN.md §13): non-finite steps are skipped
    (``nonfinite_policy="skip"`` + the in-graph ``skip_nonfinite`` guard)
    and, past ``max_consecutive_nonfinite``, answered with a rollback-
    restore from the newest verifiable checkpoint and a deterministic
    replay; a failed or hung generator fit (retries + watchdog in
    ``AsyncRefresher``) keeps the stale generator and re-arms the SNR
    trigger instead of killing the run.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.genfit.refresh import (AsyncRefresher, drop_snapshot,
                                  latest_snapshot_step, load_snapshot,
                                  refresh_on_snr, save_snapshot,
                                  snapshot_path_exists, swap_event)
from repro.obs import NULL_REGISTRY, JsonlExporter, ProfileWindow, Registry
from repro.obs.trace import span
from repro.optim import head_state_bytes
from repro.resilience import faults
from repro.train.state import TrainState, snr_reset_pair
from repro.train.step import publish_step_metrics


def _fit_with_retries(fit_fn, state, retries: int, backoff_s: float):
    """Blocking-fit twin of the AsyncRefresher worker's retry policy."""
    for attempt in range(retries + 1):
        try:
            faults.fire("genfit/fit")
            return fit_fn(state)
        except Exception:
            if attempt >= retries:
                raise
            time.sleep(backoff_s * (2 ** attempt))


def _fit_snapshot(state: TrainState) -> TrainState:
    """Deep-copy the leaves a background generator fit reads.

    With buffer donation on, the training step *invalidates* the state
    buffers it consumes — a background fit still reading them would race
    (or crash, on backends that actually unmap donated buffers). The old
    escape hatch disabled donation under --gen-async, which reintroduced
    the (C, K) scatter-copy every step (1.3 s/step at C=2M per
    BENCH_heads). Snapshot-then-donate inverts the cost: one copy of the
    fit's inputs per *refresh submit* (rare), donation stays on for every
    step. gen_fit_fn receives only (params, head_state, gen_fit_step)
    derived data, so only those leaves are copied.
    """
    return state._replace(
        params=jax.tree.map(jnp.copy, state.params),
        head_state=jax.tree.map(jnp.copy, state.head_state),
        gen_fit_step=jnp.copy(state.gen_fit_step))


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    gen_warmup_steps: int = 0       # fit generator after this many steps
    gen_refresh_steps: int = 0      # 0 = never refresh after warmup
    gen_async: bool = False         # fit in a background thread
    gen_swap_delay: int = 0         # steps between submit and swap (async)
    # "period": refresh every gen_refresh_steps (the fields above).
    # "snr": refresh when the online gradient-SNR proxy (TrainState.
    # snr_ewma, DESIGN.md §9) degrades below snr_threshold x the
    # post-install reference; gen_refresh_steps is ignored after warmup.
    gen_refresh_mode: str = "period"
    snr_threshold: float = 0.85     # trigger at ewma < threshold * ref
    snr_patience: int = 8           # min steps after install before trigger
    # -- observability (repro.obs, DESIGN.md §10) --
    metrics_jsonl: Optional[str] = None   # per-step JSONL event log path
    metrics_interval: int = 1       # emit a "step" event every N steps
    profile_dir: Optional[str] = None     # jax.profiler capture dir
    profile_steps: int = 5          # steady-state steps in the capture
    # -- resilience (DESIGN.md §13) --
    # "skip": a non-finite step is dropped (requires the jitted step's
    # skip_nonfinite guard for the state to survive it) and counted;
    # after max_consecutive_nonfinite skips in a row — or immediately,
    # when the step has no in-graph guard and the state is already
    # poisoned — the loop rolls back to the newest verifiable
    # checkpoint and replays. "raise" restores the legacy
    # FloatingPointError crash.
    nonfinite_policy: str = "skip"
    max_consecutive_nonfinite: int = 3
    max_rollbacks: int = 2          # rollback-restores before giving up
    gen_fit_retries: int = 2        # transient-failure retries per fit
    gen_fit_backoff_s: float = 0.05  # exponential backoff base
    gen_fit_timeout_s: Optional[float] = None  # hang watchdog (None = off)

    def gen_due(self, step: int) -> bool:
        return (step == self.gen_warmup_steps
                or bool(self.gen_refresh_steps
                        and step > self.gen_warmup_steps
                        and (step - self.gen_warmup_steps)
                        % self.gen_refresh_steps == 0))

    def last_submit_before(self, step: int) -> Optional[int]:
        """Latest refresh-submit step < ``step`` (None if none yet)."""
        if step <= self.gen_warmup_steps:
            return None
        if not self.gen_refresh_steps:
            return self.gen_warmup_steps
        k = (step - 1 - self.gen_warmup_steps) // self.gen_refresh_steps
        return self.gen_warmup_steps + k * self.gen_refresh_steps


class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps (straggler proxy)."""

    def __init__(self, factor: float, alpha: float):
        self.factor, self.alpha = factor, alpha
        self.ewma: Optional[float] = None
        self.flagged = 0
        self.history: List[float] = []

    def observe(self, dt: float) -> bool:
        self.history.append(dt)
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.flagged += 1
            # Do not fold outliers into the EWMA — keeps the baseline clean.
            return True
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        return False


class Preemption:
    """SIGTERM-or-callback preemption flag (GCE/Borg-style eviction)."""

    def __init__(self, install_signal: bool = False):
        self._flag = False
        if install_signal:
            signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, *_):
        self._flag = True

    def trigger(self):
        self._flag = True

    @property
    def requested(self) -> bool:
        return self._flag


def run_loop(state: TrainState, train_step: Callable, batch_fn: Callable,
             cfg: LoopConfig, rng: jax.Array,
             preemption: Optional[Preemption] = None,
             gen_fit_fn: Optional[Callable[[TrainState], Any]] = None,
             on_step: Optional[Callable[[int, Dict], None]] = None,
             registry: Optional[Registry] = None):
    """Run (or resume) training. Returns (state, history dict).

    ``batch_fn(step) -> batch`` must be deterministic in step.
    ``gen_fit_fn(state) -> LMHeadState`` refits the adversarial generator.

    Observability (repro.obs, DESIGN.md §10): pass a ``registry`` to
    collect the documented ``train/*`` / ``snr/*`` / ``genfit/*``
    metrics; with ``cfg.metrics_jsonl`` set an own registry is created
    and every lifecycle event plus a per-``metrics_interval`` step
    sample is appended to the JSONL log. With neither, the loop runs
    against the shared null registry — the zero-overhead default.
    ``history`` keeps its pre-obs keys (loss/step/step_times/gen_*)
    for compatibility; ``step_times`` holds steady-state steps only,
    the first executed step of the process (XLA compilation) lands in
    ``history["compile_time_s"]`` instead.
    """
    preemption = preemption or Preemption()
    monitor = StragglerMonitor(cfg.straggler_factor, cfg.ewma_alpha)
    if registry is None:
        registry = (Registry() if (cfg.metrics_jsonl or cfg.profile_dir)
                    else NULL_REGISTRY)
    if cfg.profile_dir:
        registry.annotate = True    # host spans show up on the trace
    exporter = (JsonlExporter(cfg.metrics_jsonl) if cfg.metrics_jsonl
                else None)
    emit = exporter.emit if exporter is not None else (lambda ev: None)
    profiler = ProfileWindow(cfg.profile_dir, cfg.profile_steps)
    # history is the compatibility view (keys appear only when the
    # corresponding event happened, as before); the registry is the
    # primary record.
    history: Dict[str, Any] = {"loss": [], "step": [], "step_times": []}
    if cfg.gen_refresh_mode not in ("period", "snr"):
        raise ValueError(f"unknown gen_refresh_mode "
                         f"{cfg.gen_refresh_mode!r} (period|snr)")
    snr_mode = cfg.gen_refresh_mode == "snr"
    if snr_mode and cfg.gen_async and cfg.gen_swap_delay > 0:
        if cfg.snr_patience <= cfg.gen_swap_delay:
            raise ValueError(
                "snr_patience must exceed gen_swap_delay: the trigger "
                "must stay quiet until the in-flight fit has been "
                "installed and its reference armed")
    if not snr_mode and cfg.gen_async and cfg.gen_refresh_steps:
        if cfg.gen_swap_delay >= cfg.gen_refresh_steps:
            raise ValueError(
                "gen_swap_delay must be < gen_refresh_steps (one refresh "
                "in flight at a time)")

    # ---- auto-resume ----------------------------------------------------
    start_step = int(state.step)
    if cfg.checkpoint_dir:
        ck = latest_step(cfg.checkpoint_dir)
        if ck is not None and ck > start_step:
            state, _ = restore_checkpoint(cfg.checkpoint_dir,
                                          state.as_pytree(), step=ck)
            state = TrainState(**state)
            start_step = int(jax.device_get(state.step))

    # ---- re-establish an async refresh that was in flight ---------------
    use_async = (gen_fit_fn is not None and cfg.gen_async
                 and cfg.gen_swap_delay > 0)

    def establish_refresh(state: TrainState, start_step: int):
        """(Re)build the refresher + pending swap for a run (re)starting
        at ``start_step``. Called at startup and again after a
        rollback-restore — a rollback is a resume that never left the
        process, so it replays the same in-flight-fit recovery."""
        if not use_async:
            return None, None
        refresher = AsyncRefresher(
            gen_fit_fn, retries=cfg.gen_fit_retries,
            backoff_s=cfg.gen_fit_backoff_s,
            timeout_s=cfg.gen_fit_timeout_s)
        if snr_mode:
            # SNR-triggered submits are data-dependent, so the submit step
            # cannot be recomputed from the config — recover it from the
            # gensnap artifact the submit persisted. In flight iff the
            # snapshot postdates the installed generator and the resume
            # lands inside its (submit, swap] window.
            s_sub = (latest_snapshot_step(cfg.checkpoint_dir)
                     if cfg.checkpoint_dir else None)
            if s_sub is not None:
                fit_host = int(jax.device_get(state.gen_fit_step))
                if not (s_sub > fit_host and s_sub < start_step):
                    s_sub = None
        else:
            s_sub = cfg.last_submit_before(start_step)
        if (s_sub is not None
                and start_step <= s_sub + cfg.gen_swap_delay
                and s_sub + cfg.gen_swap_delay < cfg.total_steps):
            # Resumed inside a (submit, swap] window: replay the fit from
            # the persisted submit-time snapshot. The fit is deterministic
            # in (state, config), so the swap installs bit-identical
            # parameters at the recorded step.
            if (cfg.checkpoint_dir
                    and snapshot_path_exists(cfg.checkpoint_dir, s_sub)):
                snap = load_snapshot(cfg.checkpoint_dir, s_sub,
                                     state.as_pytree())
                snap_state = TrainState(**snap)   # disk copy: not aliased
            else:
                snap_state = _fit_snapshot(state)
            refresher.submit(snap_state, s_sub)
            registry.counter("genfit/submits").inc()
            emit({"event": "gen_submit", "step": s_sub, "resumed": True})
            return refresher, s_sub + cfg.gen_swap_delay
        return refresher, None

    refresher, pending_swap = establish_refresh(state, start_step)

    # Head param + optimizer-state footprint (DESIGN.md §11): a static
    # function of shapes/dtypes, computed once and republished as a gauge
    # with every step sample.
    try:
        hs_bytes: Optional[int] = head_state_bytes(state.params,
                                                   state.opt_state)
    except Exception:
        hs_bytes = None     # exotic state trees: skip the gauge, not the run

    # Consumed gensnap artifacts are dropped only once a *durable*
    # checkpoint from beyond their swap step exists: a resume always loads
    # the latest checkpoint, and any checkpoint labeled <= swap_step
    # re-enters the replay window and needs the snapshot (a hard kill
    # right after the swap must not lose the replay source).
    snaps_to_drop: List[tuple] = []

    def maybe_checkpoint(step, force=False):
        if not cfg.checkpoint_dir:
            return
        if force or (cfg.checkpoint_every
                     and step % cfg.checkpoint_every == 0 and step > 0):
            save_checkpoint(cfg.checkpoint_dir, step, state.as_pytree(),
                            keep=cfg.keep_checkpoints)
            for s_sub, s_swap in list(snaps_to_drop):
                if step > s_swap:
                    drop_snapshot(cfg.checkpoint_dir, s_sub)
                    snaps_to_drop.remove((s_sub, s_swap))

    # Resilience counters (DESIGN.md §13): consecutive non-finite steps
    # and rollback-restores consumed so far.
    nonfinite_streak = 0
    rollbacks = 0
    first_executed = True

    step = start_step
    while step < cfg.total_steps:
        # -- generator warmup / refresh (the paper's Step 1) --
        if gen_fit_fn is not None:
            if pending_swap is not None and step == pending_swap:
                # Recorded swap point: install the background fit (blocks
                # only if the fit is still running — by construction the
                # step is config-determined, never timing-determined).
                old_fit = int(jax.device_get(state.gen_fit_step))
                s_sub_val = refresher.submit_step
                try:
                    head, s_sub = refresher.result()
                except Exception as e:
                    # Degradation ladder: the fit failed (retries
                    # exhausted) or hung (watchdog). Keep serving the
                    # stale generator; clearing pending_swap drops the
                    # busy latch, so the SNR trigger — whose EWMA is
                    # still degraded against the OLD install's reference
                    # — re-arms and fires a fresh submit on a later
                    # step instead of the run dying at the swap.
                    registry.counter("genfit/refresh_failed").inc()
                    history.setdefault("gen_refresh_failed_steps",
                                       []).append(step)
                    emit({"event": "gen_refresh_failed", "step": step,
                          "submit_step": s_sub_val, "reason": repr(e)})
                    if cfg.checkpoint_dir:
                        snaps_to_drop.append((s_sub_val, step))
                    pending_swap = None
                else:
                    # Fresh generator: restart the SNR proxy EWMA and
                    # disarm the reference (re-armed snr_patience steps
                    # after the install).
                    ewma0, ref0 = snr_reset_pair()
                    state = state._replace(
                        head_state=head,
                        gen_fit_step=jnp.asarray(s_sub, jnp.int32),
                        snr_ewma=ewma0, snr_ref=ref0)
                    pending_swap = None
                    history.setdefault("gen_swap_steps", []).append(step)
                    emit(swap_event(step, old_fit, s_sub,
                                    refresher.last_fit_seconds, registry))
                    if cfg.checkpoint_dir:
                        snaps_to_drop.append((s_sub, step))
            if snr_mode:
                # Warmup fit is scheduled; every later refresh is
                # triggered by the online SNR proxy degrading (the state
                # it reads is checkpointed, so resume replays the same
                # trigger steps).
                due = step == cfg.gen_warmup_steps
                if not due:
                    fit_host = int(jax.device_get(state.gen_fit_step))
                    install_est = (fit_host + cfg.gen_swap_delay
                                   if use_async and fit_host >= 0
                                   else fit_host)
                    fired = refresh_on_snr(
                        step, install_est,
                        float(jax.device_get(state.snr_ewma)),
                        float(jax.device_get(state.snr_ref)),
                        cfg.snr_threshold, cfg.snr_patience)
                    busy = (pending_swap is not None
                            or (refresher is not None
                                and refresher.in_flight))
                    if fired and busy:
                        # One refresh in flight at a time: the trigger
                        # fired but submission declines. Counted per
                        # declined step — a growing counter here means
                        # the EWMA stayed degraded through a whole
                        # submit→swap window (tune gen_swap_delay).
                        registry.counter("genfit/refresh_skipped").inc()
                    elif fired:
                        due = True
                        history.setdefault("snr_trigger_steps", []).append(step)
                        emit({"event": "snr_trigger", "step": step})
            else:
                due = cfg.gen_due(step)
            if due:
                # An async fit whose swap step cannot land inside the run
                # would never be installed — fit blocking instead (still a
                # pure function of the config, so resume stays exact).
                if use_async and step + cfg.gen_swap_delay < cfg.total_steps:
                    if refresher.in_flight:
                        raise RuntimeError(
                            f"generator refresh submitted at step {step} "
                            f"while one is in flight")
                    if cfg.checkpoint_dir:
                        save_snapshot(cfg.checkpoint_dir, step,
                                      state.as_pytree())
                    refresher.submit(_fit_snapshot(state), step)
                    pending_swap = step + cfg.gen_swap_delay
                    history.setdefault("gen_submit_steps", []).append(step)
                    registry.counter("genfit/submits").inc()
                    emit({"event": "gen_submit", "step": step})
                else:
                    old_fit = int(jax.device_get(state.gen_fit_step))
                    t_fit = time.perf_counter()
                    try:
                        new_head = _fit_with_retries(
                            gen_fit_fn, state, cfg.gen_fit_retries,
                            cfg.gen_fit_backoff_s)
                    except Exception as e:
                        # Same ladder as the async swap: keep the stale
                        # generator, record the failure, train on.
                        registry.counter("genfit/refresh_failed").inc()
                        history.setdefault("gen_refresh_failed_steps",
                                           []).append(step)
                        emit({"event": "gen_refresh_failed", "step": step,
                              "submit_step": step, "reason": repr(e)})
                    else:
                        fit_s = time.perf_counter() - t_fit
                        ewma0, ref0 = snr_reset_pair()
                        state = state._replace(
                            head_state=new_head,
                            gen_fit_step=jnp.asarray(step, jnp.int32),
                            snr_ewma=ewma0, snr_ref=ref0)
                        history.setdefault("gen_swap_steps",
                                           []).append(step)
                        registry.counter("genfit/submits").inc()
                        emit(swap_event(step, old_fit, step, fit_s,
                                        registry))

        # The first executed step of THIS process pays XLA compilation —
        # a different quantity from the steady-state step time, recorded
        # as compile_time_s and excluded from step_times, the straggler
        # EWMA, and the train/step_time_s histogram (benchmarks no
        # longer hand-trim step 0). Profiling likewise starts only once
        # compilation is out of the way.
        is_compile = first_executed
        first_executed = False
        if not is_compile:
            profiler.tick(step)
        t0 = time.perf_counter()
        with span("train/phase/data", registry):
            batch = batch_fn(step)
        # Site "train/batch": a corrupt action NaN-poisons the batch so
        # the non-finite path is exercised end to end *inside* the jitted
        # step, not via a synthetic host-side flag.
        batch = faults.inject("train/batch", batch)
        # Step-indexed rng (not sequential splitting): restart from a
        # checkpoint replays the exact rng stream — bit-exact recovery.
        sub = jax.random.fold_in(rng, step)
        with span("train/phase/step", registry):
            state, metrics = train_step(state, batch, sub)
            jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0

        loss = float(jax.device_get(metrics["loss"]))
        # "nonfinite" is the in-graph skip guard's report (train/step.py
        # skip_nonfinite): when present and set, the step already
        # selected its pre-step state and the host sees a clean skip.
        # A non-finite loss WITHOUT the guard means the optimizer
        # applied poisoned gradients — only rollback can recover.
        guarded = "nonfinite" in metrics
        skipped = guarded and float(
            jax.device_get(metrics["nonfinite"])) > 0
        bad = skipped or not np.isfinite(loss)
        if bad and cfg.nonfinite_policy != "skip":
            raise FloatingPointError(f"non-finite loss at step {step}")
        slow = False
        if is_compile:
            history["compile_time_s"] = dt
            registry.gauge("train/compile_time_s").set(dt)
            emit({"event": "compile", "step": step, "compile_time_s": dt})
        else:
            slow = monitor.observe(dt)
            history["step_times"].append(dt)
            registry.histogram("train/step_time_s").observe(dt)
            if slow:
                registry.counter("train/stragglers").inc()
        history["loss"].append(loss)
        history["step"].append(step)

        sample_due = (exporter is not None and not is_compile
                      and step % max(cfg.metrics_interval, 1) == 0)
        if on_step is not None or registry.enabled or sample_due:
            # One host transfer for the whole (tiny, already-computed)
            # metrics dict, shared by the callback, the gauges, and the
            # JSONL sample.
            host_m = {k: float(v)
                      for k, v in jax.device_get(metrics).items()}
            snr_ref = (float(jax.device_get(state.snr_ref))
                       if "snr_ewma" in host_m else None)
            publish_step_metrics(registry, host_m, snr_ref=snr_ref,
                                 head_state_bytes=hs_bytes)
            if sample_due:
                ev = {"event": "step", "step": step, "loss": loss,
                      "step_time_s": dt, "straggler": slow}
                for k in ("snr_proxy", "snr_ewma", "grad_norm"):
                    if k in host_m:
                        ev[k] = host_m[k]
                if snr_ref is not None:
                    ev["snr_ref"] = snr_ref
                emit(ev)
            if on_step is not None:
                on_step(step, {**host_m, "step_time": dt,
                               "straggler": slow})

        if bad:
            nonfinite_streak += 1
            registry.counter("train/nonfinite_skipped").inc()
            history.setdefault("nonfinite_steps", []).append(step)
            emit({"event": "nonfinite_skip", "step": step,
                  "streak": nonfinite_streak})
            if not guarded or nonfinite_streak >= cfg.max_consecutive_nonfinite:
                # Rollback-restore: rewind to the newest verifiable
                # checkpoint and replay. The data/rng streams are
                # step-indexed, so the replay is deterministic; a
                # persistent cause re-fires and the rollback budget
                # converts it into the legacy crash.
                rollbacks += 1
                ck = (latest_step(cfg.checkpoint_dir)
                      if cfg.checkpoint_dir else None)
                if ck is None or rollbacks > cfg.max_rollbacks:
                    raise FloatingPointError(
                        f"non-finite loss at step {step} ("
                        + ("no verifiable checkpoint to roll back to"
                           if ck is None else
                           f"rollback budget {cfg.max_rollbacks} "
                           f"exhausted") + ")")
                restored, _ = restore_checkpoint(
                    cfg.checkpoint_dir, state.as_pytree(), step=ck)
                state = TrainState(**restored)
                registry.counter("train/rollbacks").inc()
                history.setdefault("rollback_steps", []).append([step, ck])
                emit({"event": "rollback_restore", "step": step,
                      "restored_step": ck})
                nonfinite_streak = 0
                refresher, pending_swap = establish_refresh(state, ck)
                step = ck
                continue
            # Clean skip (in-graph guard kept the state): fall through —
            # checkpointing and preemption still see a valid state.
        else:
            nonfinite_streak = 0

        if snr_mode and gen_fit_fn is not None:
            # Arm the reference snr_patience steps after the install:
            # freeze the EWMA as the "healthy" level the trigger compares
            # against. A running max would false-trigger on a fresh
            # generator — the proxy naturally decays from its 1/2 optimum
            # as the discriminator sharpens — so the reference is a fixed
            # early-window snapshot instead. Runs before maybe_checkpoint
            # so the armed value is durable and resume replays it.
            fit_host = int(jax.device_get(state.gen_fit_step))
            if fit_host >= 0:
                install_est = (fit_host + cfg.gen_swap_delay
                               if use_async else fit_host)
                if (float(jax.device_get(state.snr_ref)) < 0
                        and float(jax.device_get(state.snr_ewma)) >= 0
                        and step - install_est >= cfg.snr_patience):
                    # jnp.copy, not the array itself: snr_ref aliasing
                    # snr_ewma's buffer breaks donated train steps
                    # ("attempt to donate the same buffer twice").
                    state = state._replace(
                        snr_ref=jnp.copy(state.snr_ewma))

        maybe_checkpoint(step + 1)
        if preemption.requested:
            maybe_checkpoint(step + 1, force=True)
            history["preempted_at"] = step + 1
            break
        step += 1

    history["stragglers"] = monitor.flagged
    profiler.stop()
    if registry.enabled:
        history["metrics"] = registry.snapshot()
    if exporter is not None:
        exporter.emit({"event": "summary", "metrics": registry.snapshot()})
        exporter.close()
    return state, history
