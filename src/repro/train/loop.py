"""Fault-tolerant training loop.

Production behaviours, all exercised by tests:
  * periodic atomic checkpoints + auto-resume (bit-exact restart);
  * preemption handling: SIGTERM (or an injected callback) triggers an
    immediate checkpoint and a clean exit — the restart continues from the
    exact step (simulated preemption in tests/test_train_loop.py);
  * straggler monitor: per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are counted and logged — at fleet scale this
    signal feeds the controller that re-schedules slow hosts;
  * deterministic data: the loader is stateless in (seed, step), so restart
    only needs the step counter;
  * generator refresh: the adversarial tree is (re)fitted from a model
    snapshot every ``gen_refresh_steps`` (0 = fit once at
    ``gen_warmup_steps``).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.state import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    gen_warmup_steps: int = 0       # fit generator after this many steps
    gen_refresh_steps: int = 0      # 0 = never refresh after warmup


class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps (straggler proxy)."""

    def __init__(self, factor: float, alpha: float):
        self.factor, self.alpha = factor, alpha
        self.ewma: Optional[float] = None
        self.flagged = 0
        self.history: List[float] = []

    def observe(self, dt: float) -> bool:
        self.history.append(dt)
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.flagged += 1
            # Do not fold outliers into the EWMA — keeps the baseline clean.
            return True
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        return False


class Preemption:
    """SIGTERM-or-callback preemption flag (GCE/Borg-style eviction)."""

    def __init__(self, install_signal: bool = False):
        self._flag = False
        if install_signal:
            signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, *_):
        self._flag = True

    def trigger(self):
        self._flag = True

    @property
    def requested(self) -> bool:
        return self._flag


def run_loop(state: TrainState, train_step: Callable, batch_fn: Callable,
             cfg: LoopConfig, rng: jax.Array,
             preemption: Optional[Preemption] = None,
             gen_fit_fn: Optional[Callable[[TrainState], Any]] = None,
             on_step: Optional[Callable[[int, Dict], None]] = None):
    """Run (or resume) training. Returns (state, history dict).

    ``batch_fn(step) -> batch`` must be deterministic in step.
    ``gen_fit_fn(state) -> LMHeadState`` refits the adversarial generator.
    """
    preemption = preemption or Preemption()
    monitor = StragglerMonitor(cfg.straggler_factor, cfg.ewma_alpha)
    history: Dict[str, list] = {"loss": [], "step": []}

    # ---- auto-resume ----------------------------------------------------
    start_step = int(state.step)
    if cfg.checkpoint_dir:
        ck = latest_step(cfg.checkpoint_dir)
        if ck is not None and ck > start_step:
            state, _ = restore_checkpoint(cfg.checkpoint_dir,
                                          state.as_pytree(), step=ck)
            state = TrainState(**state)
            start_step = int(jax.device_get(state.step))

    def maybe_checkpoint(step, force=False):
        if not cfg.checkpoint_dir:
            return
        if force or (cfg.checkpoint_every
                     and step % cfg.checkpoint_every == 0 and step > 0):
            save_checkpoint(cfg.checkpoint_dir, step, state.as_pytree(),
                            keep=cfg.keep_checkpoints)

    for step in range(start_step, cfg.total_steps):
        # -- generator warmup / refresh (the paper's Step 1) --
        if gen_fit_fn is not None:
            due = (step == cfg.gen_warmup_steps
                   or (cfg.gen_refresh_steps
                       and step > cfg.gen_warmup_steps
                       and (step - cfg.gen_warmup_steps)
                       % cfg.gen_refresh_steps == 0))
            if due:
                state = state._replace(head_state=gen_fit_fn(state))

        t0 = time.perf_counter()
        batch = batch_fn(step)
        # Step-indexed rng (not sequential splitting): restart from a
        # checkpoint replays the exact rng stream — bit-exact recovery.
        sub = jax.random.fold_in(rng, step)
        state, metrics = train_step(state, batch, sub)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = monitor.observe(dt)

        loss = float(jax.device_get(metrics["loss"]))
        if not np.isfinite(loss):
            raise FloatingPointError(f"non-finite loss at step {step}")
        history["loss"].append(loss)
        history["step"].append(step)
        if on_step is not None:
            on_step(step, {**{k: float(jax.device_get(v))
                              for k, v in metrics.items()},
                           "step_time": dt, "straggler": slow})

        maybe_checkpoint(step + 1)
        if preemption.requested:
            maybe_checkpoint(step + 1, force=True)
            history["preempted_at"] = step + 1
            break

    history["stragglers"] = monitor.flagged
    return state, history
