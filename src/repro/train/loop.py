"""Fault-tolerant training loop.

Production behaviours, all exercised by tests:
  * periodic atomic checkpoints + auto-resume (bit-exact restart);
  * preemption handling: SIGTERM (or an injected callback) triggers an
    immediate checkpoint and a clean exit — the restart continues from the
    exact step (simulated preemption in tests/test_train_loop.py);
  * straggler monitor: per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are counted and logged — at fleet scale this
    signal feeds the controller that re-schedules slow hosts;
  * deterministic data: the loader is stateless in (seed, step), so restart
    only needs the step counter;
  * generator refresh: the adversarial tree is (re)fitted from a model
    snapshot every ``gen_refresh_steps`` (0 = fit once at
    ``gen_warmup_steps``). With ``gen_async`` the fit runs in a background
    thread (repro.genfit.refresh) while training continues on the stale
    generator, and the new generator is swapped in at the *recorded* step
    ``submit + gen_swap_delay`` — a pure function of the config, so
    checkpoint/resume replays the exact swap and stays bit-exact (the
    submit-time state is persisted as a ``gensnap`` artifact and the fit,
    being deterministic, is re-run on resume if it was in flight);
  * SNR-driven refresh (``gen_refresh_mode="snr"``): instead of a fixed
    period, a refresh is submitted when the online gradient-SNR proxy
    tracked in ``TrainState.snr_ewma`` degrades below ``snr_threshold`` x
    the post-install reference (genfit.refresh.refresh_on_snr,
    DESIGN.md §9). The trigger reads only checkpointed state, so resume
    replays the same trigger steps; the data-dependent submit step is
    recovered from the gensnap artifact on resume.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.genfit.refresh import (AsyncRefresher, drop_snapshot,
                                  latest_snapshot_step, load_snapshot,
                                  refresh_on_snr, save_snapshot,
                                  snapshot_path_exists)
from repro.train.state import TrainState, snr_reset_pair


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    gen_warmup_steps: int = 0       # fit generator after this many steps
    gen_refresh_steps: int = 0      # 0 = never refresh after warmup
    gen_async: bool = False         # fit in a background thread
    gen_swap_delay: int = 0         # steps between submit and swap (async)
    # "period": refresh every gen_refresh_steps (the fields above).
    # "snr": refresh when the online gradient-SNR proxy (TrainState.
    # snr_ewma, DESIGN.md §9) degrades below snr_threshold x the
    # post-install reference; gen_refresh_steps is ignored after warmup.
    gen_refresh_mode: str = "period"
    snr_threshold: float = 0.85     # trigger at ewma < threshold * ref
    snr_patience: int = 8           # min steps after install before trigger

    def gen_due(self, step: int) -> bool:
        return (step == self.gen_warmup_steps
                or bool(self.gen_refresh_steps
                        and step > self.gen_warmup_steps
                        and (step - self.gen_warmup_steps)
                        % self.gen_refresh_steps == 0))

    def last_submit_before(self, step: int) -> Optional[int]:
        """Latest refresh-submit step < ``step`` (None if none yet)."""
        if step <= self.gen_warmup_steps:
            return None
        if not self.gen_refresh_steps:
            return self.gen_warmup_steps
        k = (step - 1 - self.gen_warmup_steps) // self.gen_refresh_steps
        return self.gen_warmup_steps + k * self.gen_refresh_steps


class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps (straggler proxy)."""

    def __init__(self, factor: float, alpha: float):
        self.factor, self.alpha = factor, alpha
        self.ewma: Optional[float] = None
        self.flagged = 0
        self.history: List[float] = []

    def observe(self, dt: float) -> bool:
        self.history.append(dt)
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.flagged += 1
            # Do not fold outliers into the EWMA — keeps the baseline clean.
            return True
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        return False


class Preemption:
    """SIGTERM-or-callback preemption flag (GCE/Borg-style eviction)."""

    def __init__(self, install_signal: bool = False):
        self._flag = False
        if install_signal:
            signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, *_):
        self._flag = True

    def trigger(self):
        self._flag = True

    @property
    def requested(self) -> bool:
        return self._flag


def run_loop(state: TrainState, train_step: Callable, batch_fn: Callable,
             cfg: LoopConfig, rng: jax.Array,
             preemption: Optional[Preemption] = None,
             gen_fit_fn: Optional[Callable[[TrainState], Any]] = None,
             on_step: Optional[Callable[[int, Dict], None]] = None):
    """Run (or resume) training. Returns (state, history dict).

    ``batch_fn(step) -> batch`` must be deterministic in step.
    ``gen_fit_fn(state) -> LMHeadState`` refits the adversarial generator.
    """
    preemption = preemption or Preemption()
    monitor = StragglerMonitor(cfg.straggler_factor, cfg.ewma_alpha)
    history: Dict[str, list] = {"loss": [], "step": []}
    if cfg.gen_refresh_mode not in ("period", "snr"):
        raise ValueError(f"unknown gen_refresh_mode "
                         f"{cfg.gen_refresh_mode!r} (period|snr)")
    snr_mode = cfg.gen_refresh_mode == "snr"
    if snr_mode and cfg.gen_async and cfg.gen_swap_delay > 0:
        if cfg.snr_patience <= cfg.gen_swap_delay:
            raise ValueError(
                "snr_patience must exceed gen_swap_delay: the trigger "
                "must stay quiet until the in-flight fit has been "
                "installed and its reference armed")
    if not snr_mode and cfg.gen_async and cfg.gen_refresh_steps:
        if cfg.gen_swap_delay >= cfg.gen_refresh_steps:
            raise ValueError(
                "gen_swap_delay must be < gen_refresh_steps (one refresh "
                "in flight at a time)")

    # ---- auto-resume ----------------------------------------------------
    start_step = int(state.step)
    if cfg.checkpoint_dir:
        ck = latest_step(cfg.checkpoint_dir)
        if ck is not None and ck > start_step:
            state, _ = restore_checkpoint(cfg.checkpoint_dir,
                                          state.as_pytree(), step=ck)
            state = TrainState(**state)
            start_step = int(jax.device_get(state.step))

    # ---- re-establish an async refresh that was in flight ---------------
    refresher: Optional[AsyncRefresher] = None
    pending_swap: Optional[int] = None
    use_async = (gen_fit_fn is not None and cfg.gen_async
                 and cfg.gen_swap_delay > 0)
    if use_async:
        refresher = AsyncRefresher(gen_fit_fn)
        if snr_mode:
            # SNR-triggered submits are data-dependent, so the submit step
            # cannot be recomputed from the config — recover it from the
            # gensnap artifact the submit persisted. In flight iff the
            # snapshot postdates the installed generator and the resume
            # lands inside its (submit, swap] window.
            s_sub = (latest_snapshot_step(cfg.checkpoint_dir)
                     if cfg.checkpoint_dir else None)
            if s_sub is not None:
                fit_host = int(jax.device_get(state.gen_fit_step))
                if not (s_sub > fit_host and s_sub < start_step):
                    s_sub = None
        else:
            s_sub = cfg.last_submit_before(start_step)
        if (s_sub is not None
                and start_step <= s_sub + cfg.gen_swap_delay
                and s_sub + cfg.gen_swap_delay < cfg.total_steps):
            # Resumed inside a (submit, swap] window: replay the fit from
            # the persisted submit-time snapshot. The fit is deterministic
            # in (state, config), so the swap installs bit-identical
            # parameters at the recorded step.
            snap_state = state
            if (cfg.checkpoint_dir
                    and snapshot_path_exists(cfg.checkpoint_dir, s_sub)):
                snap = load_snapshot(cfg.checkpoint_dir, s_sub,
                                     state.as_pytree())
                snap_state = TrainState(**snap)
            refresher.submit(snap_state, s_sub)
            pending_swap = s_sub + cfg.gen_swap_delay

    # Consumed gensnap artifacts are dropped only once a *durable*
    # checkpoint from beyond their swap step exists: a resume always loads
    # the latest checkpoint, and any checkpoint labeled <= swap_step
    # re-enters the replay window and needs the snapshot (a hard kill
    # right after the swap must not lose the replay source).
    snaps_to_drop: List[tuple] = []

    def maybe_checkpoint(step, force=False):
        if not cfg.checkpoint_dir:
            return
        if force or (cfg.checkpoint_every
                     and step % cfg.checkpoint_every == 0 and step > 0):
            save_checkpoint(cfg.checkpoint_dir, step, state.as_pytree(),
                            keep=cfg.keep_checkpoints)
            for s_sub, s_swap in list(snaps_to_drop):
                if step > s_swap:
                    drop_snapshot(cfg.checkpoint_dir, s_sub)
                    snaps_to_drop.remove((s_sub, s_swap))

    for step in range(start_step, cfg.total_steps):
        # -- generator warmup / refresh (the paper's Step 1) --
        if gen_fit_fn is not None:
            if pending_swap is not None and step == pending_swap:
                # Recorded swap point: install the background fit (blocks
                # only if the fit is still running — by construction the
                # step is config-determined, never timing-determined).
                head, s_sub = refresher.result()
                # Fresh generator: restart the SNR proxy EWMA and disarm
                # the reference (re-armed snr_patience steps after the
                # install).
                ewma0, ref0 = snr_reset_pair()
                state = state._replace(
                    head_state=head,
                    gen_fit_step=jnp.asarray(s_sub, jnp.int32),
                    snr_ewma=ewma0, snr_ref=ref0)
                pending_swap = None
                history.setdefault("gen_swap_steps", []).append(step)
                if cfg.checkpoint_dir:
                    snaps_to_drop.append((s_sub, step))
            if snr_mode:
                # Warmup fit is scheduled; every later refresh is
                # triggered by the online SNR proxy degrading (the state
                # it reads is checkpointed, so resume replays the same
                # trigger steps).
                due = step == cfg.gen_warmup_steps
                if (not due and pending_swap is None
                        and not (refresher is not None
                                 and refresher.in_flight)):
                    fit_host = int(jax.device_get(state.gen_fit_step))
                    install_est = (fit_host + cfg.gen_swap_delay
                                   if use_async and fit_host >= 0
                                   else fit_host)
                    due = refresh_on_snr(
                        step, install_est,
                        float(jax.device_get(state.snr_ewma)),
                        float(jax.device_get(state.snr_ref)),
                        cfg.snr_threshold, cfg.snr_patience)
                    if due:
                        history.setdefault("snr_trigger_steps",
                                           []).append(step)
            else:
                due = cfg.gen_due(step)
            if due:
                # An async fit whose swap step cannot land inside the run
                # would never be installed — fit blocking instead (still a
                # pure function of the config, so resume stays exact).
                if use_async and step + cfg.gen_swap_delay < cfg.total_steps:
                    if refresher.in_flight:
                        raise RuntimeError(
                            f"generator refresh submitted at step {step} "
                            f"while one is in flight")
                    if cfg.checkpoint_dir:
                        save_snapshot(cfg.checkpoint_dir, step,
                                      state.as_pytree())
                    refresher.submit(state, step)
                    pending_swap = step + cfg.gen_swap_delay
                    history.setdefault("gen_submit_steps", []).append(step)
                else:
                    ewma0, ref0 = snr_reset_pair()
                    state = state._replace(
                        head_state=gen_fit_fn(state),
                        gen_fit_step=jnp.asarray(step, jnp.int32),
                        snr_ewma=ewma0, snr_ref=ref0)
                    history.setdefault("gen_swap_steps", []).append(step)

        t0 = time.perf_counter()
        batch = batch_fn(step)
        # Step-indexed rng (not sequential splitting): restart from a
        # checkpoint replays the exact rng stream — bit-exact recovery.
        sub = jax.random.fold_in(rng, step)
        state, metrics = train_step(state, batch, sub)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = monitor.observe(dt)

        loss = float(jax.device_get(metrics["loss"]))
        if not np.isfinite(loss):
            raise FloatingPointError(f"non-finite loss at step {step}")
        history["loss"].append(loss)
        history["step"].append(step)
        history.setdefault("step_times", []).append(dt)
        if on_step is not None:
            on_step(step, {**{k: float(jax.device_get(v))
                              for k, v in metrics.items()},
                           "step_time": dt, "straggler": slow})

        if snr_mode and gen_fit_fn is not None:
            # Arm the reference snr_patience steps after the install:
            # freeze the EWMA as the "healthy" level the trigger compares
            # against. A running max would false-trigger on a fresh
            # generator — the proxy naturally decays from its 1/2 optimum
            # as the discriminator sharpens — so the reference is a fixed
            # early-window snapshot instead. Runs before maybe_checkpoint
            # so the armed value is durable and resume replays it.
            fit_host = int(jax.device_get(state.gen_fit_step))
            if fit_host >= 0:
                install_est = (fit_host + cfg.gen_swap_delay
                               if use_async else fit_host)
                if (float(jax.device_get(state.snr_ref)) < 0
                        and float(jax.device_get(state.snr_ewma)) >= 0
                        and step - install_est >= cfg.snr_patience):
                    # jnp.copy, not the array itself: snr_ref aliasing
                    # snr_ewma's buffer breaks donated train steps
                    # ("attempt to donate the same buffer twice").
                    state = state._replace(
                        snr_ref=jnp.copy(state.snr_ewma))

        maybe_checkpoint(step + 1)
        if preemption.requested:
            maybe_checkpoint(step + 1, force=True)
            history["preempted_at"] = step + 1
            break

    history["stragglers"] = monitor.flagged
    return state, history
