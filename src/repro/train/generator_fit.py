"""Fitting the adversarial generator for an LM (DESIGN.md §2 adaptation).

The paper fits the tree on fixed input features. An LM's features evolve, so
we fit the generator on a *frozen snapshot*: run the current model over a few
batches, collect (hidden state, next token) pairs, PCA-project the hiddens to
k dims (paper §3 'Technical Details'), and fit the tree. The resulting
(proj, tree) pair replaces ``LMHeadState``; the discriminator trains against
it until the next refresh. Overhead is sub-leading, as the paper requires: a
handful of forward passes plus an O(N·k·log C)-phase tree fit.

Fitting goes through :mod:`repro.genfit` (level-parallel by default, with
the sequential recursion and the subtree-sharded fitter as options), and
:func:`refresh_lm_generator` implements the warm-start path for mid-training
refreshes: the projection is *kept* (so the previous tree's split
assignments stay meaningful in the unchanged feature space) and only node
parameters are re-solved — optionally with drift-triggered subtree refits
(DESIGN.md §3). Every path is a deterministic function of (params/state,
batches, config), which the async-refresh protocol relies on.
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heads as heads_lib
from repro.core.heads import Generator
from repro.core.tree_fit import FitConfig, fit_tree, pca_projection
from repro.genfit import (fit_tree_levelwise, fit_tree_sharded,
                          refresh_tree)
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.lm_head import LMHeadState

_FITTERS = {
    "levelwise": fit_tree_levelwise,
    "sequential": fit_tree,
    "sharded": fit_tree_sharded,
}


def collect_features(params, cfg: ModelConfig, batches: Iterable[dict],
                     max_tokens: int = 200_000):
    """Run the model; return (hiddens (N, d) fp32, labels (N,)), N ≤
    ``max_tokens``.

    The jitted forward is traced once, for the first batch's shape: a
    ragged final batch (smaller batch/seq dims) is zero-padded up to that
    shape and only its valid region is collected — causal models give
    bit-identical hiddens for the real tokens, and the padding rows never
    reach the fit. Collection stops *requesting* batches once the cap is
    reached, and each batch is truncated to the remaining budget instead
    of materializing everything and slicing at the end.
    """
    hs, ys = [], []
    remaining = int(max_tokens)
    fwd = jax.jit(lambda p, t: transformer.forward(p, cfg, t)[0])
    shape0 = None
    for batch in batches:
        if remaining <= 0:
            break
        tok = np.asarray(batch["tokens"])
        lab = np.asarray(batch["labels"])
        if shape0 is None:
            shape0 = tok.shape
        b = min(tok.shape[0], shape0[0])
        s = min(tok.shape[1], shape0[1])
        if tok.shape != shape0:
            pad_tok = np.zeros(shape0, tok.dtype)
            pad_tok[:b, :s] = tok[:b, :s]
            tok = pad_tok
        h = np.asarray(fwd(params, jnp.asarray(tok)),
                       np.float32)[:b, :s].reshape(-1, cfg.d_model)
        y = lab[:b, :s].reshape(-1)
        take = min(len(y), remaining)
        hs.append(h[:take])
        ys.append(y[:take])
        remaining -= take
    assert hs, "collect_features: no batches"
    return np.concatenate(hs), np.concatenate(ys)


def _fit_projected_tree(feats, labels, cfg: ModelConfig,
                        fit_config: Optional[FitConfig],
                        method: str):
    """PCA-project, fit, fold the centering into the node biases."""
    proj_np, mean = pca_projection(feats, cfg.gen_feature_dim)
    x_gen = (feats - mean) @ proj_np
    fitter = _FITTERS[method]
    tree = fitter(x_gen, labels, cfg.vocab_size,
                  config=fit_config or FitConfig(reg=0.1))
    # The tree was fitted on centered features (h - mean) @ proj, but at
    # train time we compute h @ proj. Fold the centering into the node
    # biases: z = w.((h - mean) @ proj) + b = w.(h @ proj) + (b - w.(mean
    # @ proj)). Padding-forcing nodes have w = 0, so their +/-PAD_LOGIT
    # biases are untouched.
    offset = jnp.asarray(-(mean @ proj_np), jnp.float32)
    shifted = tree._replace(b=tree.b + tree.w @ offset)
    return shifted, jnp.asarray(proj_np)


def fit_lm_generator(params, cfg: ModelConfig, batches: Iterable[dict],
                     kind: str = "adversarial_ns",
                     fit_config: Optional[FitConfig] = None,
                     max_tokens: int = 200_000,
                     method: str = "levelwise") -> LMHeadState:
    """Snapshot-fit the generator; returns a fresh LMHeadState.

    ``method`` selects the fitter: ``levelwise`` (default; O(log C)
    sequential phases), ``sequential`` (the reference recursion), or
    ``sharded`` (subtree fan-out).
    """
    feats, labels = collect_features(params, cfg, batches, max_tokens)
    if kind == "freq_ns":
        counts = np.bincount(labels, minlength=cfg.vocab_size).astype(
            np.float32)
        gen = heads_lib.make_freq_generator(jnp.asarray(counts))
        proj = jnp.zeros((cfg.d_model, cfg.gen_feature_dim), jnp.float32)
        return LMHeadState(gen=gen, proj=proj)
    tree, proj = _fit_projected_tree(feats, labels, cfg, fit_config,
                                     method)
    return LMHeadState(gen=Generator(tree=tree), proj=proj)


def refresh_lm_generator(prev: LMHeadState, params, cfg: ModelConfig,
                         batches: Iterable[dict],
                         fit_config: Optional[FitConfig] = None,
                         max_tokens: int = 200_000,
                         prev_counts: Optional[np.ndarray] = None,
                         drift_threshold: Optional[float] = None
                         ) -> Tuple[LMHeadState, np.ndarray]:
    """Warm-start generator refresh (incremental path, DESIGN.md §3).

    Keeps ``prev.proj`` — the feature space stays fixed, so the previous
    tree's split assignments remain meaningful — and re-solves only node
    parameters from a fresh snapshot (plus drift-triggered subtree refits
    when ``drift_threshold`` and ``prev_counts`` are given). Returns
    ``(head_state, label_counts)``; feed the counts back at the next
    refresh for drift detection.
    """
    assert prev.gen.tree is not None, "no tree to warm-start from"
    feats, labels = collect_features(params, cfg, batches, max_tokens)
    x_gen = feats @ np.asarray(prev.proj, np.float32)
    tree, counts = refresh_tree(
        prev.gen.tree, x_gen, labels, cfg.vocab_size,
        config=fit_config or FitConfig(reg=0.1),
        prev_counts=prev_counts, drift_threshold=drift_threshold)
    return LMHeadState(gen=Generator(tree=tree), proj=prev.proj), counts


def fit_lm_sampler(kind: str, params, cfg: ModelConfig,
                   batches: Iterable[dict], proj=None,
                   max_tokens: int = 8_192, seed: int = 0, **kwargs):
    """Fit a :mod:`repro.core.samplers` proposal from an LM snapshot.

    Companion to :func:`fit_lm_generator` for the non-tree samplers the
    ``NegativeSampler`` protocol added: collect (hidden, next-token)
    pairs, project hiddens into the generator feature space (``proj`` —
    pass ``head_state.proj`` so the sampler sees the same ``x_gen`` the
    training step computes; PCA-fit a fresh projection when ``None``),
    and fit the requested sampler on per-class mean embeddings
    (lsh/rff) or label counts (unigram). Returns ``(sampler, proj)``.
    """
    from repro.core import samplers as samplers_lib

    if kind == "uniform":
        proj = (jnp.zeros((cfg.d_model, cfg.gen_feature_dim), jnp.float32)
                if proj is None else proj)
        return samplers_lib.UniformSampler(num_labels=cfg.vocab_size), proj
    feats, labels = collect_features(params, cfg, batches, max_tokens)
    if kind == "unigram":
        counts = np.bincount(labels, minlength=cfg.vocab_size).astype(
            np.float32)
        proj = (jnp.zeros((cfg.d_model, cfg.gen_feature_dim), jnp.float32)
                if proj is None else proj)
        return samplers_lib.unigram_from_counts(counts), proj
    if proj is None:
        proj_np, _ = pca_projection(feats, cfg.gen_feature_dim)
        proj = jnp.asarray(proj_np)
    # Uncentered projection, matching the train-time x_gen = h @ proj —
    # unlike the tree fit there is no bias term to fold a centering into
    # (LSH codes are pure sign(x·plane)).
    x_gen = feats @ np.asarray(proj, np.float32)
    sampler = samplers_lib.fit_sampler(kind, x_gen, labels,
                                       cfg.vocab_size, seed=seed, **kwargs)
    return sampler, proj


def make_gen_fit_fn(cfg: ModelConfig, batch_fn, kind: str,
                    fit_config: Optional[FitConfig] = None,
                    max_tokens: int = 16_384, n_batches: int = 8,
                    batch_offset: int = 10_000,
                    method: str = "levelwise",
                    warm_refresh: bool = True):
    """Build the ``gen_fit_fn(state) -> LMHeadState`` used by ``run_loop``.

    The first fit (``state.gen_fit_step < 0``) is a full fit; later
    refreshes warm-start from the in-state tree when ``warm_refresh``.
    Because the decision reads only checkpointed state, a resumed run
    replays exactly the fit the uninterrupted run performed.
    """

    def batches():
        return (batch_fn(batch_offset + i) for i in range(n_batches))

    def gen_fit(state):
        first = int(jax.device_get(state.gen_fit_step)) < 0
        if (first or not warm_refresh or kind != "adversarial_ns"
                or state.head_state.gen.tree is None):
            return fit_lm_generator(state.params, cfg, batches(),
                                    kind=kind, fit_config=fit_config,
                                    max_tokens=max_tokens, method=method)
        head, _ = refresh_lm_generator(state.head_state, state.params,
                                       cfg, batches(),
                                       fit_config=fit_config,
                                       max_tokens=max_tokens)
        return head

    return gen_fit
