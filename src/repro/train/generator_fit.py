"""Fitting the adversarial generator for an LM (DESIGN.md §2 adaptation).

The paper fits the tree on fixed input features. An LM's features evolve, so
we fit the generator on a *frozen snapshot*: run the current model over a few
batches, collect (hidden state, next token) pairs, PCA-project the hiddens to
k dims (paper §3 'Technical Details'), and run the paper's greedy
Newton/balanced-split fit. The resulting (proj, tree) pair replaces
``LMHeadState``; the discriminator trains against it until the next refresh.
Overhead is sub-leading, as the paper requires: a handful of forward passes
plus an O(N·k·log C) tree fit.
"""
from __future__ import annotations

from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heads as heads_lib
from repro.core.heads import Generator
from repro.core.tree_fit import FitConfig, fit_tree, pca_projection
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.lm_head import LMHeadState


def collect_features(params, cfg: ModelConfig, batches: Iterable[dict],
                     max_tokens: int = 200_000):
    """Run the model; return (hiddens (N, d) fp32, labels (N,))."""
    hs, ys = [], []
    total = 0
    fwd = jax.jit(lambda p, t: transformer.forward(p, cfg, t)[0])
    for batch in batches:
        h = fwd(params, jnp.asarray(batch["tokens"]))
        h = np.asarray(h, np.float32).reshape(-1, cfg.d_model)
        y = np.asarray(batch["labels"]).reshape(-1)
        hs.append(h)
        ys.append(y)
        total += len(y)
        if total >= max_tokens:
            break
    return np.concatenate(hs)[:max_tokens], np.concatenate(ys)[:max_tokens]


def fit_lm_generator(params, cfg: ModelConfig, batches: Iterable[dict],
                     kind: str = "adversarial_ns",
                     fit_config: Optional[FitConfig] = None,
                     max_tokens: int = 200_000) -> LMHeadState:
    """Snapshot-fit the generator; returns a fresh LMHeadState."""
    feats, labels = collect_features(params, cfg, batches, max_tokens)
    if kind == "freq_ns":
        counts = np.bincount(labels, minlength=cfg.vocab_size).astype(
            np.float32)
        gen = heads_lib.make_freq_generator(jnp.asarray(counts))
        proj = jnp.zeros((cfg.d_model, cfg.gen_feature_dim), jnp.float32)
        return LMHeadState(gen=gen, proj=proj)
    proj_np, mean = pca_projection(feats, cfg.gen_feature_dim)
    x_gen = (feats - mean) @ proj_np
    tree = fit_tree(x_gen, labels, cfg.vocab_size,
                    config=fit_config or FitConfig(reg=0.1))
    # The tree was fitted on centered features (h - mean) @ proj, but at
    # train time we compute h @ proj. Fold the centering into the node
    # biases: z = w.((h - mean) @ proj) + b = w.(h @ proj) + (b - w.(mean @
    # proj)). Padding-forcing nodes have w = 0, so their +/-PAD_LOGIT biases
    # are untouched.
    offset = jnp.asarray(-(mean @ proj_np), jnp.float32)
    shifted = tree._replace(b=tree.b + tree.w @ offset)
    return LMHeadState(gen=Generator(tree=shifted),
                       proj=jnp.asarray(proj_np))
