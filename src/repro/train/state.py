"""TrainState: trainable params + optimizer state + non-trainable head state
(the paper's generator is deliberately NOT optimized — §2.2 'we can keep
[the generator] constant while training the discriminator')."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

from repro.models.lm_head import LMHeadState


def snr_reset_pair():
    """Fresh (snr_ewma, snr_ref) = (-1.0, -1.0) as two DISTINCT buffers.

    Two separate ``jnp.full((), -1.0)`` calls can come back as one cached
    device buffer (jax caches device_put of scalar constants), and a
    donated train step then rejects the state with "attempt to donate the
    same buffer twice". Slicing a 2-vector guarantees distinct buffers.
    """
    import jax.numpy as jnp

    v = jnp.full((2,), -1.0, jnp.float32)
    return v[0], v[1]


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any
    head_state: LMHeadState
    # Step at which the current generator was (re)fitted; -1 before the
    # first fit. Checkpointed so a resumed run knows which refresh window
    # it is in (repro.genfit.refresh) and swaps are replayed bit-exactly.
    gen_fit_step: jax.Array
    # Online gradient-SNR proxy (heads._sampled_metrics "snr_proxy",
    # DESIGN.md §9): EWMA of the per-batch signal-mass estimate, and the
    # post-refresh reference level it is compared against. Both are -1.0
    # before a value exists and are reset to -1.0 whenever a new generator
    # is installed; checkpointed so the SNR-driven refresh trigger
    # (genfit.refresh.refresh_on_snr) replays identically on resume.
    snr_ewma: jax.Array
    snr_ref: jax.Array

    def as_pytree(self):
        return self._asdict()
