"""TrainState: trainable params + optimizer state + non-trainable head state
(the paper's generator is deliberately NOT optimized — §2.2 'we can keep
[the generator] constant while training the discriminator')."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

from repro.models.lm_head import LMHeadState


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any
    head_state: LMHeadState
    # Step at which the current generator was (re)fitted; -1 before the
    # first fit. Checkpointed so a resumed run knows which refresh window
    # it is in (repro.genfit.refresh) and swaps are replayed bit-exactly.
    gen_fit_step: jax.Array

    def as_pytree(self):
        return self._asdict()
