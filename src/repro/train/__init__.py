from repro.train.loop import LoopConfig, Preemption, StragglerMonitor, run_loop
from repro.train.state import TrainState
from repro.train.step import (init_train_state, make_batched_prefill,
                              make_eval_step, make_paged_decode,
                              make_prefill, make_serve_step,
                              make_train_step)

__all__ = ["LoopConfig", "Preemption", "StragglerMonitor", "run_loop",
           "TrainState", "init_train_state", "make_batched_prefill",
           "make_eval_step", "make_paged_decode", "make_prefill",
           "make_serve_step", "make_train_step"]
