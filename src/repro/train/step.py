"""train_step / eval_step / serve_step builders.

These close over static configs and take pure pytrees, so the same function
jits on one CPU device and pjits on the 512-chip production mesh (the launch
layer supplies in/out shardings). The head strategy — including the paper's
adversarial sampling — is a config knob; `serve_step` applies Eq. 5 bias
removal over the full vocabulary.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.heads import (HeadConfig, HeadParams,
                              resolve_head_update)  # noqa: F401 (re-export)
from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig, apply_updates, init_opt_state
from repro.optim import sparse as sparse_opt
from repro.train.state import TrainState, snr_reset_pair


def loss_fn(params, cfg: ModelConfig, hcfg: HeadConfig, head_state,
            batch: Dict[str, jax.Array], rng: jax.Array, sampler=None):
    h, _, fwd_metrics = transformer.forward(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"))
    labels = batch["labels"]
    mask = batch.get("mask")
    if cfg.modality == "vision" and labels.shape[1] != h.shape[1]:
        # Vision prefix carries no next-token loss.
        nv = h.shape[1] - labels.shape[1]
        h = h[:, nv:]
    loss, head_metrics = lm_head.lm_head_loss(
        cfg, hcfg, HeadParams(**params["head"]), head_state, h, labels,
        rng, mask=mask, sampler=sampler)
    metrics = {"loss": loss, **fwd_metrics, **head_metrics}
    return loss, metrics


def make_train_step(cfg: ModelConfig, hcfg: HeadConfig,
                    opt_cfg: OptimizerConfig, head_update: str = "auto",
                    head_kernel: bool = False, mesh=None,
                    sampler=None, snr_alpha: float = 0.1,
                    embed_update: str = "auto",
                    skip_nonfinite: bool = False):
    """Returns train_step(state, batch, rng) -> (state, metrics).

    ``head_update`` picks the head-gradient path (DESIGN.md §8):

    * ``dense`` — ``jax.value_and_grad`` end to end: autodiff scatter-adds
      the candidate-score backward into a dense (C, K) gradient and the
      optimizer walks every row. O(C·K) per step regardless of sampling.
    * ``sparse`` — the trunk still backprops through ``jax.vjp`` (driven
      by the analytic head cotangent ``dh``), but the head gradient is a
      ``SparseRows`` leaf over the ≤ B·(1+n_neg) touched rows and the
      optimizer applies O(U·K) row updates. Identical math on the touched
      rows (exact for Adagrad/SGD, lazy-decay AdamW), cost independent
      of C.
    * ``auto`` (default) — sparse for sampled heads, dense for `softmax`.

    ``head_kernel`` routes the sparse path's gather→loss→coefficient chain
    through the fused Pallas kernel. ``mesh`` lets the sparse optimizer
    update run shard-local on a vocab-sharded head (each model shard
    applies only the rows it owns — ``parallel.collectives``).

    ``sampler`` overrides the negative-sampling proposal with an explicit
    :class:`repro.core.samplers.NegativeSampler` (closed over, so it is
    static for the life of the step function — generator refreshes only
    reach the default ``cfg.kind``-derived proposal, which is rebuilt from
    ``head_state`` every call). ``snr_alpha`` is the EWMA weight of the
    online SNR proxy tracked in ``TrainState.snr_ewma`` for the
    SNR-driven refresh trigger (DESIGN.md §9).

    ``skip_nonfinite`` arms the DESIGN.md §13 skip-step guard *inside*
    the jitted step: when the loss (or grad norm) is non-finite, every
    params/opt/EWMA leaf selects its pre-step value, so a poisoned batch
    costs one wasted step instead of corrupting the run. The select must
    live in-graph because the loop donates the input state — by the time
    the host sees the metrics, the pre-step buffers are gone. The step
    counter still advances (data/rng streams are step-indexed and must
    not replay the bad batch) and ``metrics["nonfinite"]`` reports the
    skip for the loop's counter / consecutive-skip limit.

    ``embed_update`` extends the sparse treatment to the *input* embedding
    (DESIGN.md §11): the token gather runs outside the trunk vjp, its
    cotangent rows are deduped into a SparseRows leaf, and the optimizer
    applies O(touched-tokens·d) row updates instead of scatter-adding a
    dense (V, d) gradient. ``auto`` (default) rides with the head: sparse
    when the head path is sparse, dense otherwise; ``dense`` forces the
    old behaviour.
    """
    mode = resolve_head_update(head_update, hcfg.kind)
    assert not (head_kernel and mode == "dense"), (
        "head_kernel routes the SPARSE path through the fused Pallas "
        "kernel; the resolved head_update here is 'dense', which would "
        "silently ignore it")
    assert embed_update in ("auto", "sparse", "dense"), embed_update
    emode = embed_update
    if emode == "auto":
        emode = "sparse" if mode == "sparse" else "dense"
    assert not (emode == "sparse" and mode == "dense"), (
        "sparse embed updates ride the sparse-head step (jax.vjp); the "
        "dense value_and_grad path cannot deliver them")

    def dense_step(state: TrainState, batch, rng):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (loss, metrics), grads = grad_fn(state.params, cfg, hcfg,
                                         state.head_state, batch, rng,
                                         sampler)
        return grads, metrics

    def sparse_step(state: TrainState, batch, rng):
        params = state.params
        # Sparse embed path: run the token gather OUTSIDE the trunk vjp
        # (forward takes inputs_embeds) and collect its cotangent rows as
        # SparseRows instead of letting autodiff scatter-add a dense
        # (V, d) gradient. Trace-time Python check: params without an
        # embedding table (standalone-head configs) fall back to dense.
        embed_sparse = emode == "sparse" and "embed" in params
        drop = {"head", "embed"} if embed_sparse else {"head"}
        trunk = {k: v for k, v in params.items() if k not in drop}
        tokens = batch["tokens"]

        if embed_sparse:
            cdt = jnp.dtype(cfg.dtype)
            h0 = jnp.take(params["embed"], tokens, axis=0).astype(cdt)

            def trunk_fwd(tp, h0_in):
                ve = batch.get("vision_embeds")
                ie = (h0_in if ve is None
                      else jnp.concatenate([ve.astype(cdt), h0_in],
                                           axis=1))
                h, _, fwd_metrics = transformer.forward(
                    tp, cfg, tokens, positions=batch.get("positions"),
                    inputs_embeds=ie)
                return h, fwd_metrics

            h, trunk_vjp, fwd_metrics = jax.vjp(trunk_fwd, trunk, h0,
                                                has_aux=True)
        else:
            def trunk_fwd(tp):
                h, _, fwd_metrics = transformer.forward(
                    tp, cfg, tokens, positions=batch.get("positions"),
                    vision_embeds=batch.get("vision_embeds"))
                return h, fwd_metrics

            h, trunk_vjp, fwd_metrics = jax.vjp(trunk_fwd, trunk,
                                                has_aux=True)
        labels = batch["labels"]
        n_vis = 0
        if cfg.modality == "vision" and labels.shape[1] != h.shape[1]:
            n_vis = h.shape[1] - labels.shape[1]
        loss, head_metrics, sparse, dh = lm_head.lm_sparse_head_loss(
            cfg, hcfg, HeadParams(**params["head"]), state.head_state,
            h[:, n_vis:] if n_vis else h, labels, rng,
            mask=batch.get("mask"), use_kernel=head_kernel, sampler=sampler)
        if n_vis:   # vision prefix carries no next-token loss
            dh = jnp.pad(dh, ((0, 0), (n_vis, 0), (0, 0)))
        if embed_sparse:
            trunk_grads, dh0 = trunk_vjp(dh.astype(h.dtype))
            vocab = params["embed"].shape[0]
            grads = {**trunk_grads, "head": sparse,
                     "embed": sparse_opt.accumulate_embed_rows(
                         tokens.reshape(-1),
                         dh0.reshape(-1, dh0.shape[-1]), vocab)}
        else:
            (trunk_grads,) = trunk_vjp(dh.astype(h.dtype))
            grads = {**trunk_grads, "head": sparse}
        metrics = {"loss": loss, **fwd_metrics, **head_metrics}
        return grads, metrics

    def train_step(state: TrainState, batch, rng):
        # named_scope labels the jaxpr/HLO so a --profile-dir device
        # capture attributes ops to the same phases the host spans use
        # (DESIGN.md §10): loss+grad (incl. sample-negatives inside the
        # head loss) vs the optimizer scatter.
        with jax.named_scope("loss_and_grad"):
            grads, metrics = (dense_step if mode == "dense"
                              else sparse_step)(state, batch, rng)
        with jax.named_scope("optimizer_scatter"):
            new_params, new_opt, opt_metrics = apply_updates(
                opt_cfg, state.params, grads, state.opt_state, mesh=mesh)
        metrics.update(opt_metrics)
        # Fold the per-batch signal-mass proxy into the EWMA the SNR
        # refresh trigger watches. "snr_proxy" presence is a trace-time
        # Python check (the head kind is static), so the dense-softmax
        # path compiles without the extra arithmetic. snr_ref is armed
        # host-side by the loop; the step only smooths.
        snr_ewma = state.snr_ewma
        if "snr_proxy" in metrics:
            p = metrics["snr_proxy"].astype(jnp.float32)
            snr_ewma = jnp.where(
                state.snr_ewma < 0, p,
                (1.0 - snr_alpha) * state.snr_ewma + snr_alpha * p)
            metrics["snr_ewma"] = snr_ewma
        if skip_nonfinite:
            ok = jnp.isfinite(metrics["loss"])
            if "grad_norm" in metrics:
                ok = ok & jnp.isfinite(metrics["grad_norm"])
            sel = lambda n, o: jnp.where(ok, n, o)  # noqa: E731
            new_params = jax.tree.map(sel, new_params, state.params)
            new_opt = jax.tree.map(sel, new_opt, state.opt_state)
            snr_ewma = jnp.where(ok, snr_ewma, state.snr_ewma)
            metrics["nonfinite"] = (~ok).astype(jnp.float32)
        return TrainState(step=state.step + 1, params=new_params,
                          opt_state=new_opt,
                          head_state=state.head_state,
                          gen_fit_step=state.gen_fit_step,
                          snr_ewma=snr_ewma,
                          snr_ref=state.snr_ref), metrics

    return train_step


# Jitted-metric name -> repro.obs gauge (DESIGN.md §10 `snr/*` and
# `train/*` namespaces). The SNR triple drives the refresh trigger
# (snr_proxy = per-batch Eq. 13 signal mass, snr_ewma = its smoothed
# TrainState series, snr_ref = the armed post-install reference), and
# publishing them as gauges is what makes --gen-refresh-mode snr
# observable outside TrainState.
STEP_METRIC_GAUGES = {
    "loss": "train/loss",
    "grad_norm": "train/grad_norm",
    "snr_proxy": "snr/proxy",
    "snr_ewma": "snr/ewma",
}


def publish_step_metrics(registry, host_metrics: Dict[str, float],
                         snr_ref: Optional[float] = None,
                         head_state_bytes: Optional[int] = None) -> None:
    """Host-side bridge from a jitted step's metrics dict to the obs
    registry. The step function runs under jit and cannot touch host
    state, so the loop device_gets the (tiny, already-computed) metrics
    once per step and publishes through this mapping; ``snr_ref`` lives
    on TrainState, not in the metrics dict, and is passed separately.
    ``head_state_bytes`` (optim.head_state_bytes — a static byte count,
    computed once at loop start) lands on the ``train/head_state_bytes``
    gauge so the DESIGN.md §11 memory model is observable in prod."""
    registry.counter("train/steps").inc()
    for src, name in STEP_METRIC_GAUGES.items():
        if src in host_metrics:
            registry.gauge(name).set(host_metrics[src])
    if snr_ref is not None:
        registry.gauge("snr/ref").set(snr_ref)
    if head_state_bytes is not None:
        registry.gauge("train/head_state_bytes").set(head_state_bytes)


def make_eval_step(cfg: ModelConfig, hcfg: HeadConfig):
    """Debiased predictive log-likelihood + accuracy (paper Fig. 1 axes)."""

    def eval_step(state: TrainState, batch):
        h, _, _ = transformer.forward(
            state.params, cfg, batch["tokens"],
            positions=batch.get("positions"),
            vision_embeds=batch.get("vision_embeds"))
        scores = lm_head.lm_predictive_scores(
            cfg, hcfg, HeadParams(**state.params["head"]),
            state.head_state, h)
        labels = batch["labels"].astype(jnp.int32)
        mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))
        logp = scores - jax.nn.logsumexp(scores, axis=-1, keepdims=True)
        pos = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        acc = (jnp.argmax(scores, -1) == labels).astype(jnp.float32)
        denom = jnp.maximum(mask.sum(), 1.0)
        return {"eval_loglik": jnp.sum(pos * mask) / denom,
                "eval_acc": jnp.sum(acc * mask) / denom}

    return eval_step


def make_serve_step(cfg: ModelConfig, hcfg: HeadConfig,
                    topk_beam: int = 0, use_kernel: bool = False,
                    mesh=None):
    """Greedy decode step: one token in, one token out, cache updated.

    With ``topk_beam == 0`` (default) the predictive scores are dense: the
    paper's bias removal (Eq. 5) as an O(C·k) tree pass riding on top of the
    O(C·K) logits matmul. With ``topk_beam > 0`` the decode never touches
    O(C): beam search over the generator tree proposes ``topk_beam``
    candidates in O(beam·k·log C) and only those are scored + debiased
    (``use_kernel`` routes the scoring through the gather_scores Pallas
    kernel). Both paths pick the same argmax whenever the true top-1 label
    survives the beam.

    ``mesh`` routes the beam path's candidate scoring through
    ``parallel.collectives.sharded_candidate_scores``: each model shard
    scores only the candidate rows it owns and one psum of the tiny
    (batch, beam) score tensor replicates the result — no all-gather of
    the vocab-sharded output embedding.
    """
    score_fn = (lm_head.serving_score_fn(cfg, use_kernel=use_kernel,
                                         mesh=mesh)
                if topk_beam else None)

    def serve_step(params, head_state, token, cache, cache_pos,
                   positions=None):
        h, new_cache, _ = transformer.forward(
            params, cfg, token, positions=positions, cache=cache,
            cache_pos=cache_pos)
        head_params = HeadParams(**params["head"])
        if topk_beam:
            _, labels = lm_head.lm_predictive_topk(
                cfg, hcfg, head_params, head_state, h[:, -1], topk=1,
                beam=topk_beam, use_kernel=use_kernel, score_fn=score_fn)
            next_token = labels[..., 0].astype(jnp.int32)
        else:
            scores = lm_head.lm_predictive_scores(
                cfg, hcfg, head_params, head_state, h[:, -1])
            next_token = jnp.argmax(scores, axis=-1).astype(jnp.int32)
        return next_token[:, None], new_cache

    return serve_step


def make_prefill(cfg: ModelConfig):
    def prefill(params, tokens, cache, vision_embeds=None, positions=None):
        h, new_cache, _ = transformer.forward(
            params, cfg, tokens, positions=positions,
            vision_embeds=vision_embeds, cache=cache,
            cache_pos=jnp.int32(0))
        return h, new_cache

    return prefill


def make_batched_prefill(cfg: ModelConfig, page_len: int, sink_page: int,
                         cache_dtype=jnp.bfloat16):
    """Prefill a *batch* of newly-admitted requests into their pages
    (repro.serve). Generalizes the old one-request-per-call
    ``make_prefill_into_slot``: every request admitted in an engine
    iteration runs through ONE padded forward instead of N sequential
    single-row calls.

    Returns ``batched_prefill(params, tokens, lengths, lanes, arena,
    page_tables)`` → ``(h, arena)``:

    - ``tokens`` (N, S): right-padded prompts. Padding is invisible to the
      real tokens (causal attention never looks forward), so each row's
      K/V and hiddens are bit-identical to an unpadded single-request
      prefill — the property the batched-vs-sequential oracle pins.
    - ``lengths`` (N,): true prompt lengths; positions at or beyond a
      row's length scatter into ``sink_page`` (the allocator's garbage
      page) instead of a mapped page.
    - ``lanes`` (N,): decode-lane index per row, for the lane-indexed SSM
      conv/state leaves. Padding rows carry an out-of-range lane and are
      dropped by the scatter.
    - ``page_tables`` (N, max_pages): each row's logical→physical page
      map; logical position p lands at ``(page_tables[n, p // page_len],
      p % page_len)``.

    The forward runs against a fresh contiguous (N, S) cache — identical
    math to :func:`make_prefill` — and only the final scatter re-addresses
    the resulting K/V into the paged arena. N and S are shape-traced, so
    the engine buckets both (rows to a power of two, lengths to a power of
    two) to bound recompiles.
    """

    def batched_prefill(params, tokens, lengths, lanes, arena, page_tables):
        n, s = tokens.shape
        fresh = transformer.init_cache(cfg, n, s, dtype=cache_dtype)
        h, new_cache, _ = transformer.forward(
            params, cfg, tokens, cache=fresh, cache_pos=jnp.int32(0))
        pos = jnp.arange(s, dtype=jnp.int32)
        pid = page_tables[:, pos // page_len]               # (N, S)
        pid = jnp.where(pos[None, :] < lengths[:, None], pid, sink_page)
        off = jnp.broadcast_to((pos % page_len)[None], (n, s))
        out = dict(arena)
        for key in ("k", "v"):
            if key in arena:      # (L, P, page_len, KV, hd) ← (L, N, S, ..)
                out[key] = arena[key].at[:, pid, off].set(
                    new_cache[key].astype(arena[key].dtype))
        for key in ("conv", "state"):
            if key in arena:      # lane-indexed; padding lanes drop
                out[key] = arena[key].at[:, lanes].set(
                    new_cache[key].astype(arena[key].dtype), mode="drop")
        return h, out

    return batched_prefill


def make_paged_prefill(cfg: ModelConfig):
    """Multi-token forward *against the paged arena*: per-lane start
    positions and page tables, S tokens per row.

    Returns ``paged_prefill(params, tokens, start, lengths, arena,
    page_tables)`` → ``(h (B, S, d), arena)``. Two serving paths share this
    one compiled step (repro.serve, DESIGN.md §12):

    - **shared-prefix suffix prefill**: a newly admitted request whose
      prompt prefix is already resident in shared pages runs its *suffix*
      through here — row r's tokens are ``prompt[start[r]:]``, attention
      gathers the shared prefix pages through the page table, and only the
      suffix K/V is computed and written. The prefix pays its prefill once
      across every request that shares it.
    - **speculative verify**: the draft chain ``[y_last, d1..dk]`` runs as
      one batched multi-token step; the returned per-position hiddens feed
      next-token selection at every draft position in a single launch.

    ``start`` (B,) is each row's first logical position, ``lengths`` (B,)
    its true token count — positions at or beyond a row's length (row/
    length padding) write to the allocator's sink page via ``write_mask``
    and their hiddens are garbage the caller masks host-side. Unlike
    :func:`make_batched_prefill` there is no fresh contiguous cache: the
    forward reads and writes the arena directly, so earlier tokens'
    K/V — shared prefix pages or the rows' own prior decode writes — are
    visible exactly as the contiguous layout would present them
    (byte-identity pinned by the sharing/speculation oracle tests).
    Attention-family models only: an SSM branch carries recurrent state
    that is neither paged nor position-local.
    """
    assert cfg.block == "attn", (
        "paged multi-token steps (prefix sharing / speculative verify) "
        "need position-local state; SSM/hybrid caches are recurrent")

    def paged_prefill(params, tokens, start, lengths, arena, page_tables):
        s = tokens.shape[1]
        wmask = jnp.arange(s, dtype=jnp.int32)[None] < lengths[:, None]
        h, new_arena, _ = transformer.forward(
            params, cfg, tokens, cache=arena, cache_pos=start,
            page_table=page_tables, write_mask=wmask)
        return h, new_arena

    return paged_prefill


def make_paged_decode(cfg: ModelConfig):
    """Masked decode step over a paged pool: per-lane ``cache_pos`` and
    page tables.

    Returns ``paged_decode(params, token, arena, cache_pos, page_table)``
    → ``(h_last (B, d), arena)``. ``token`` is (B, 1) — one in-flight
    token per decode lane — ``cache_pos`` is a (B,) int32 vector (each
    lane at its own depth), and ``page_table`` (B, max_pages) maps each
    lane's logical pages onto the shared arena. Free lanes ride along as
    garbage: their page-table rows point at the sink page, so their
    writes land in the garbage page and every consumer of ``h_last``
    masks them out host-side. Head scoring is deliberately NOT fused here
    — the serve engine owns it so the candidate cache can skip the tree
    descent per step.
    """

    def paged_decode(params, token, arena, cache_pos, page_table):
        h, new_arena, _ = transformer.forward(
            params, cfg, token, cache=arena, cache_pos=cache_pos,
            page_table=page_table)
        return h[:, -1], new_arena

    return paged_decode


def init_train_state(rng, cfg: ModelConfig, opt_cfg: OptimizerConfig,
                     head_kind: str) -> TrainState:
    k_p, k_h = jax.random.split(rng)
    params = transformer.init_params(k_p, cfg)
    ewma0, ref0 = snr_reset_pair()
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=init_opt_state(opt_cfg, params),
        head_state=lm_head.default_head_state(k_h, cfg, head_kind),
        gen_fit_step=jnp.full((), -1, jnp.int32),
        snr_ewma=ewma0, snr_ref=ref0)
