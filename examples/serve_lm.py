"""Batched serving with the adversarial head's bias removal (Eq. 5).

Part 1 — lock-step decode, three head paths on the same prompts:

- dense path: xi + log p_n over the full vocab (O(C·K) logits matmul plus
  the O(C·k) level-recursive tree pass);
- beam path: tree-guided beam search proposes a handful of candidates in
  O(beam·k·log C), only those are scored and debiased — decode never
  touches O(C);
- exhaustive beam (= padded vocab): must reproduce dense token-for-token.

Part 2 — the same prompts through the continuous-batching engine
(`repro.serve`): a paged KV pool sized to HALF the monolithic bytes (pages
of 8 positions instead of one max_len buffer per lane), fewer decode lanes
than requests (so admission actually queues), batched multi-request
prefill, per-request EOS + max-new-tokens retirement with page
reclamation, and the prefix-keyed candidate cache skipping the tree
descent on resubmitted prompts. Engine outputs are asserted byte-identical
to the lock-step beam decode — paging changes physical KV addressing,
never the math.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.serve import Engine, Request, ServeConfig
from repro.train import make_prefill, make_serve_step


def main():
    cfg = ModelConfig(
        name="serve-demo", num_layers=2, d_model=128, d_ff=384,
        vocab_size=1024, num_heads=4, num_kv_heads=2,
        vocab_pad_multiple=128, gen_feature_dim=16, dtype="float32",
        remat=False)
    batch, prompt_len, gen_tokens, max_len = 8, 24, 16, 48

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    head_state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                            "adversarial_ns")
    hcfg = lm_head.head_config(cfg, "adversarial_ns")
    prefill = jax.jit(make_prefill(cfg))
    # beam=32: the fast sublinear path; beam=1024 (= padded vocab): an
    # exhaustive beam, which must reproduce the dense decode token-for-token.
    steps = {
        "dense": jax.jit(make_serve_step(cfg, hcfg)),
        "beam=32": jax.jit(make_serve_step(cfg, hcfg, topk_beam=32)),
        "beam=full": jax.jit(make_serve_step(cfg, hcfg, topk_beam=1024)),
    }

    prompts = jax.random.randint(jax.random.PRNGKey(2),
                                 (batch, prompt_len), 0, cfg.vocab_size)

    decoded = {}
    for name, serve_step in steps.items():
        cache = transformer.init_cache(cfg, batch, max_len,
                                       dtype=jnp.float32)
        t0 = time.time()
        _, cache = prefill(params, prompts, cache)
        print(f"[{name}] prefill: batch={batch} len={prompt_len} "
              f"({(time.time()-t0)*1e3:.0f} ms)")

        token = prompts[:, -1:]
        out = []
        t0 = time.time()
        for t in range(gen_tokens):
            token, cache = serve_step(params, head_state, token, cache,
                                      jnp.int32(prompt_len + t))
            out.append(token)
        dt = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        print(f"[{name}] decoded {gen_tokens} tokens x {batch} seqs in "
              f"{dt*1e3:.0f} ms ({batch*gen_tokens/dt:.0f} tok/s, greedy, "
              f"debiased scores)")
        print(f"[{name}] sample:", gen[0].tolist())
        assert gen.shape == (batch, gen_tokens)
        assert int(gen.max()) < cfg.vocab_size
        assert int(gen.min()) >= 0
        decoded[name] = gen

    assert bool(jnp.all(decoded["dense"] == decoded["beam=full"])), \
        "exhaustive beam must match the dense decode exactly"
    agree = float(jnp.mean((decoded["dense"] == decoded["beam=32"]
                            ).astype(jnp.float32)))
    # The demo generator is a random init, so its beam proposes near-uniform
    # candidates; agreement climbs towards 100% once the tree is fitted to
    # the model (repro.train.generator_fit).
    print(f"dense/beam=32 token agreement: {agree:.0%} (unfitted generator)")

    # --- Part 2: continuous-batching engine over a paged KV pool --------
    # Half as many decode lanes as requests (admission queues and
    # back-fills retired lanes mid-flight) AND half the monolithic pool's
    # KV bytes: pages of 8 positions, 12 pages ≈ (4 lanes × 48)/2
    # positions. Each request maps ceil(40/8) = 5 pages, so two run
    # concurrently per admission round — memory, not lanes, is the honest
    # limit. Same prompts, same beam → byte-identical anyway.
    page_len = 8
    n_pages = (batch // 2) * max_len // 2 // page_len
    engine = Engine(cfg, hcfg, params, head_state, ServeConfig(
        n_slots=batch // 2, max_len=max_len, beam=32, page_len=page_len,
        n_pages=n_pages, cache_dtype=jnp.float32))
    prompts_np = np.asarray(prompts)
    t0 = time.time()
    handles = [engine.submit(Request(prompt=p, max_new_tokens=gen_tokens))
               for p in prompts_np]
    engine.run()
    dt = time.time() - t0
    out = np.stack([h.result() for h in handles])
    assert (out == np.asarray(decoded["beam=32"])).all(), \
        "engine must reproduce the lock-step beam decode byte-for-byte"
    st = engine.stats()
    assert st["peak_pages_in_use"] <= n_pages and st["pages_in_use"] == 0
    print(f"[engine] {batch} requests over {batch // 2} lanes / "
          f"{n_pages} pages x {page_len} (half the monolithic KV bytes) "
          f"in {dt*1e3:.0f} ms ({batch*gen_tokens/dt:.0f} tok/s); "
          f"outputs == lock-step beam=32; peak pages "
          f"{st['peak_pages_in_use']}/{n_pages}, "
          f"{st['prefill_calls']} batched prefill launches")

    # Resubmit the same prompts: every step's candidate set is a prefix hit,
    # so the tree descent is skipped entirely (descent_skips > 0). Hit rate
    # is the delta over this run — the lifetime rate would fold in the
    # first run's all-miss lookups.
    before = engine.candidate_cache.stats()
    skips_before = engine.descent_skips
    for p in prompts_np:
        engine.submit(Request(prompt=p, max_new_tokens=gen_tokens))
    engine.run()
    after = engine.candidate_cache.stats()
    hits = after["hits"] - before["hits"]
    lookups = hits + after["misses"] - before["misses"]
    skips = engine.descent_skips - skips_before
    assert hits > 0 and skips > 0
    print(f"[engine] resubmitted prompts: candidate-cache hit rate "
          f"{hits / lookups:.0%}, {skips} decode steps skipped the tree "
          "descent")

    # EOS + per-request max-length: stop at a token we know the greedy
    # decode emits; that request retires early and frees its slot.
    eos = int(out[0, 3])
    first = out[0].tolist().index(eos)   # the token may repeat earlier
    h_eos = engine.submit(Request(prompt=prompts_np[0],
                                  max_new_tokens=gen_tokens, eos_id=eos))
    h_short = engine.submit(Request(prompt=prompts_np[1],
                                    max_new_tokens=3))
    engine.run()
    assert h_eos.eos_hit and len(h_eos.tokens) == first + 1, h_eos.tokens
    assert len(h_short.tokens) == 3
    print(f"[engine] eos_id={eos}: stopped after {len(h_eos.tokens)} tokens"
          f" (max was {gen_tokens}); max_new_tokens=3 request emitted "
          f"{len(h_short.tokens)}")
    print("OK")


if __name__ == "__main__":
    main()
