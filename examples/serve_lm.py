"""Batched serving with the adversarial head's bias removal (Eq. 5).

Prefill a batch of prompts, then greedy-decode with a KV cache, twice:

- dense path: xi + log p_n over the full vocab (O(C·K) logits matmul plus
  the O(C·k) level-recursive tree pass);
- beam path: tree-guided beam search proposes a handful of candidates in
  O(beam·k·log C), only those are scored and debiased — decode never
  touches O(C).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.train import make_prefill, make_serve_step


def main():
    cfg = ModelConfig(
        name="serve-demo", num_layers=2, d_model=128, d_ff=384,
        vocab_size=1024, num_heads=4, num_kv_heads=2,
        vocab_pad_multiple=128, gen_feature_dim=16, dtype="float32",
        remat=False)
    batch, prompt_len, gen_tokens, max_len = 8, 24, 16, 48

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    head_state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                            "adversarial_ns")
    hcfg = lm_head.head_config(cfg, "adversarial_ns")
    prefill = jax.jit(make_prefill(cfg))
    # beam=32: the fast sublinear path; beam=1024 (= padded vocab): an
    # exhaustive beam, which must reproduce the dense decode token-for-token.
    steps = {
        "dense": jax.jit(make_serve_step(cfg, hcfg)),
        "beam=32": jax.jit(make_serve_step(cfg, hcfg, topk_beam=32)),
        "beam=full": jax.jit(make_serve_step(cfg, hcfg, topk_beam=1024)),
    }

    prompts = jax.random.randint(jax.random.PRNGKey(2),
                                 (batch, prompt_len), 0, cfg.vocab_size)

    decoded = {}
    for name, serve_step in steps.items():
        cache = transformer.init_cache(cfg, batch, max_len,
                                       dtype=jnp.float32)
        t0 = time.time()
        _, cache = prefill(params, prompts, cache)
        print(f"[{name}] prefill: batch={batch} len={prompt_len} "
              f"({(time.time()-t0)*1e3:.0f} ms)")

        token = prompts[:, -1:]
        out = []
        t0 = time.time()
        for t in range(gen_tokens):
            token, cache = serve_step(params, head_state, token, cache,
                                      jnp.int32(prompt_len + t))
            out.append(token)
        dt = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        print(f"[{name}] decoded {gen_tokens} tokens x {batch} seqs in "
              f"{dt*1e3:.0f} ms ({batch*gen_tokens/dt:.0f} tok/s, greedy, "
              f"debiased scores)")
        print(f"[{name}] sample:", gen[0].tolist())
        assert gen.shape == (batch, gen_tokens)
        assert int(gen.max()) < cfg.vocab_size
        assert int(gen.min()) >= 0
        decoded[name] = gen

    assert bool(jnp.all(decoded["dense"] == decoded["beam=full"])), \
        "exhaustive beam must match the dense decode exactly"
    agree = float(jnp.mean((decoded["dense"] == decoded["beam=32"]
                            ).astype(jnp.float32)))
    # The demo generator is a random init, so its beam proposes near-uniform
    # candidates; agreement climbs towards 100% once the tree is fitted to
    # the model (repro.train.generator_fit).
    print(f"dense/beam=32 token agreement: {agree:.0%} (unfitted generator)")
    print("OK")


if __name__ == "__main__":
    main()
