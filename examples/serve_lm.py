"""Batched serving with the adversarial head's bias removal (Eq. 5).

Prefill a batch of prompts, then greedy-decode with a KV cache; predictive
scores are xi + log p_n (the paper's Step 3) computed by the dense
level-recursive tree pass — the O(C·k) rider on the O(C·K) logits matmul.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.train import make_prefill, make_serve_step


def main():
    cfg = ModelConfig(
        name="serve-demo", num_layers=2, d_model=128, d_ff=384,
        vocab_size=1024, num_heads=4, num_kv_heads=2,
        vocab_pad_multiple=128, gen_feature_dim=16, dtype="float32",
        remat=False)
    batch, prompt_len, gen_tokens, max_len = 8, 24, 16, 48

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    head_state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                            "adversarial_ns")
    hcfg = lm_head.head_config(cfg, "adversarial_ns")
    prefill = jax.jit(make_prefill(cfg))
    serve_step = jax.jit(make_serve_step(cfg, hcfg))

    prompts = jax.random.randint(jax.random.PRNGKey(2),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    cache = transformer.init_cache(cfg, batch, max_len, dtype=jnp.float32)

    t0 = time.time()
    _, cache = prefill(params, prompts, cache)
    print(f"prefill: batch={batch} len={prompt_len} "
          f"({(time.time()-t0)*1e3:.0f} ms)")

    token = prompts[:, -1:]
    out = [token]
    t0 = time.time()
    for t in range(gen_tokens):
        token, cache = serve_step(params, head_state, token, cache,
                                  jnp.int32(prompt_len + t))
        out.append(token)
    dt = time.time() - t0
    gen = jnp.concatenate(out[1:], axis=1)
    print(f"decoded {gen_tokens} tokens x {batch} seqs in {dt*1e3:.0f} ms "
          f"({batch*gen_tokens/dt:.0f} tok/s, greedy, debiased scores)")
    print("sample:", gen[0].tolist())
    assert gen.shape == (batch, gen_tokens)
    assert int(gen.max()) < cfg.vocab_size
    print("OK")


if __name__ == "__main__":
    main()
