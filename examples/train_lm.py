"""End-to-end LM training driver with the adversarial softmax head.

Pipeline: synthetic clustered-bigram token stream -> decoder LM ->
generator warmup fit (tree on a frozen hidden-state snapshot) ->
adversarial-NS training with checkpoints + straggler monitor ->
debiased eval (Eq. 5).

Profiles:
  demo  (default) — ~1M params, 60 steps, runs in ~1 min on CPU
  100m           — ~100M params (d=768, 12L), a few hundred steps; the
                   same code pjits onto the production mesh via --arch
                   configs in repro.launch.train for cluster runs.

Run:  PYTHONPATH=src python examples/train_lm.py [--profile demo|100m]
      [--head adversarial_ns|softmax|uniform_ns|...]
      [--gen-refresh N] [--gen-async] [--gen-swap-delay D]

The generator-refresh demo (the loop's "Step 1" end-to-end): with
``--gen-refresh N`` the tree is refitted every N steps from a frozen
snapshot — warm-started from the previous tree after the first fit
(watch the printed fit times collapse once the structure is reused). In
blocking mode the whole loop stalls for each fit; with ``--gen-async``
the fit runs in a background thread while training keeps stepping on the
stale generator, and the new tree is swapped in at the recorded step
(submit + D) — same schedule, no stall, bit-exact under resume.
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core.tree_fit import FitConfig
from repro.data import lm_batch_fn
from repro.models import lm_head
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig
from repro.train import (LoopConfig, init_train_state, make_eval_step,
                         make_train_step, run_loop)
from repro.train.generator_fit import make_gen_fit_fn

PROFILES = {
    "demo": dict(num_layers=2, d_model=128, d_ff=384, vocab_size=2048,
                 num_heads=4, num_kv_heads=2, seq=64, batch=8, steps=150,
                 gen_warmup=60),
    "100m": dict(num_layers=12, d_model=768, d_ff=2304, vocab_size=32_768,
                 num_heads=12, num_kv_heads=4, seq=512, batch=8, steps=300,
                 gen_warmup=50),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="demo", choices=PROFILES)
    ap.add_argument("--head", default="adversarial_ns")
    ap.add_argument("--head-update", default="auto",
                    choices=("auto", "dense", "sparse"),
                    help="head-gradient path (DESIGN.md §8): sparse = "
                         "O(B·K·n_neg) touched-row updates, independent "
                         "of vocab size; dense = O(C·K) autodiff. auto "
                         "picks sparse for sampled heads.")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--gen-refresh", type=int, default=None,
                    help="refresh the generator every N steps "
                         "(default: steps // 3)")
    ap.add_argument("--gen-async", action="store_true",
                    help="non-blocking refresh: fit in a background "
                         "thread, swap at the recorded step")
    ap.add_argument("--gen-swap-delay", type=int, default=8)
    args = ap.parse_args()
    p = PROFILES[args.profile]
    steps = args.steps or p["steps"]

    cfg = ModelConfig(
        name=f"lm-{args.profile}", num_layers=p["num_layers"],
        d_model=p["d_model"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        vocab_pad_multiple=128, gen_feature_dim=16, dtype="float32",
        remat=False)
    print(f"model: {cfg.param_count()/1e6:.1f}M params, head={args.head}")

    hcfg = lm_head.head_config(cfg, args.head, n_neg=1, reg=1e-4)
    opt = OptimizerConfig(name="adagrad", learning_rate=0.05, clip_norm=1.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, args.head)
    from repro.train.step import resolve_head_update
    head_update = resolve_head_update(args.head_update, args.head)
    desc = ("O(B·K·n_neg) touched-row updates, independent of C"
            if head_update == "sparse"
            else "dense O(C·K) gradient + optimizer sweep")
    print(f"head update: {head_update} ({desc})")
    # Donate the state so sparse row scatters run in place (no (C, K)
    # copy per step). Safe with --gen-async too: run_loop snapshots the
    # leaves the background fit reads before submitting (snapshot-then-
    # donate), so training can keep invalidating its own buffers.
    donate = (0,)
    train_step = jax.jit(make_train_step(cfg, hcfg, opt,
                                         head_update=head_update),
                         donate_argnums=donate)
    eval_step = jax.jit(make_eval_step(cfg, hcfg))

    make = lm_batch_fn(cfg.vocab_size, p["batch"], p["seq"], seed=0)
    batch_fn = lambda s: {k: jnp.asarray(v)                # noqa: E731
                          for k, v in make(s).items()}

    # Higher lambda_n than the paper's 0.1: LM hidden states drift, so a
    # conservative (better-calibrated) generator keeps the Eq. 5
    # correction bounded (DESIGN.md §7). First fit is full; later
    # refreshes warm-start from the in-state tree (DESIGN.md §3).
    base_fit = make_gen_fit_fn(
        cfg, batch_fn, kind=args.head, fit_config=FitConfig(reg=1.0),
        max_tokens=16_384, n_batches=32)
    fit_log = []

    def gen_fit(st):
        t0 = time.perf_counter()
        head = base_fit(st)
        fit_log.append(time.perf_counter() - t0)
        return head

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # gen_refresh re-fits the tree periodically: LM hidden states DRIFT
        # during training (unlike the paper's fixed features), and a stale
        # generator degrades both negatives and the Eq. 5 correction.
        refresh = args.gen_refresh or max(steps // 3, 1)
        warmup = min(p["gen_warmup"], max(steps // 4, 1))
        # Async needs the swap to precede the next submit; with a 1-step
        # refresh period there is no room, so fall back to blocking.
        use_async = args.gen_async and refresh > 1
        loop = LoopConfig(total_steps=steps, checkpoint_every=max(steps //
                                                                  4, 1),
                          checkpoint_dir=ckpt_dir,
                          gen_warmup_steps=warmup,
                          gen_refresh_steps=refresh,
                          gen_async=use_async,
                          gen_swap_delay=(min(args.gen_swap_delay,
                                              refresh - 1)
                                          if use_async else 0))
        gen_cb = gen_fit if args.head in ("adversarial_ns", "nce",
                                          "sampled_softmax",
                                          "freq_ns") else None
        mode = "async" if use_async else "blocking"
        print(f"generator refresh: every {refresh} steps ({mode})")
        state, hist = run_loop(
            state, train_step, batch_fn, loop, jax.random.PRNGKey(1),
            gen_fit_fn=gen_cb,
            on_step=lambda s, m: (s % 10 == 0) and print(
                f"  step {s:4d} loss={m['loss']:.4f} "
                f"({m['step_time']*1e3:.0f} ms)"))
        print(f"stragglers flagged: {hist['stragglers']}")
        times = hist.get("step_times", [])
        if times:
            tail = times[len(times) // 2:]       # skip compile/warmup half
            print(f"step time ({head_update} head update): "
                  f"{1e3 * sum(tail) / len(tail):.1f} ms "
                  f"(median-half mean over {len(tail)} steps)")
        if fit_log:
            print(f"generator fits: {len(fit_log)} "
                  f"(first {fit_log[0]*1e3:.0f} ms full, refresh "
                  f"{[f'{t*1e3:.0f}' for t in fit_log[1:]]} ms warm)")
        for key in ("gen_submit_steps", "gen_swap_steps"):
            if key in hist:
                print(f"{key}: {hist[key]}")

        ev = eval_step(state, batch_fn(99_999))
        print(f"eval (debiased): loglik={float(ev['eval_loglik']):.4f} "
              f"acc={float(ev['eval_acc']):.4f}")
        first = sum(hist["loss"][:5]) / 5
        last = sum(hist["loss"][-5:]) / 5
        print(f"loss {first:.4f} -> {last:.4f}")
        assert last < first, "training must reduce the loss"
        print("OK")


if __name__ == "__main__":
    main()
