"""End-to-end LM training driver with the adversarial softmax head.

Pipeline: synthetic clustered-bigram token stream -> decoder LM ->
generator warmup fit (tree on a frozen hidden-state snapshot) ->
adversarial-NS training with checkpoints + straggler monitor ->
debiased eval (Eq. 5).

Profiles:
  demo  (default) — ~1M params, 60 steps, runs in ~1 min on CPU
  100m           — ~100M params (d=768, 12L), a few hundred steps; the
                   same code pjits onto the production mesh via --arch
                   configs in repro.launch.train for cluster runs.

Run:  PYTHONPATH=src python examples/train_lm.py [--profile demo|100m]
      [--head adversarial_ns|softmax|uniform_ns|...]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.core.tree_fit import FitConfig
from repro.data import lm_batch_fn
from repro.models import lm_head
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig
from repro.train import (LoopConfig, init_train_state, make_eval_step,
                         make_train_step, run_loop)
from repro.train.generator_fit import fit_lm_generator

PROFILES = {
    "demo": dict(num_layers=2, d_model=128, d_ff=384, vocab_size=2048,
                 num_heads=4, num_kv_heads=2, seq=64, batch=8, steps=150,
                 gen_warmup=60),
    "100m": dict(num_layers=12, d_model=768, d_ff=2304, vocab_size=32_768,
                 num_heads=12, num_kv_heads=4, seq=512, batch=8, steps=300,
                 gen_warmup=50),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="demo", choices=PROFILES)
    ap.add_argument("--head", default="adversarial_ns")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    p = PROFILES[args.profile]
    steps = args.steps or p["steps"]

    cfg = ModelConfig(
        name=f"lm-{args.profile}", num_layers=p["num_layers"],
        d_model=p["d_model"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        vocab_pad_multiple=128, gen_feature_dim=16, dtype="float32",
        remat=False)
    print(f"model: {cfg.param_count()/1e6:.1f}M params, head={args.head}")

    hcfg = lm_head.head_config(cfg, args.head, n_neg=1, reg=1e-4)
    opt = OptimizerConfig(name="adagrad", learning_rate=0.05, clip_norm=1.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, args.head)
    train_step = jax.jit(make_train_step(cfg, hcfg, opt))
    eval_step = jax.jit(make_eval_step(cfg, hcfg))

    make = lm_batch_fn(cfg.vocab_size, p["batch"], p["seq"], seed=0)
    batch_fn = lambda s: {k: jnp.asarray(v)                # noqa: E731
                          for k, v in make(s).items()}

    def gen_fit(st):
        print("  [generator] fitting tree on frozen snapshot ...")
        return fit_lm_generator(
            st.params, cfg, (make(10_000 + i) for i in range(32)),
            kind=args.head, fit_config=FitConfig(reg=1.0),
            max_tokens=16_384)   # higher lambda_n than the paper's 0.1:
        # LM hidden states drift, so a conservative (better-calibrated)
        # generator keeps the Eq. 5 correction bounded (DESIGN.md §7).

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # gen_refresh re-fits the tree periodically: LM hidden states DRIFT
        # during training (unlike the paper's fixed features), and a stale
        # generator degrades both negatives and the Eq. 5 correction.
        loop = LoopConfig(total_steps=steps, checkpoint_every=max(steps //
                                                                  4, 1),
                          checkpoint_dir=ckpt_dir,
                          gen_warmup_steps=p["gen_warmup"],
                          gen_refresh_steps=max(steps // 3, 1))
        gen_cb = gen_fit if args.head in ("adversarial_ns", "nce",
                                          "sampled_softmax",
                                          "freq_ns") else None
        state, hist = run_loop(
            state, train_step, batch_fn, loop, jax.random.PRNGKey(1),
            gen_fit_fn=gen_cb,
            on_step=lambda s, m: (s % 10 == 0) and print(
                f"  step {s:4d} loss={m['loss']:.4f} "
                f"({m['step_time']*1e3:.0f} ms)"))
        print(f"stragglers flagged: {hist['stragglers']}")

        ev = eval_step(state, batch_fn(99_999))
        print(f"eval (debiased): loglik={float(ev['eval_loglik']):.4f} "
              f"acc={float(ev['eval_acc']):.4f}")
        first = sum(hist["loss"][:5]) / 5
        last = sum(hist["loss"][-5:]) / 5
        print(f"loss {first:.4f} -> {last:.4f}")
        assert last < first, "training must reduce the loss"
        print("OK")


if __name__ == "__main__":
    main()
