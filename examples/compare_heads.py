"""Paper Figure 1 analog: all heads race on clustered extreme classification.

Follows the paper's §5 protocol: each head's learning rate is tuned on a
validation split (Adagrad, Table 1 style), then trained for an equal step
budget; we report test accuracy + predictive log-likelihood. Expected
ordering (the paper's result): adversarial_ns leads the sampled heads and
approaches full softmax; NCE pays for re-learning the base distribution;
uniform NS trails.

Run:  PYTHONPATH=src python examples/compare_heads.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import heads as heads_lib
from repro.core.heads import Generator
from repro.core.tree_fit import FitConfig, fit_tree, pca_projection
from repro.core.xc_train import tune_and_train
from repro.data.synthetic import ClusteredXCSpec, make_clustered_xc

HEADS = ["adversarial_ns", "uniform_ns", "freq_ns", "nce",
         "sampled_softmax", "ove", "augment_reduce", "softmax"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--labels", type=int, default=2048)
    ap.add_argument("--heads", nargs="*", default=HEADS)
    args = ap.parse_args()

    c, kdim, k_gen = args.labels, 64, 8
    spec = ClusteredXCSpec(num_labels=c, feature_dim=kdim, seed=0)
    x_tr, y_tr, x_te, y_te = make_clustered_xc(spec, 42_000, 3_000)
    x_tr, x_val = x_tr[:40_000], x_tr[40_000:]
    y_tr, y_val = y_tr[:40_000], y_tr[40_000:]

    t0 = time.time()
    proj, mean = pca_projection(x_tr, k_gen)
    tree = fit_tree((x_tr - mean) @ proj, y_tr, c,
                    config=FitConfig(reg=0.1, seed=0))
    tree_fit_s = time.time() - t0

    def j(a, dt=None):
        return jnp.asarray(a) if dt is None else jnp.asarray(a, dt)

    x, y = j(x_tr), j(y_tr, jnp.int32)
    xg = j((x_tr - mean) @ proj, jnp.float32)
    xv, yv = j(x_val), j(y_val, jnp.int32)
    xgv = j((x_val - mean) @ proj, jnp.float32)
    xte, yte = j(x_te), j(y_te, jnp.int32)
    xgte = j((x_te - mean) @ proj, jnp.float32)
    counts = jnp.bincount(y, length=c).astype(jnp.float32)

    print(f"C={c} K={kdim} N={len(y_tr)} steps={args.steps} "
          f"(tree fit: {tree_fit_s:.1f}s; lr tuned per head, paper §5)")
    print(f"{'head':16s} {'lr*':>6s} {'train_s':>8s} {'test acc':>9s} "
          f"{'loglik':>8s}")
    results = {}
    for kind in args.heads:
        gen = Generator()
        if kind in ("adversarial_ns", "nce", "sampled_softmax"):
            gen = Generator(tree=tree)
        elif kind == "freq_ns":
            gen = heads_lib.make_freq_generator(counts)
        t0 = time.time()
        cfg, params, lr = tune_and_train(
            kind, gen, c, x, xg, y, xv, xgv, yv, steps=args.steps)
        dt = time.time() - t0
        acc = float(heads_lib.predictive_accuracy(cfg, params, gen, xte,
                                                  xgte, yte))
        ll = float(heads_lib.predictive_log_likelihood(cfg, params, gen,
                                                       xte, xgte, yte))
        results[kind] = acc
        print(f"{kind:16s} {lr:6.2f} {dt:8.1f} {acc:9.3f} {ll:8.3f}")

    if {"adversarial_ns", "uniform_ns"} <= results.keys():
        assert results["adversarial_ns"] > results["uniform_ns"], \
            "paper claim: adversarial > uniform at equal budget"
        print("OK: adversarial negative sampling leads the sampled heads.")


if __name__ == "__main__":
    main()
