"""Quickstart: the paper, end to end, in under a minute on CPU.

Reproduces the paper's pipeline (§2.2 steps 1-3) on a synthetic clustered
extreme-classification set (the regime of §2.2's 'Boston Terrier vs French
Bulldog' argument):

  1. fit the probabilistic decision tree to the data (paper §3);
  2. train a linear classifier with adversarial negative sampling (Eq. 6);
  3. predict with bias removal (Eq. 5) and compare against uniform negative
     sampling trained for the same number of steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heads as heads_lib
from repro.core.heads import Generator, HeadConfig
from repro.core.tree_fit import FitConfig, fit_tree, pca_projection
from repro.data.synthetic import ClusteredXCSpec, make_clustered_xc


def train(kind, x, y, xg, gen, c, kdim, steps=400, lr=0.5, seed=0):
    cfg = HeadConfig(num_labels=c, kind=kind, n_neg=1, reg=1e-4)
    params = heads_lib.init_head_params(jax.random.PRNGKey(seed), c, kdim)

    @jax.jit
    def step(params, key):
        def lf(p):
            return heads_lib.head_loss(cfg, p, gen, x, xg, y, key)[0]
        loss, grads = jax.value_and_grad(lf)(params)
        return jax.tree.map(lambda p, g: p - lr * g, params, grads), loss

    key = jax.random.PRNGKey(seed + 1)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        params, loss = step(params, sub)
    return cfg, params


def main():
    c, kdim, k_gen = 256, 64, 8
    spec = ClusteredXCSpec(num_labels=c, feature_dim=kdim, seed=0)
    x_tr, y_tr, x_te, y_te = make_clustered_xc(spec, 8000, 2000)

    # -- Step 1: fit the adversarial generator tree (paper §3) --
    t0 = time.time()
    proj, mean = pca_projection(x_tr, k_gen)
    xg_tr = (x_tr - mean) @ proj
    xg_te = (x_te - mean) @ proj
    tree = fit_tree(xg_tr, y_tr, c, config=FitConfig(reg=0.1, seed=0))
    print(f"[1] tree fitted in {time.time() - t0:.1f}s "
          f"(C={c}, k={k_gen}, depth={tree.depth})")

    xj = jnp.asarray(x_tr)
    yj = jnp.asarray(y_tr, jnp.int32)
    xgj = jnp.asarray(xg_tr, jnp.float32)

    # -- Step 2: adversarial negative sampling (Eq. 6) --
    gen_tree = Generator(tree=tree)
    cfg_adv, p_adv = train("adversarial_ns", xj, yj, xgj, gen_tree, c, kdim)

    # Baseline: uniform negative sampling (Eq. 2), same budget.
    cfg_uni, p_uni = train("uniform_ns", xj, yj, xgj, Generator(), c, kdim)

    # -- Step 3: predictions with bias removal (Eq. 5) --
    xte, yte = jnp.asarray(x_te), jnp.asarray(y_te, jnp.int32)
    xgte = jnp.asarray(xg_te, jnp.float32)
    for name, cfg, p, g in [("adversarial+debias", cfg_adv, p_adv, gen_tree),
                            ("uniform", cfg_uni, p_uni, Generator())]:
        acc = heads_lib.predictive_accuracy(cfg, p, g, xte, xgte, yte)
        ll = heads_lib.predictive_log_likelihood(cfg, p, g, xte, xgte, yte)
        print(f"[3] {name:20s} test acc={float(acc):.3f} "
              f"loglik={float(ll):.3f}")

    acc_adv = float(heads_lib.predictive_accuracy(
        cfg_adv, p_adv, gen_tree, xte, xgte, yte))
    acc_uni = float(heads_lib.predictive_accuracy(
        cfg_uni, p_uni, Generator(), xte, xgte, yte))
    assert acc_adv > acc_uni, "adversarial should beat uniform (paper Fig 1)"
    print("OK: adversarial negative sampling beats uniform at equal steps.")


if __name__ == "__main__":
    main()
