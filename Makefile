# Tier-1 verification and benchmarks, one command each.
#
#   make test         - full suite (what the roadmap calls tier-1 verify)
#   make test-fast    - skip @pytest.mark.slow (subprocess launcher tests,
#                       odd-page-geometry oracle sweeps) and the chaos
#                       suite (@pytest.mark.resilience)
#   make test-serve   - serving-engine suite only (@pytest.mark.serve)
#   make test-resilience - chaos suite only (@pytest.mark.resilience,
#                       DESIGN.md §13): fault-injection schedules, the
#                       train/genfit/serve degradation ladders, and the
#                       kill-mid-checkpoint resume tests
#   make bench-serve  - dense vs beam serving latency sweep over C
#   make bench-engine - continuous-batching engine under Poisson traffic
#                       (writes BENCH_engine.json: throughput, p50/p99,
#                       paged-vs-monolithic concurrency at equal bytes,
#                       plus the adversarial multi-tenant section)
#   make bench-engine-adversarial - ONLY the adversarial multi-tenant
#                       traffic (shared-prefix bursts, heavy-tail SLA
#                       mix): COW sharing concurrency, speculative
#                       accept rate, FIFO-vs-SLA interactive p99; fast,
#                       never writes BENCH_engine.json
#   make bench-engine-faults - ONLY the resilience section (DESIGN.md
#                       §13): degraded-mode serving under an injected
#                       fault schedule — shed/deadline/poison status
#                       mix, leak check, ok-p99 vs fault-free; fast,
#                       never writes BENCH_engine.json
#   make bench-tree-fit - generator fitting at scale: sequential oracle vs
#                       level-parallel vs warm-start refresh + held-out
#                       log-likelihood (writes BENCH_tree_fit.json)
#   make bench-heads  - head TRAIN-step cost vs C: dense O(C·K) autodiff
#                       update vs sparse O(B·K·n_neg) touched-row update,
#                       plus the head-state memory sweep — prints the
#                       bytes/label table (adamw/adagrad/sm3 × fp32/bf16,
#                       DESIGN.md §11) and writes BENCH_heads.json with
#                       state_bytes columns up to C=16M
#   make bench-snr    - gradient-SNR table for every fitted NegativeSampler
#                       (tree/uniform/unigram/lsh/rff) + the same-objective
#                       convergence race (writes BENCH_snr.json)
#   make bench-smoke  - CI guard: one tiny C per benchmark, schema
#                       asserted, no timings (benchmark scripts can't rot)
#   make obs-demo     - CI guard for the repro.obs pipeline: a tiny
#                       instrumented train run whose JSONL event log,
#                       registry snapshot, and exporters are all asserted
#                       (DESIGN.md §10)
#   make bench        - the full benchmark harness CSV

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-serve test-resilience bench-serve \
        bench-engine bench-engine-adversarial bench-engine-faults \
        bench-tree-fit bench-heads bench-snr bench-smoke obs-demo bench

test:
	$(PYTHON) -m pytest -x -q

test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow and not resilience"

test-serve:
	$(PYTHON) -m pytest -x -q -m serve

test-resilience:
	$(PYTHON) -m pytest -x -q -m "resilience and not slow"

bench-serve:
	$(PYTHON) -m benchmarks.bench_serve

bench-engine:
	$(PYTHON) -m benchmarks.bench_engine

bench-engine-adversarial:
	$(PYTHON) -m benchmarks.bench_engine --traffic adversarial

bench-engine-faults:
	$(PYTHON) -m benchmarks.bench_engine --faults

bench-tree-fit:
	$(PYTHON) -m benchmarks.bench_tree_fit

bench-heads:
	$(PYTHON) -m benchmarks.bench_heads

bench-snr:
	$(PYTHON) -m benchmarks.bench_snr

bench-smoke:
	$(PYTHON) -m benchmarks.smoke

obs-demo:
	$(PYTHON) -m benchmarks.obs_demo

bench:
	$(PYTHON) -m benchmarks.run
