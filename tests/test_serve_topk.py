"""Sublinear top-k serving: beam search over the generator tree + candidate
re-scoring with Eq. 5 debiasing (the --topk-beam decode path)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heads as heads_lib
from repro.core import tree as tree_lib
from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.train import make_prefill, make_serve_step

CS = [7, 64, 1000]


def _tree(seed, c, k, scale=0.7):
    return tree_lib.init_tree(jax.random.PRNGKey(seed), c, k, scale=scale)


class TestBeamSearch:
    @pytest.mark.parametrize("c", CS)
    @pytest.mark.parametrize("beam", [4, 32, None])
    def test_top1_matches_dense_argmax(self, c, beam):
        """Beam top-1 == argmax(log_prob_all); None means exhaustive beam."""
        k = 6
        if beam is None:
            beam = tree_lib.padded_size(c)
        t = _tree(c, c, k)
        x = jax.random.normal(jax.random.PRNGKey(c + 1), (8, k))
        labels, logp = jax.jit(functools.partial(
            tree_lib.beam_search, beam=beam, topk=1))(t, x)
        dense = tree_lib.log_prob_all(t, x)
        np.testing.assert_array_equal(np.asarray(labels[:, 0]),
                                      np.asarray(jnp.argmax(dense, -1)))
        np.testing.assert_allclose(np.asarray(logp[:, 0]),
                                   np.asarray(jnp.max(dense, -1)),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("c", CS)
    def test_full_beam_topk_is_exact(self, c):
        """Exhaustive beam == dense sort, values and labels."""
        k, topk = 5, min(5, c)
        t = _tree(c + 10, c, k, scale=1.2)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, k))
        labels, logp = tree_lib.beam_search(t, x, tree_lib.padded_size(c),
                                            topk)
        dense = tree_lib.log_prob_all(t, x)
        ref_v, ref_l = jax.lax.top_k(dense, topk)
        np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_l))
        np.testing.assert_allclose(np.asarray(logp), np.asarray(ref_v),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("c", [5, 7, 13, 1000])
    @pytest.mark.parametrize("beam", [8, 64])
    def test_no_padding_labels_in_candidates(self, c, beam):
        """Padded leaves (c < C_pad) must never surface as candidates."""
        k = 4
        t = _tree(3 * c, c, k, scale=2.0)
        x = jax.random.normal(jax.random.PRNGKey(3), (16, k))
        labels, logp = tree_lib.beam_search(t, x, beam, min(beam, c))
        labels = np.asarray(labels)
        logp = np.asarray(logp)
        live = np.isfinite(logp)
        assert (labels[live] >= 0).all() and (labels[live] < c).all()
        # Dead slots are explicitly label -1, never an aliased real label.
        assert (labels[~live] == -1).all()

    def test_beam_logp_consistent_with_log_prob(self):
        """Returned log-probs equal log_prob() of the returned labels."""
        c, k = 64, 6
        t = _tree(5, c, k)
        x = jax.random.normal(jax.random.PRNGKey(6), (8, k))
        labels, logp = tree_lib.beam_search(t, x, 16, 4)
        xb = jnp.broadcast_to(x[:, None, :], labels.shape + (k,))
        ref = tree_lib.log_prob(t, xb, labels)
        np.testing.assert_allclose(np.asarray(logp), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_batched_shapes(self):
        """Arbitrary leading batch dims flow through."""
        c, k = 64, 4
        t = _tree(7, c, k)
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 3, k))
        labels, logp = tree_lib.beam_search(t, x, 8, 4)
        assert labels.shape == (2, 3, 4)
        assert logp.shape == (2, 3, 4)


class TestPredictiveTopk:
    def _setup(self, c, seed=0, kk=6, dim=12, debias=True):
        t = _tree(seed, c, kk, scale=0.8)
        cfg = heads_lib.HeadConfig(num_labels=c, kind="adversarial_ns",
                                   debias=debias)
        gen = heads_lib.make_tree_generator(t)
        ks = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
        params = heads_lib.init_head_params(ks[0], c, dim, scale=0.5)
        h = jax.random.normal(ks[1], (9, dim))
        x_gen = jax.random.normal(ks[2], (9, kk))
        return cfg, params, gen, h, x_gen

    @pytest.mark.parametrize("c", CS)
    @pytest.mark.parametrize("debias", [True, False])
    def test_full_beam_matches_dense_topk(self, c, debias):
        cfg, params, gen, h, x_gen = self._setup(c, seed=c, debias=debias)
        topk = min(5, c)
        dense = heads_lib.predictive_scores(cfg, params, gen, h, x_gen)
        ref_v, ref_l = jax.lax.top_k(dense, topk)
        top, labels = heads_lib.predictive_topk(
            cfg, params, gen, h, x_gen, topk, beam=tree_lib.padded_size(c))
        np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_l))
        np.testing.assert_allclose(np.asarray(top), np.asarray(ref_v),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("beam", [8, 32])
    def test_candidates_are_real_labels(self, beam):
        c = 1000
        cfg, params, gen, h, x_gen = self._setup(c, seed=11)
        top, labels = heads_lib.predictive_topk(cfg, params, gen, h, x_gen,
                                                topk=4, beam=beam)
        labels = np.asarray(labels)
        assert (labels >= 0).all() and (labels < c).all()
        assert np.isfinite(np.asarray(top)).all()

    def test_kernel_score_path_matches(self):
        """gather_scores Pallas kernel path == plain candidate_scores path."""
        cfg, params, gen, h, x_gen = self._setup(64, seed=13)
        ref_v, ref_l = heads_lib.predictive_topk(cfg, params, gen, h, x_gen,
                                                 topk=4, beam=16)
        ker_v, ker_l = heads_lib.predictive_topk(
            cfg, params, gen, h, x_gen, topk=4, beam=16,
            score_fn=heads_lib.kernel_score_fn())
        np.testing.assert_array_equal(np.asarray(ker_l), np.asarray(ref_l))
        np.testing.assert_allclose(np.asarray(ker_v), np.asarray(ref_v),
                                   rtol=1e-5, atol=1e-5)

    def test_topk_wider_than_beam_pads_to_contract(self):
        """topk > beam: output keeps (..., topk) shape, -inf/-1 padding."""
        c = 64
        cfg, params, gen, h, x_gen = self._setup(c, seed=19)
        top, labels = heads_lib.predictive_topk(cfg, params, gen, h, x_gen,
                                                topk=16, beam=8)
        assert top.shape == (9, 16) and labels.shape == (9, 16)
        assert np.isfinite(np.asarray(top[:, :8])).all()
        assert (np.asarray(top[:, 8:]) == -np.inf).all()
        assert (np.asarray(labels[:, 8:]) == -1).all()
        t_labels, t_logp = tree_lib.beam_search(gen.tree, x_gen, 8, 16)
        assert t_labels.shape == (9, 16) and t_logp.shape == (9, 16)
        assert (np.asarray(t_labels[:, 8:]) == -1).all()

    def test_treeless_adversarial_falls_back_to_raw_scores(self):
        """adversarial_ns with no fitted tree serves undebiased dense topk."""
        c = 32
        cfg = heads_lib.HeadConfig(num_labels=c, kind="adversarial_ns")
        gen = heads_lib.Generator()
        ks = jax.random.split(jax.random.PRNGKey(23), 2)
        params = heads_lib.init_head_params(ks[0], c, 8, scale=0.5)
        h = jax.random.normal(ks[1], (5, 8))
        x_gen = jnp.zeros((5, 4))
        ref_v, ref_l = jax.lax.top_k(heads_lib.full_logits(params, h), 3)
        top, labels = heads_lib.predictive_topk(cfg, params, gen, h, x_gen, 3)
        np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_l))
        np.testing.assert_allclose(np.asarray(top), np.asarray(ref_v),
                                   rtol=1e-6)

    def test_non_adversarial_fallback(self):
        """Non-tree heads fall back to dense scoring + top_k."""
        c = 64
        cfg = heads_lib.HeadConfig(num_labels=c, kind="freq_ns")
        gen = heads_lib.make_freq_generator(
            jnp.arange(1, c + 1, dtype=jnp.float32))
        ks = jax.random.split(jax.random.PRNGKey(17), 2)
        params = heads_lib.init_head_params(ks[0], c, 8, scale=0.5)
        h = jax.random.normal(ks[1], (5, 8))
        x_gen = jnp.zeros((5, 4))
        dense = heads_lib.predictive_scores(cfg, params, gen, h, x_gen)
        ref_v, ref_l = jax.lax.top_k(dense, 3)
        top, labels = heads_lib.predictive_topk(cfg, params, gen, h, x_gen, 3)
        np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_l))
        np.testing.assert_allclose(np.asarray(top), np.asarray(ref_v),
                                   rtol=1e-6)


class TestServeStepBeam:
    def _cfg(self):
        return ModelConfig(
            name="topk-test", num_layers=1, d_model=32, d_ff=64,
            vocab_size=100, num_heads=2, num_kv_heads=2,
            vocab_pad_multiple=128, gen_feature_dim=8, dtype="float32",
            remat=False)

    def test_exhaustive_beam_decode_equals_dense_decode(self):
        """make_serve_step(topk_beam=C_pad) reproduces the dense decode."""
        cfg = self._cfg()
        hcfg = lm_head.head_config(cfg, "adversarial_ns")
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        head_state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                                "adversarial_ns")
        batch, prompt_len, gen_tokens = 2, 4, 4
        prompts = jax.random.randint(jax.random.PRNGKey(2),
                                     (batch, prompt_len), 0, cfg.vocab_size)
        prefill = jax.jit(make_prefill(cfg))
        outs = {}
        for name, beam in (("dense", 0), ("beam", 128)):
            step = jax.jit(make_serve_step(cfg, hcfg, topk_beam=beam))
            cache = transformer.init_cache(cfg, batch,
                                           prompt_len + gen_tokens,
                                           dtype=jnp.float32)
            _, cache = prefill(params, prompts, cache)
            token, toks = prompts[:, -1:], []
            for t in range(gen_tokens):
                token, cache = step(params, head_state, token, cache,
                                    jnp.int32(prompt_len + t))
                toks.append(token)
            outs[name] = np.asarray(jnp.concatenate(toks, 1))
        np.testing.assert_array_equal(outs["dense"], outs["beam"])
        assert (outs["beam"] >= 0).all()
        assert (outs["beam"] < cfg.vocab_size).all()

    def test_narrow_beam_decode_stays_in_vocab(self):
        """Even a narrow beam only ever emits real (non-padding) tokens."""
        cfg = self._cfg()
        hcfg = lm_head.head_config(cfg, "adversarial_ns")
        params = transformer.init_params(jax.random.PRNGKey(3), cfg)
        head_state = lm_head.default_head_state(jax.random.PRNGKey(4), cfg,
                                                "adversarial_ns")
        step = jax.jit(make_serve_step(cfg, hcfg, topk_beam=4))
        cache = transformer.init_cache(cfg, 2, 6, dtype=jnp.float32)
        prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 2), 0,
                                     cfg.vocab_size)
        _, cache = jax.jit(make_prefill(cfg))(params, prompts, cache)
        token = prompts[:, -1:]
        for t in range(4):
            token, cache = step(params, head_state, token, cache,
                                jnp.int32(2 + t))
            assert int(token.min()) >= 0
            assert int(token.max()) < cfg.vocab_size
