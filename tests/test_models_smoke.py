"""Per-arch smoke tests (reduced configs): one forward + one train step on
CPU, asserting shapes and finiteness; decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfg_lib
from repro.models import lm_head, specs, transformer
from repro.models.config import ModelConfig

ARCHS = cfg_lib.ARCHS


def _data(cfg: ModelConfig, batch=2, seq=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    kt, kl, kv = jax.random.split(rng, 3)
    out = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (batch, seq), 0, cfg.vocab_size),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.modality == "vision":
        nv = cfg.num_vision_tokens
        out["tokens"] = out["tokens"][:, : seq - nv]
        out["vision_embeds"] = 0.02 * jax.random.normal(
            kv, (batch, nv, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(seq)[None, None],
                               (3, batch, seq)).astype(jnp.int32)
        out["positions"] = pos
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = cfg_lib.reduced_config(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch, seq = 2, 16
    data = _data(cfg, batch, seq)

    h, _, metrics = transformer.forward(
        params, cfg, data["tokens"],
        positions=data.get("positions"),
        vision_embeds=data.get("vision_embeds"))
    assert h.shape == (batch, seq, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    # One adversarial-NS train step: loss finite, grads finite.
    hcfg = lm_head.head_config(cfg, "adversarial_ns", reg=1e-4)
    state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                       "adversarial_ns")

    def loss_fn(p):
        hh, _, _ = transformer.forward(
            p, cfg, data["tokens"], positions=data.get("positions"),
            vision_embeds=data.get("vision_embeds"))
        loss, _ = lm_head.lm_head_loss(
            cfg, hcfg, lm_head.HeadParams(**p["head"]), state, hh,
            data["labels"], jax.random.PRNGKey(2), mask=data["mask"])
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.all(jnp.isfinite(g.astype(jnp.float32))), grads))
    assert all(bool(x) for x in leaves)
    # Head + embedding gradients must be nonzero (technique is wired in).
    assert float(jnp.abs(grads["head"]["w"]).sum()) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Prefill + stepwise decode == full forward on the same tokens."""
    # fp32 (no bf16 roundoff); generous MoE capacity (capacity dropping is
    # batch-size dependent, which would make decode != forward by design).
    cfg = dataclasses.replace(cfg_lib.reduced_config(arch),
                              modality="text", num_vision_tokens=0,
                              mrope_sections=(), dtype="float32",
                              capacity_factor=8.0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch, seq, prompt = 2, 12, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (batch, seq), 0,
                                cfg.vocab_size)

    h_full, _, _ = transformer.forward(params, cfg, tokens)

    cache = transformer.init_cache(cfg, batch, max_len=seq,
                                   dtype=jnp.float32)
    h_pre, cache, _ = transformer.forward(params, cfg, tokens[:, :prompt],
                                          cache=cache,
                                          cache_pos=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(h_pre, np.float32),
                               np.asarray(h_full[:, :prompt], np.float32),
                               rtol=2e-2, atol=2e-2)
    hs = []
    for t in range(prompt, seq):
        h_t, cache, _ = transformer.forward(
            params, cfg, tokens[:, t:t + 1], cache=cache,
            cache_pos=jnp.int32(t))
        hs.append(h_t)
    h_dec = jnp.concatenate(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_dec, np.float32),
                               np.asarray(h_full[:, prompt:], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_config_shapes_without_allocation():
    """Full (non-reduced) configs build abstract params + specs only."""
    for arch in ARCHS:
        cfg = cfg_lib.get_config(arch)
        p = specs.params_specs(cfg)
        n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
        assert n_params > 0
        for shape, cell in cfg_lib.shape_cells(arch).items():
            if cell is None:
                continue
            if cell["mode"] == "train":
                s = specs.train_input_specs(cfg, cell["seq_len"],
                                            cell["global_batch"])
            elif cell["mode"] == "prefill":
                s = specs.prefill_input_specs(cfg, cell["seq_len"],
                                              cell["global_batch"])
            else:
                s = specs.decode_input_specs(cfg, cell["seq_len"],
                                             cell["global_batch"])
            assert s


def test_param_count_sane():
    """param_count() lands within a factor ~2 of the nameplate sizes."""
    expected = {
        "mamba2-370m": 0.37e9, "stablelm-3b": 3e9, "deepseek-7b": 7e9,
        "gemma2-27b": 27e9, "mixtral-8x22b": 141e9,
        "deepseek-moe-16b": 16e9, "hymba-1.5b": 1.5e9,
    }
    for arch, target in expected.items():
        n = cfg_lib.get_config(arch).param_count()
        assert 0.4 * target < n < 2.5 * target, (arch, n, target)
