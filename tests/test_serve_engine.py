"""Continuous-batching engine over the paged KV pool: page-allocator
invariants (property-based), scheduler invariants, byte-identity vs the
lock-step oracle across page geometries, and a fragmentation regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.serve import (CandidateCache, ContinuationStore, Engine,
                         PagedPool, Request, ServeConfig, lockstep_decode)
from repro.serve.traffic import (TrafficConfig, drive, make_heavy_tail_mix,
                                 make_shared_prefix_burst, make_workload)

pytestmark = pytest.mark.serve

CFG = ModelConfig(
    name="engine-test", num_layers=1, d_model=32, d_ff=64, vocab_size=100,
    num_heads=2, num_kv_heads=2, vocab_pad_multiple=128, gen_feature_dim=8,
    dtype="float32", remat=False)
HCFG = lm_head.head_config(CFG, "adversarial_ns")
PARAMS = transformer.init_params(jax.random.PRNGKey(0), CFG)
HEAD_STATE = lm_head.default_head_state(jax.random.PRNGKey(1), CFG,
                                        "adversarial_ns")
MAX_LEN = 12
BEAM = 8
N_SLOTS = 2


_ENGINES = {}


def shared_engine(page_len: int = 0, batched: bool = True,
                  n_pages: int = 0) -> Engine:
    """One shared engine per geometry (jit caches stay warm across tests);
    between runs all lanes/pages are free and the queues empty, so state
    carry-over is only the candidate cache — which never changes outputs,
    only skips work. (A plain helper, not a pytest fixture: the hypothesis
    fallback shim hides fixture params from pytest's resolver.)"""
    key = (page_len, batched, n_pages)
    if key not in _ENGINES:
        _ENGINES[key] = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM, page_len=page_len,
            n_pages=n_pages, batched_prefill=batched,
            cache_dtype=jnp.float32))
    return _ENGINES[key]


def _prompts(rng, n, lo=2, hi=4):
    return [rng.integers(0, CFG.vocab_size,
                         rng.integers(lo, hi + 1)).astype(np.int32)
            for _ in range(n)]


def _lockstep(prompts, gen_tokens, beam):
    """Reference decode: the shared fixed-batch oracle from repro.serve."""
    return lockstep_decode(CFG, HCFG, PARAMS, HEAD_STATE, prompts,
                           gen_tokens, topk_beam=beam)


# ---------------------------------------------------------------------------
# Page allocator: hypothesis property suite
# ---------------------------------------------------------------------------

def _fresh_pool(n_lanes=3, n_pages=8, page_len=3, max_len=9):
    return PagedPool(CFG, n_lanes, n_pages, page_len, max_len,
                     dtype=jnp.float32)


def _drive_allocator(pool, seed, n_ops):
    """Random alloc/release interleaving; returns the live lane->pages map
    mirror kept independently of the pool's own bookkeeping."""
    rng = np.random.default_rng(seed)
    live = {}
    for _ in range(n_ops):
        if live and (rng.random() < 0.5 or not pool.num_free_lanes):
            lane = list(live)[rng.integers(0, len(live))]
            got = pool.release(lane)
            assert sorted(got) == sorted(live.pop(lane)), \
                "release must reclaim exactly the request's pages"
        else:
            need = int(rng.integers(1, pool.max_pages + 1))
            expect = pool.can_admit(need)
            out = pool.alloc(need)
            assert (out is not None) == expect, \
                "alloc must succeed exactly when can_admit says so"
            if out is not None:
                lane, pages = out
                assert len(pages) == need
                live[lane] = pages
        pool.check_invariants()
    return live


class TestPageAllocator:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**20), n_ops=st.integers(1, 40))
    def test_free_and_mapped_partition_pages(self, seed, n_ops):
        """After ANY interleaving: free + mapped pages partition
        range(n_pages) and no page is double-mapped across live lanes
        (check_invariants asserts both at every step)."""
        pool = _fresh_pool()
        live = _drive_allocator(pool, seed, n_ops)
        mapped = {p for pages in live.values() for p in pages}
        assert len(mapped) == sum(len(v) for v in live.values())
        assert pool.num_mapped_pages == len(mapped)
        assert pool.num_free_pages == pool.n_pages - len(mapped)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**20), n_ops=st.integers(1, 40))
    def test_drained_pool_is_indistinguishable_from_fresh(self, seed,
                                                          n_ops):
        """Any interleaving that ends with every request retired leaves
        allocator state identical to a fresh pool's (sets of free pages/
        lanes; page tables all-sink)."""
        pool = _fresh_pool()
        live = _drive_allocator(pool, seed, n_ops)
        for lane in list(live):
            pool.release(lane)
        fresh = _fresh_pool()
        assert set(pool._free_pages) == set(fresh._free_pages)
        assert set(pool._free_lanes) == set(fresh._free_lanes)
        assert pool._pages_of == {}
        np.testing.assert_array_equal(pool.page_table, fresh.page_table)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**20), n_lanes=st.integers(1, 4),
           page_len=st.sampled_from([1, 2, 3, 5, 9]))
    def test_alloc_never_exceeds_capacity(self, seed, n_lanes, page_len):
        """Greedy allocation saturates at exactly min(lane, page) capacity;
        the pool never over-grants and page tables never alias."""
        max_len = 9
        n_pages = max(-(-max_len // page_len), 5)
        pool = PagedPool(CFG, n_lanes, n_pages, page_len, max_len,
                         dtype=jnp.float32)
        rng = np.random.default_rng(seed)
        granted = 0
        while True:
            need = int(rng.integers(1, pool.max_pages + 1))
            out = pool.alloc(need)
            if out is None:
                assert (pool.num_free_lanes == 0
                        or pool.num_free_pages < need)
                break
            granted += len(out[1])
            pool.check_invariants()
        assert granted == pool.num_mapped_pages <= n_pages

    @settings(max_examples=25, deadline=None)
    @given(total_len=st.integers(1, 9), page_len=st.sampled_from([1, 2, 3,
                                                                  4, 9]))
    def test_pages_needed_covers_exactly(self, total_len, page_len):
        """pages_needed is the minimal page count covering total_len."""
        pool = PagedPool(CFG, 2, 12, page_len, 9, dtype=jnp.float32)
        need = pool.pages_needed(total_len)
        assert need * page_len >= total_len
        assert (need - 1) * page_len < total_len

    def test_double_release_and_bad_lane_rejected(self):
        pool = _fresh_pool()
        lane, pages = pool.alloc(2)
        assert pool.release(lane) == pages
        with pytest.raises(AssertionError):    # double release
            pool.release(lane)
        with pytest.raises(AssertionError):    # never-allocated lane
            pool.release(pool.n_lanes - 1)

    def test_sink_page_outside_allocator_range(self):
        """The sink page is a physical arena row the allocator never hands
        out — free lanes' garbage writes cannot alias a live mapping."""
        pool = _fresh_pool(n_pages=4)
        assert pool.sink == 4
        assert pool.cache["k"].shape[1] == 5      # n_pages + sink
        seen = set()
        while pool.can_admit(1):
            seen.update(pool.alloc(1)[1])
        assert pool.sink not in seen
        assert (pool.page_table <= pool.sink).all()


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

class TestSchedulerInvariants:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**20), n=st.integers(1, 6),
           gen=st.integers(1, 4), use_eos=st.sampled_from([False, True]))
    def test_every_request_retires_exactly_once(self, seed, n, gen,
                                                use_eos):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(seed)
        completed_before = len(engine.completed)
        handles = [engine.submit(Request(
            prompt=p, max_new_tokens=gen,
            eos_id=int(rng.integers(0, CFG.vocab_size)) if use_eos
            else None)) for p in _prompts(rng, n)]
        order_before = list(engine.admission_order)
        engine.run()

        # Every admitted request retired exactly once.
        new_completed = list(engine.completed)[completed_before:]
        assert sorted(h.request_id for h in new_completed) == \
            sorted(h.request_id for h in handles)
        for h in handles:
            assert h.done and h.finished_at is not None
            assert 1 <= len(h.tokens) <= gen
            if len(h.tokens) < gen:     # early retirement must be EOS
                assert h.eos_hit
            assert all(0 <= t < CFG.vocab_size for t in h.tokens)

        # No lane or page leaked or double-assigned.
        engine.pool.check_invariants()
        assert engine.pool.num_free_lanes == N_SLOTS
        assert engine.pool.num_mapped_pages == 0
        assert engine.num_active == 0 and engine.num_pending == 0

        # FIFO admission fairness: admitted in submission order.
        new_order = list(engine.admission_order)[len(order_before):]
        assert new_order == [h.request_id for h in handles]


# ---------------------------------------------------------------------------
# Byte-identity oracle across page geometries
# ---------------------------------------------------------------------------

class TestGeometryOracle:
    """Engine output must be byte-identical to the lock-step decode for
    EVERY page geometry: paging changes physical addressing only, never
    the positions the softmax sees."""

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**20))
    def test_byte_identical_to_lockstep_beam(self, seed):
        """Engine (2 lanes, mixed admission, page_len 3) == lock-step batch
        decode, token for token, for the same seed/prompts."""
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(seed)
        b, pl, gen = 3, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, BEAM)
        handles = [engine.submit(Request(prompt=p, max_new_tokens=gen))
                   for p in prompts]
        engine.run()
        out = np.stack([h.result() for h in handles])
        np.testing.assert_array_equal(out, ref)

    @pytest.mark.parametrize("page_len", [1, MAX_LEN])
    @pytest.mark.parametrize("batched", [True, False])
    def test_geometry_sweep_beam(self, page_len, batched):
        self._run_geometry(page_len, batched, beam=BEAM)

    @pytest.mark.slow
    @pytest.mark.parametrize("page_len", [3, 7])
    @pytest.mark.parametrize("batched", [True, False])
    def test_geometry_sweep_beam_odd_pages(self, page_len, batched):
        self._run_geometry(page_len, batched, beam=BEAM)

    def _run_geometry(self, page_len, batched, beam):
        rng = np.random.default_rng(1000 * page_len + batched)
        b, gen = 4, 3
        prompts = _prompts(rng, b, lo=2, hi=5)
        refs = [
            _lockstep(p[None], gen, beam)[0] for p in prompts]
        engine = shared_engine(page_len=page_len, batched=batched)
        handles = [engine.submit(Request(prompt=p, max_new_tokens=gen))
                   for p in prompts]
        engine.run()
        for h, ref in zip(handles, refs):
            np.testing.assert_array_equal(h.result(), ref)
        engine.pool.check_invariants()

    def test_byte_identical_to_lockstep_dense(self):
        rng = np.random.default_rng(7)
        b, pl, gen = 3, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, 0)
        for page_len in (1, 3, MAX_LEN):
            eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
                n_slots=2, max_len=MAX_LEN, beam=0, page_len=page_len,
                cache_dtype=jnp.float32))
            handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                       for p in prompts]
            eng.run()
            np.testing.assert_array_equal(
                np.stack([h.result() for h in handles]), ref)

    def test_batched_prefill_one_launch_for_burst(self):
        """A burst admitted together prefills in ONE padded call (vs one
        per request sequentially) and still matches the oracle."""
        rng = np.random.default_rng(41)
        gen = 2
        prompts = _prompts(rng, N_SLOTS, lo=2, hi=4)
        refs = [_lockstep(p[None], gen, BEAM)[0] for p in prompts]
        for batched, expect_calls in ((True, 1), (False, N_SLOTS)):
            eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
                n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM, page_len=3,
                batched_prefill=batched, cache_dtype=jnp.float32))
            handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                       for p in prompts]
            eng.step()          # single admission round for the burst
            assert eng.prefill_calls == expect_calls
            eng.run()
            for h, ref in zip(handles, refs):
                np.testing.assert_array_equal(h.result(), ref)


# ---------------------------------------------------------------------------
# Fragmentation / undersized-pool regression
# ---------------------------------------------------------------------------

class TestFragmentation:
    def test_half_size_paged_pool_serves_mixed_trace(self):
        """Poisson traffic of mixed lengths through a paged pool sized to
        ~half the monolithic pool's bytes: the whole trace completes (no
        deadlock), occupancy never exceeds n_pages, and outputs still
        match the oracle."""
        page_len = 3
        # Monolithic bytes: N_SLOTS * MAX_LEN positions. Half, in pages:
        n_pages = (N_SLOTS * MAX_LEN // 2) // page_len          # 4 pages
        assert n_pages * page_len * 2 == N_SLOTS * MAX_LEN
        tcfg = TrafficConfig(
            n_requests=12, rate=500.0, prompt_len=4, gen_tokens=2,
            prompt_len_choices=(2, 3, 4), gen_tokens_choices=(1, 2, 3),
            vocab_size=CFG.vocab_size, seed=5)
        workload = make_workload(tcfg)
        engine = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM, page_len=page_len,
            n_pages=n_pages, cache_dtype=jnp.float32))
        res = drive(engine, workload, time_scale=0.0)
        assert res["n_requests"] == tcfg.n_requests
        stats = engine.stats()
        assert stats["completed"] >= tcfg.n_requests
        assert 0 < stats["peak_pages_in_use"] <= n_pages
        assert stats["pages_in_use"] == 0       # drained
        engine.pool.check_invariants()
        # Byte-identity survives the undersized pool.
        for h in list(engine.completed)[-tcfg.n_requests:]:
            ref = _lockstep(h.request.prompt[None],
                            h.request.max_new_tokens, BEAM)[0]
            np.testing.assert_array_equal(h.result(), ref)

    def test_internal_fragmentation_reported(self):
        """stats() fragmentation: mapped-but-unwritten positions over
        mapped bytes, in (0, 1) while a short request holds a long page."""
        engine = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=1, max_len=MAX_LEN, beam=0, page_len=MAX_LEN,
            cache_dtype=jnp.float32))
        rng = np.random.default_rng(43)
        prompt = rng.integers(0, CFG.vocab_size, 2).astype(np.int32)
        engine.submit(Request(prompt=prompt, max_new_tokens=6))
        engine.step()       # admitted: 2-3 positions used of a 12-page
        frag = engine.stats()["internal_fragmentation"]
        assert 0.0 < frag < 1.0
        engine.run()
        assert engine.stats()["internal_fragmentation"] == 0.0


# ---------------------------------------------------------------------------
# Candidate cache on the paged path
# ---------------------------------------------------------------------------

class TestCandidateCachePath:
    def test_repeat_prefix_hits_and_identical_outputs(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        h1 = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        engine.run()
        skips_before = engine.descent_skips
        hits_before = engine.candidate_cache.hits
        h2 = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        engine.run()
        assert h2.tokens == h1.tokens
        assert engine.candidate_cache.hits > hits_before
        assert engine.descent_skips > skips_before

    def test_head_swap_invalidates_cached_candidates(self):
        """A generator/head refresh must not serve candidates descended
        under the old tree: swap_head_state bumps the cache version, so
        the same prompt re-descends (no descent skip) and the outputs
        match an engine built with the new head state from scratch."""
        new_head = lm_head.default_head_state(jax.random.PRNGKey(2), CFG,
                                              "adversarial_ns")

        def fresh(head_state):
            return Engine(CFG, HCFG, PARAMS, head_state, ServeConfig(
                n_slots=1, max_len=MAX_LEN, beam=BEAM, page_len=3,
                cache_dtype=jnp.float32))

        rng = np.random.default_rng(19)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        eng = fresh(HEAD_STATE)
        eng.submit(Request(prompt=prompt, max_new_tokens=4))
        eng.run()
        # Sanity: with no swap the repeat skips descents via the cache.
        skips0 = eng.descent_skips
        eng.submit(Request(prompt=prompt, max_new_tokens=4))
        eng.run()
        assert eng.descent_skips > skips0

        eng.swap_head_state(new_head)
        skips1 = eng.descent_skips
        h = eng.submit(Request(prompt=prompt, max_new_tokens=4))
        eng.run()
        # Old entries are unreachable: every step re-descended.
        assert eng.descent_skips == skips1
        stats = eng.candidate_cache.stats()
        assert stats["version"] == 1 and stats["invalidations"] == 1
        # And the decode is what the new head produces, not a stale mix.
        ref_eng = fresh(new_head)
        ref = ref_eng.submit(Request(prompt=prompt, max_new_tokens=4))
        ref_eng.run()
        assert h.tokens == ref.tokens

    def test_cache_disabled_engine_matches(self):
        rng = np.random.default_rng(13)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        outs = []
        for use_cache in (True, False):
            eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
                n_slots=1, max_len=MAX_LEN, beam=BEAM, page_len=3,
                use_candidate_cache=use_cache, cache_dtype=jnp.float32))
            h = eng.submit(Request(prompt=prompt, max_new_tokens=4))
            h2 = eng.submit(Request(prompt=prompt, max_new_tokens=4))
            eng.run()
            outs.append((h.tokens, h2.tokens))
            assert (eng.candidate_cache is not None) == use_cache
        assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Retirement
# ---------------------------------------------------------------------------

class TestRetirement:
    def test_per_request_max_new_tokens(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(17)
        prompts = _prompts(rng, 3)
        lens = [1, 3, 2]
        handles = [engine.submit(Request(prompt=p, max_new_tokens=g))
                   for p, g in zip(prompts, lens)]
        engine.run()
        assert [len(h.tokens) for h in handles] == lens

    def test_eos_stops_early_and_frees_lane_and_pages(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(19)
        prompt = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        h_ref = engine.submit(Request(prompt=prompt, max_new_tokens=5))
        engine.run()
        assert len(h_ref.tokens) == 5
        eos = h_ref.tokens[2]
        first = h_ref.tokens.index(eos)      # eos may repeat earlier
        h = engine.submit(Request(prompt=prompt, max_new_tokens=5,
                                  eos_id=eos))
        engine.run()
        assert h.eos_hit and len(h.tokens) == first + 1
        assert h.tokens == h_ref.tokens[:first + 1]
        assert engine.pool.num_free_lanes == N_SLOTS
        assert engine.pool.num_mapped_pages == 0

    def test_oversized_request_rejected(self):
        engine = shared_engine(page_len=3)
        prompt = np.zeros((MAX_LEN,), np.int32)
        with pytest.raises(ValueError):
            engine.submit(Request(prompt=prompt, max_new_tokens=1))

    def test_zero_budget_request_rejected(self):
        """The engine always decodes >= 1 token; a zero budget would write
        one position past the request's page reservation."""
        engine = shared_engine(page_len=3)
        with pytest.raises(ValueError):
            engine.submit(Request(prompt=np.zeros((2,), np.int32),
                                  max_new_tokens=0))

    def test_streaming_matches_result(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(23)
        prompt = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        h = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        streamed = list(engine.stream(h))
        assert streamed == list(h.result())


# ---------------------------------------------------------------------------
# Pool / cache / traffic units
# ---------------------------------------------------------------------------

class TestPagedPoolUnit:
    def test_arena_shape(self):
        pool = PagedPool(CFG, 4, 6, 4, 16, dtype=jnp.float32)
        # +1 physical page: the sink.
        assert pool.cache["k"].shape == (
            CFG.num_layers, 7, 4, CFG.num_kv_heads, CFG.resolved_head_dim)
        assert pool.max_pages == 4
        assert pool.page_table.shape == (4, 4)

    def test_lifo_reuse(self):
        pool = _fresh_pool()
        lane, pages = pool.alloc(2)
        pool.release(lane)
        lane2, pages2 = pool.alloc(2)
        assert lane2 == lane                 # LIFO lane reuse
        assert pages2 == pages[::-1]         # LIFO page reuse

    def test_pool_too_small_for_max_len_rejected(self):
        with pytest.raises(AssertionError):
            PagedPool(CFG, 2, 2, 3, MAX_LEN, dtype=jnp.float32)


class TestCandidateCacheUnit:
    def test_lru_eviction_and_stats(self):
        cc = CandidateCache(capacity=2)
        c = np.arange(4, dtype=np.int32)
        lp = np.zeros(4, np.float32)
        cc.put((1,), c, lp)
        cc.put((2,), c, lp)
        assert cc.get((1,)) is not None      # (1,) now most-recent
        cc.put((3,), c, lp)                  # evicts (2,)
        assert cc.get((2,)) is None
        assert cc.get((3,)) is not None
        assert cc.evictions == 1
        assert cc.stats()["hits"] == 2 and cc.stats()["misses"] == 1

    def test_hit_returns_stored_arrays(self):
        cc = CandidateCache(capacity=4)
        c = np.array([5, 7, -1], np.int32)
        lp = np.array([-0.5, -1.5, -np.inf], np.float32)
        cc.put((0, 1, 2), c, lp)
        got_c, got_lp = cc.get((0, 1, 2))
        np.testing.assert_array_equal(got_c, c)
        np.testing.assert_array_equal(got_lp, lp)


class TestTraffic:
    def test_workload_shapes_and_repeats(self):
        tcfg = TrafficConfig(n_requests=32, rate=100.0, prompt_len=5,
                             gen_tokens=3, vocab_size=50, repeat_frac=0.5,
                             n_shared_prompts=1, seed=3)
        wl = make_workload(tcfg)
        assert len(wl) == 32
        arrivals = [t for t, _ in wl]
        assert arrivals == sorted(arrivals) and arrivals[0] == 0.0
        keys = {tuple(r.prompt.tolist()) for _, r in wl}
        assert len(keys) < 32               # shared prompts actually repeat
        for _, r in wl:
            assert r.prompt.shape == (5,) and r.max_new_tokens == 3

    def test_mixed_length_workload(self):
        tcfg = TrafficConfig(n_requests=64, rate=100.0, prompt_len=8,
                             gen_tokens=4, prompt_len_choices=(2, 5, 8),
                             gen_tokens_choices=(1, 4), vocab_size=50,
                             seed=9)
        wl = make_workload(tcfg)
        assert {r.prompt.shape[0] for _, r in wl} == {2, 5, 8}
        assert {r.max_new_tokens for _, r in wl} == {1, 4}


class TestSSMEngine:
    @pytest.mark.slow
    def test_ssm_engine_matches_oracle_mixed_lengths(self):
        """SSM models through the paged engine: recurrent state is NOT
        position-local, so batched prefill must group by exact prompt
        length instead of length-padding (padding tokens would keep
        updating the carried state). Mixed lengths — including one shorter
        than the conv window, the seed bug the left-pad in
        ssm.ssm_block's prefill conv_state fixes — must match the
        per-request oracle byte for byte."""
        import dataclasses
        from repro import configs as cfg_lib
        cfg = dataclasses.replace(cfg_lib.reduced_config("mamba2-370m"),
                                  dtype="float32", remat=False)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        hs = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                        "adversarial_ns")
        hcfg = lm_head.head_config(cfg, "adversarial_ns")
        rng = np.random.default_rng(3)
        # 2 < ssm_conv_width - 1: the short-prompt conv-state case.
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (5, 3, 5, 2)]
        refs = [lockstep_decode(cfg, hcfg, params, hs, p[None], 3,
                                topk_beam=0)[0] for p in prompts]
        eng = Engine(cfg, hcfg, params, hs, ServeConfig(
            n_slots=4, max_len=12, beam=0, page_len=3,
            cache_dtype=jnp.float32))
        # Pure-SSM has no K/V arena: the requested page geometry is pinned
        # to one nominal page per lane so pages never gate admission.
        assert eng.pool.page_len == 12 and eng.pool.n_pages == 4
        handles = [eng.submit(Request(prompt=p, max_new_tokens=3))
                   for p in prompts]
        eng.run()
        for h, ref in zip(handles, refs):
            np.testing.assert_array_equal(h.result(), ref)
        # One prefill launch per distinct prompt length in the burst —
        # but the FIFO audit trail stays in submission order.
        assert eng.prefill_calls == 3
        assert list(eng.admission_order) == [h.request_id for h in handles]
        eng.pool.check_invariants()


class TestMeshScoring:
    def test_sharded_score_fn_matches_plain(self):
        """make_serve_step(mesh=...) (1-shard mesh here; multi-shard runs in
        test_parallel's subprocess launcher test) == plain scoring."""
        from repro.parallel import AxisType, make_mesh
        mesh = make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
        rng = np.random.default_rng(29)
        b, pl, gen = 2, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, BEAM)
        sharded = lockstep_decode(CFG, HCFG, PARAMS, HEAD_STATE, prompts,
                                  gen, topk_beam=BEAM, mesh=mesh)
        np.testing.assert_array_equal(sharded, ref)

    def test_engine_mesh_paged_arena_matches(self):
        """Engine(mesh=...) with the paged arena device_put through
        paged_cache_shardings still reproduces the oracle."""
        from repro.parallel import AxisType, make_mesh
        mesh = make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
        rng = np.random.default_rng(31)
        prompts = rng.integers(0, CFG.vocab_size, (2, 3)).astype(np.int32)
        ref = _lockstep(prompts, 3, BEAM)
        eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=2, max_len=MAX_LEN, beam=BEAM, page_len=3, mesh=mesh,
            cache_dtype=jnp.float32))
        handles = [eng.submit(Request(prompt=p, max_new_tokens=3))
                   for p in prompts]
        eng.run()
        np.testing.assert_array_equal(
            np.stack([h.result() for h in handles]), ref)


# ---------------------------------------------------------------------------
# Refcounted / shared pages (PR 9): hypothesis property suite
# ---------------------------------------------------------------------------

def _fill_arena(pool, seed):
    """Overwrite the K/V arena with recognizable random bytes so byte-
    identity checks on page ops are meaningful."""
    rng = np.random.default_rng(seed)
    cache = dict(pool.cache)
    for key in ("k", "v"):
        cache[key] = jnp.asarray(
            rng.normal(size=cache[key].shape).astype(np.float32))
    pool.cache = cache


def _drive_shared_allocator(pool, seed, n_ops):
    """Random interleaving of alloc / alloc_shared / cow / release /
    register / unregister / cached-revival, mirroring refcounts
    independently of the pool's bookkeeping."""
    rng = np.random.default_rng(seed)
    live = {}                       # lane -> pages in logical order
    for _ in range(n_ops):
        r = rng.random()
        if live and r < 0.25:
            lane = sorted(live)[rng.integers(0, len(live))]
            got = pool.release(lane)
            assert sorted(got) == sorted(live.pop(lane)), \
                "release must unref exactly the lane's pages"
        elif live and r < 0.40:
            # Share a live lane's prefix (+ maybe private pages).
            donor = sorted(live)[rng.integers(0, len(live))]
            pages = pool.lane_pages(donor)
            k = int(rng.integers(1, len(pages) + 1))
            npriv = int(rng.integers(0, pool.max_pages - k + 1))
            out = pool.alloc_shared(pages[:k], npriv)
            if out is not None:
                lane, priv = out
                live[lane] = pages[:k] + priv
        elif live and r < 0.50:
            # COW a genuinely-shared page (rc > 1) when a copy target
            # exists.
            cands = [(lane, i) for lane, pages in live.items()
                     for i, p in enumerate(pages) if pool.refcount(p) > 1]
            if cands and pool.num_free_pages:
                lane, i = cands[rng.integers(0, len(cands))]
                live[lane][i] = pool.cow(lane, i)
        elif live and r < 0.60:
            lane = sorted(live)[rng.integers(0, len(live))]
            pages = live[lane]
            pool.register(pages[:int(rng.integers(1, len(pages) + 1))])
        elif r < 0.68:
            regs = sorted(pool._registered)
            if regs:
                pool.unregister([regs[rng.integers(0, len(regs))]])
        elif pool._cached and r < 0.76:
            # Revive cached (rc == 0, bytes pinned) pages into a new lane.
            cached = sorted(pool._cached)
            k = int(rng.integers(1, min(len(cached), pool.max_pages) + 1))
            out = pool.alloc_shared(cached[:k], 0)
            if out is not None:
                lane, _ = out
                live[lane] = cached[:k]
        else:
            need = int(rng.integers(1, pool.max_pages + 1))
            expect = pool.can_admit(need)
            out = pool.alloc(need)
            assert (out is not None) == expect
            if out is not None:
                lane, pages = out
                live[lane] = pages
        pool.check_invariants()
        # Refcount == number of mapping lanes, for every page.
        counts = {}
        for pages in live.values():
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        for p in range(pool.n_pages):
            assert pool.refcount(p) == counts.get(p, 0)
            # rc == 0  <=>  free-list or cached (never both, never lost).
            in_free = p in pool._free_pages
            assert (pool.refcount(p) == 0) == (in_free or pool.is_cached(p))
            assert not (in_free and pool.is_cached(p))
    return live


class TestRefcountedPool:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**20), n_ops=st.integers(1, 50))
    def test_sharing_preserves_partition_and_refcounts(self, seed, n_ops):
        pool = _fresh_pool(n_lanes=4, n_pages=8, page_len=3, max_len=9)
        _drive_shared_allocator(pool, seed, n_ops)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**20), n_ops=st.integers(1, 50))
    def test_drained_unregistered_pool_matches_fresh(self, seed, n_ops):
        """Release every lane, drop every registration: the pool must be
        indistinguishable from fresh (no page leaked through sharing,
        caching, or COW)."""
        pool = _fresh_pool(n_lanes=4, n_pages=8, page_len=3, max_len=9)
        live = _drive_shared_allocator(pool, seed, n_ops)
        for lane in list(live):
            pool.release(lane)
        pool.unregister(sorted(pool._registered))
        fresh = _fresh_pool(n_lanes=4, n_pages=8, page_len=3, max_len=9)
        assert set(pool._free_pages) == set(fresh._free_pages)
        assert len(pool._free_pages) == pool.n_pages     # no double-free
        assert set(pool._free_lanes) == set(fresh._free_lanes)
        assert pool._pages_of == {} and pool._refcount == {}
        assert pool._cached == set()
        np.testing.assert_array_equal(pool.page_table, fresh.page_table)

    def test_cached_lifecycle_and_eviction_accounting(self):
        """register → release parks pages as cached (not free); cached
        pages satisfy can_admit_evicting but not can_admit; retain revives
        them; unregister frees them."""
        pool = _fresh_pool(n_lanes=2, n_pages=4, page_len=3, max_len=9)
        lane, pages = pool.alloc(3)
        pool.register(pages[:2])
        pool.release(lane)
        assert pool.num_cached_pages == 2 and pool.num_free_pages == 2
        assert not pool.can_admit(3) and pool.can_admit_evicting(3)
        # Revive one cached page into a new lane without touching bytes.
        lane2, _ = pool.alloc_shared(pages[:1], 1)
        assert pool.refcount(pages[0]) == 1
        assert not pool.is_cached(pages[0])
        pool.release(lane2)
        pool.unregister(pages[:2])
        assert pool.num_cached_pages == 0
        assert pool.num_free_pages == pool.n_pages
        pool.check_invariants()

    def test_retain_of_free_page_rejected(self):
        pool = _fresh_pool()
        free_page = pool._free_pages[-1]
        with pytest.raises(AssertionError):
            pool.retain(free_page)

    def test_cow_copies_bytes_and_preserves_donor(self):
        """COW gives the caller a private byte-identical page; the donor
        lane keeps its mapping and the refcounts split 2 -> 1 + 1."""
        pool = _fresh_pool()
        _fill_arena(pool, seed=3)
        lane_a, pages = pool.alloc(2)
        lane_b, priv = pool.alloc_shared(pages, 0)
        assert priv == [] and pool.refcount(pages[1]) == 2
        src = pages[1]
        before = {k: np.asarray(pool.cache[k][:, src]) for k in ("k", "v")}
        new = pool.cow(lane_b, 1)
        assert new != src
        assert pool.lane_pages(lane_a) == pages          # donor untouched
        assert pool.lane_pages(lane_b) == [pages[0], new]
        assert pool.refcount(src) == 1 and pool.refcount(new) == 1
        for k in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(pool.cache[k][:, new]), before[k])
        pool.check_invariants()

    def test_spill_restore_byte_identity(self):
        """spill → clobber the arena → restore reproduces the lane's pages
        byte for byte in freshly-allocated pages."""
        pool = _fresh_pool(n_lanes=2, n_pages=6, page_len=3, max_len=9)
        _fill_arena(pool, seed=5)
        lane, pages = pool.alloc(3)
        idx = np.asarray(pages)
        expect = {k: np.asarray(pool.cache[k][:, idx]) for k in ("k", "v")}
        img = pool.spill(lane)
        assert img.n_pages == 3 and img.nbytes() > 0
        for k in ("k", "v"):
            np.testing.assert_array_equal(img.pages[k], expect[k])
        pool.release(lane)
        pool.cache = {k: jnp.zeros_like(v) for k, v in pool.cache.items()}
        lane2, pages2 = pool.restore(img)
        for k in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(pool.cache[k][:, np.asarray(pages2)]),
                expect[k])
        pool.check_invariants()


# ---------------------------------------------------------------------------
# Prefix sharing: byte-identity, COW tails, trie eviction
# ---------------------------------------------------------------------------

def _mt_engine(**kw):
    base = dict(n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM, page_len=3,
                n_pages=8, cache_dtype=jnp.float32)
    base.update(kw)
    return Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(**base))


class TestPrefixSharing:
    def test_shared_template_byte_identity_and_hits(self):
        """Requests sharing a 6-token template through 2 lanes: later
        admissions map the template's pages instead of re-prefilling, and
        every output still matches the per-request oracle."""
        rng = np.random.default_rng(101)
        template = rng.integers(0, CFG.vocab_size, 6).astype(np.int32)
        prompts = [
            template.copy(),
            np.concatenate([template,
                            rng.integers(0, CFG.vocab_size, 2)]),
            np.concatenate([template,
                            rng.integers(0, CFG.vocab_size, 3)]),
            template.copy(),
        ]
        prompts = [np.asarray(p, np.int32) for p in prompts]
        gen = 3
        refs = [_lockstep(p[None], gen, BEAM)[0] for p in prompts]
        eng = _mt_engine(prefix_sharing=True)
        handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                   for p in prompts]
        eng.run()
        for h, ref in zip(handles, refs):
            np.testing.assert_array_equal(h.result(), ref)
        st = eng.stats()
        assert st["prefix"]["hits"] >= 1
        assert st["prefix"]["pages_reused"] >= 1
        assert st["prefix"]["prefill_tokens_saved"] > 0
        # Cached prefix pages are accounted apart from live ones.
        assert st["pages_in_use"] == 0 and st["pages_cached"] > 0
        eng.pool.check_invariants()

    def test_exact_repeat_takes_cow_tail(self):
        """An exact prompt repeat (5 tokens = 1 full chunk + 2-token tail
        at page_len 3) revives the cached chunk AND COWs the partial tail
        page — zero prefill — and still matches the oracle."""
        rng = np.random.default_rng(103)
        prompt = rng.integers(0, CFG.vocab_size, 5).astype(np.int32)
        gen = 3
        ref = _lockstep(prompt[None], gen, BEAM)[0]
        eng = _mt_engine(prefix_sharing=True)
        h1 = eng.submit(Request(prompt=prompt, max_new_tokens=gen))
        eng.run()
        h2 = eng.submit(Request(prompt=prompt.copy(), max_new_tokens=gen))
        eng.run()
        np.testing.assert_array_equal(h1.result(), ref)
        np.testing.assert_array_equal(h2.result(), ref)
        st = eng.stats()["prefix"]
        assert st["cow_copies"] >= 1 and st["hits"] >= 1
        assert st["prefill_tokens_saved"] >= prompt.size
        eng.pool.check_invariants()

    def test_trie_eviction_under_page_pressure(self):
        """A pool too small to cache every retired prefix must evict LRU
        trie entries to admit new prompts — and keep serving correctly."""
        rng = np.random.default_rng(107)
        gen = 3
        eng = _mt_engine(prefix_sharing=True, n_slots=1, n_pages=4)
        for _ in range(3):
            p = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
            ref = _lockstep(p[None], gen, BEAM)[0]
            h = eng.submit(Request(prompt=p, max_new_tokens=gen))
            eng.run()
            np.testing.assert_array_equal(h.result(), ref)
        assert eng.stats()["prefix"]["evictions"] >= 1
        eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Speculative decode: byte-identity over draft lengths x geometries
# ---------------------------------------------------------------------------

class TestSpeculativeDecode:
    def _run_twice(self, eng, prompts, refs, gen):
        """Cold pass then warm pass (replay drafts live) — byte-identical
        both times."""
        for _ in range(2):
            handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                       for p in prompts]
            eng.run()
            for h, ref in zip(handles, refs):
                np.testing.assert_array_equal(h.result(), ref)

    @pytest.mark.parametrize("max_draft,page_len", [(1, 3), (4, 3)])
    def test_byte_identity_drafts_x_geometry(self, max_draft, page_len,
                                             n_pages=8):
        rng = np.random.default_rng(113 + max_draft)
        gen = 4
        prompts = _prompts(rng, 3, lo=2, hi=5)
        refs = [_lockstep(p[None], gen, BEAM)[0] for p in prompts]
        eng = _mt_engine(spec_decode=True, max_draft=max_draft,
                         page_len=page_len, n_pages=n_pages)
        self._run_twice(eng, prompts, refs, gen)
        st = eng.stats()["spec"]
        assert st["verify_steps"] > 0
        assert st["drafts_accepted"] > 0        # warm pass replayed
        assert st["mean_emitted_per_step"] > 1.0
        eng.pool.check_invariants()

    @pytest.mark.slow
    @pytest.mark.parametrize("max_draft,page_len", [(3, 1), (2, 7)])
    def test_byte_identity_odd_geometries(self, max_draft, page_len):
        # n_pages=0: full per-lane reservation (page_len=1 needs 12/lane).
        self.test_byte_identity_drafts_x_geometry(max_draft, page_len,
                                                  n_pages=0)

    def test_dense_head_spec_identity(self):
        rng = np.random.default_rng(127)
        gen = 4
        prompts = _prompts(rng, 2, lo=3, hi=4)
        refs = [_lockstep(p[None], gen, 0)[0] for p in prompts]
        eng = _mt_engine(spec_decode=True, max_draft=3, beam=0)
        self._run_twice(eng, prompts, refs, gen)

    def test_sharing_and_spec_together(self):
        """Both tentpole features on at once (the production shape):
        repeats share pages AND replay whole draft chains."""
        rng = np.random.default_rng(129)
        prompt = rng.integers(0, CFG.vocab_size, 6).astype(np.int32)
        gen = 4
        ref = _lockstep(prompt[None], gen, BEAM)[0]
        eng = _mt_engine(prefix_sharing=True, spec_decode=True, max_draft=3)
        for _ in range(2):
            h = eng.submit(Request(prompt=prompt.copy(),
                                   max_new_tokens=gen))
            eng.run()
            np.testing.assert_array_equal(h.result(), ref)
        st = eng.stats()
        assert st["prefix"]["hits"] >= 1
        assert st["spec"]["drafts_accepted"] > 0
        eng.pool.check_invariants()


class TestContinuationStore:
    def test_chain_lru_and_version(self):
        cs = ContinuationStore(capacity=3)
        cs.put((1, 2), 3)
        cs.put((1, 2, 3), 4)
        cs.put((1, 2, 3, 4), 5)
        assert cs.chain((1, 2), 5) == [3, 4, 5]     # walk caps at stored
        cs.put((9,), 9)                             # evicts LRU (1, 2)
        assert cs.get((1, 2)) is None
        assert len(cs._map) == 3

    def test_bump_version_orphans_stale_entries(self):
        """A head-state swap must make every recorded continuation
        unreachable — the tree that produced them no longer serves."""
        cs = ContinuationStore(capacity=8)
        cs.put((1, 2), 3)
        assert cs.get((1, 2)) == 3
        cs.bump_version()
        assert cs.get((1, 2)) is None
        cs.put((1, 2), 7)                  # new-version entry is reachable
        assert cs.chain((1, 2), 2) == [7]

    def test_ctx_window_bounds_key_length(self):
        from repro.serve.spec import CTX_WINDOW
        cs = ContinuationStore(capacity=4)
        long_ctx = tuple(range(CTX_WINDOW + 50))
        cs.put(long_ctx, 1)
        # Any context agreeing on the trailing window hits the same entry.
        assert cs.get((99,) * 7 + long_ctx[-CTX_WINDOW:]) == 1


# ---------------------------------------------------------------------------
# SLA scheduling: priority classes, preemption, on-demand growth
# ---------------------------------------------------------------------------

class TestSlaScheduling:
    def test_priority_class_admitted_before_fifo_order(self):
        """With one lane, a later-submitted higher class is admitted
        first; outputs are unaffected (scheduling is work order only)."""
        rng = np.random.default_rng(131)
        pa = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        pb = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        refs = [_lockstep(p[None], 2, BEAM)[0] for p in (pa, pb)]
        eng = _mt_engine(n_slots=1)
        lo = eng.submit(Request(prompt=pa, max_new_tokens=2, priority=0))
        hi = eng.submit(Request(prompt=pb, max_new_tokens=2, priority=5))
        eng.run()
        assert list(eng.admission_order) == [hi.request_id, lo.request_id]
        np.testing.assert_array_equal(lo.result(), refs[0])
        np.testing.assert_array_equal(hi.result(), refs[1])

    def test_preemption_spill_restore_byte_identity(self):
        """A low-class whale holding most of the pool is spilled for a
        high-class arrival and restored after — BOTH outputs byte-match
        the oracle (restore is exact, not a re-prefill)."""
        rng = np.random.default_rng(137)
        whale_p = rng.integers(0, CFG.vocab_size, 5).astype(np.int32)
        quick_p = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        ref_w = _lockstep(whale_p[None], 7, BEAM)[0]
        ref_q = _lockstep(quick_p[None], 3, BEAM)[0]
        eng = _mt_engine(n_pages=6, preemption=True)
        hw = eng.submit(Request(prompt=whale_p, max_new_tokens=7,
                                priority=0))
        eng.step()                  # whale admitted: reserves 4 of 6 pages
        hq = eng.submit(Request(prompt=quick_p, max_new_tokens=3,
                                priority=1))
        eng.run()
        np.testing.assert_array_equal(hw.result(), ref_w)
        np.testing.assert_array_equal(hq.result(), ref_q)
        st = eng.stats()["sched"]
        assert st["preemptions"] >= 1 and st["restores"] >= 1
        assert hw.preempted >= 1 and hq.preempted == 0
        eng.pool.check_invariants()
        assert eng.pool.num_in_use == 0

    def test_ondemand_growth_packs_more_lanes(self):
        """Two requests whose worst-case reservations exceed the pool:
        "reserve" serializes them, "ondemand" runs both concurrently
        (growing at page boundaries, spilling itself if the pool fills) —
        same bytes out either way."""
        rng = np.random.default_rng(139)
        prompts = [rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
                   for _ in range(2)]
        gen = 3
        refs = [_lockstep(p[None], gen, BEAM)[0] for p in prompts]
        active_after_admit = {}
        for growth in ("reserve", "ondemand"):
            eng = _mt_engine(n_pages=4, page_growth=growth)
            handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                       for p in prompts]
            eng.step()
            active_after_admit[growth] = eng.num_active
            eng.run()
            for h, ref in zip(handles, refs):
                np.testing.assert_array_equal(h.result(), ref)
            if growth == "ondemand":
                assert eng.stats()["sched"]["page_grows"] >= 1
            eng.pool.check_invariants()
        # The packing claim: same pool, same traffic, more concurrency.
        assert active_after_admit["reserve"] == 1
        assert active_after_admit["ondemand"] == 2

    def test_reserved_unwritten_pages_reported(self):
        """stats() splits reserved-but-unwritten pages from pages_in_use:
        a freshly-admitted request under worst-case reservation holds
        whole pages it has not written into yet."""
        eng = _mt_engine(n_slots=1)
        rng = np.random.default_rng(141)
        prompt = rng.integers(0, CFG.vocab_size, 2).astype(np.int32)
        eng.submit(Request(prompt=prompt, max_new_tokens=7))
        eng.step()      # admitted: 3 pages reserved, 1 written (2 tokens)
        st = eng.stats()
        assert st["pages_in_use"] == 3
        assert st["pages_reserved_unwritten"] == 2
        eng.run()
        assert eng.stats()["pages_reserved_unwritten"] == 0

    def test_deadline_miss_counted(self):
        eng = _mt_engine(n_slots=1)
        rng = np.random.default_rng(143)
        prompt = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        h = eng.submit(Request(prompt=prompt, max_new_tokens=2,
                               deadline_s=1e-9))
        eng.run()
        assert h.done
        assert eng.stats()["sched"]["deadline_misses"] >= 1


# ---------------------------------------------------------------------------
# Adversarial traffic generators
# ---------------------------------------------------------------------------

class TestAdversarialTraffic:
    def test_shared_prefix_burst_shape(self):
        tcfg = TrafficConfig(
            n_requests=40, rate=100.0, gen_tokens=2, vocab_size=50,
            n_templates=4, template_len=6, suffix_len=2,
            exact_repeat_frac=0.3, burst=4, interactive_frac=0.5,
            interactive_priority=2, seed=11)
        wl = make_shared_prefix_burst(tcfg)
        assert len(wl) == 40
        arrivals = [t for t, _ in wl]
        assert arrivals == sorted(arrivals) and arrivals[0] == 0.0
        # Bursty: arrival instants repeat `burst` at a time.
        assert len(set(arrivals)) <= len(wl) // tcfg.burst + 1
        lens = {r.prompt.shape[0] for _, r in wl}
        assert lens == {6, 8}               # template | template + suffix
        # Zipf templates actually repeat, including exact prompt repeats.
        keys = [tuple(r.prompt.tolist()) for _, r in wl]
        assert len(set(keys)) < len(keys)
        assert {r.priority for _, r in wl} == {0, 2}

    def test_heavy_tail_mix_shape(self):
        tcfg = TrafficConfig(
            n_requests=64, rate=100.0, prompt_len=3, gen_tokens=2,
            prompt_len_choices=(3, 5, 8), gen_tokens_choices=(2, 4),
            vocab_size=50, interactive_frac=0.6, interactive_priority=1,
            interactive_deadline_s=0.5, tail_alpha=1.2, seed=13)
        wl = make_heavy_tail_mix(tcfg)
        assert len(wl) == 64
        pris = {r.priority for _, r in wl}
        assert pris == {0, 1}
        for _, r in wl:
            if r.priority == 1:             # interactive probe
                assert r.prompt.shape[0] == 3 and r.max_new_tokens == 2
                assert r.deadline_s == 0.5
            else:                           # batch job from the buckets
                assert r.prompt.shape[0] in (3, 5, 8)
                assert r.max_new_tokens in (2, 4)
                assert r.deadline_s is None

    def test_drive_reports_per_class_latency(self):
        tcfg = TrafficConfig(
            n_requests=8, rate=500.0, prompt_len=3, gen_tokens=2,
            prompt_len_choices=(2, 3), gen_tokens_choices=(1, 2),
            vocab_size=CFG.vocab_size, interactive_frac=0.5,
            interactive_priority=1, seed=17)
        wl = make_heavy_tail_mix(tcfg)
        eng = _mt_engine()
        res = drive(eng, wl, time_scale=0.0)
        assert res["n_requests"] == 8
        classes = res["per_class"]
        assert set(classes) == {r.priority for _, r in wl}
        for snap in classes.values():
            assert snap["n"] >= 1
            assert snap["latency_p99_ms"] >= snap["latency_p50_ms"] >= 0
        assert sum(s["n"] for s in classes.values()) == 8
