"""Continuous-batching engine over the paged KV pool: page-allocator
invariants (property-based), scheduler invariants, byte-identity vs the
lock-step oracle across page geometries, and a fragmentation regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.serve import (CandidateCache, Engine, PagedPool, Request,
                         ServeConfig, lockstep_decode)
from repro.serve.traffic import TrafficConfig, drive, make_workload

pytestmark = pytest.mark.serve

CFG = ModelConfig(
    name="engine-test", num_layers=1, d_model=32, d_ff=64, vocab_size=100,
    num_heads=2, num_kv_heads=2, vocab_pad_multiple=128, gen_feature_dim=8,
    dtype="float32", remat=False)
HCFG = lm_head.head_config(CFG, "adversarial_ns")
PARAMS = transformer.init_params(jax.random.PRNGKey(0), CFG)
HEAD_STATE = lm_head.default_head_state(jax.random.PRNGKey(1), CFG,
                                        "adversarial_ns")
MAX_LEN = 12
BEAM = 8
N_SLOTS = 2


_ENGINES = {}


def shared_engine(page_len: int = 0, batched: bool = True,
                  n_pages: int = 0) -> Engine:
    """One shared engine per geometry (jit caches stay warm across tests);
    between runs all lanes/pages are free and the queues empty, so state
    carry-over is only the candidate cache — which never changes outputs,
    only skips work. (A plain helper, not a pytest fixture: the hypothesis
    fallback shim hides fixture params from pytest's resolver.)"""
    key = (page_len, batched, n_pages)
    if key not in _ENGINES:
        _ENGINES[key] = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM, page_len=page_len,
            n_pages=n_pages, batched_prefill=batched,
            cache_dtype=jnp.float32))
    return _ENGINES[key]


def _prompts(rng, n, lo=2, hi=4):
    return [rng.integers(0, CFG.vocab_size,
                         rng.integers(lo, hi + 1)).astype(np.int32)
            for _ in range(n)]


def _lockstep(prompts, gen_tokens, beam):
    """Reference decode: the shared fixed-batch oracle from repro.serve."""
    return lockstep_decode(CFG, HCFG, PARAMS, HEAD_STATE, prompts,
                           gen_tokens, topk_beam=beam)


# ---------------------------------------------------------------------------
# Page allocator: hypothesis property suite
# ---------------------------------------------------------------------------

def _fresh_pool(n_lanes=3, n_pages=8, page_len=3, max_len=9):
    return PagedPool(CFG, n_lanes, n_pages, page_len, max_len,
                     dtype=jnp.float32)


def _drive_allocator(pool, seed, n_ops):
    """Random alloc/release interleaving; returns the live lane->pages map
    mirror kept independently of the pool's own bookkeeping."""
    rng = np.random.default_rng(seed)
    live = {}
    for _ in range(n_ops):
        if live and (rng.random() < 0.5 or not pool.num_free_lanes):
            lane = list(live)[rng.integers(0, len(live))]
            got = pool.release(lane)
            assert sorted(got) == sorted(live.pop(lane)), \
                "release must reclaim exactly the request's pages"
        else:
            need = int(rng.integers(1, pool.max_pages + 1))
            expect = pool.can_admit(need)
            out = pool.alloc(need)
            assert (out is not None) == expect, \
                "alloc must succeed exactly when can_admit says so"
            if out is not None:
                lane, pages = out
                assert len(pages) == need
                live[lane] = pages
        pool.check_invariants()
    return live


class TestPageAllocator:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**20), n_ops=st.integers(1, 40))
    def test_free_and_mapped_partition_pages(self, seed, n_ops):
        """After ANY interleaving: free + mapped pages partition
        range(n_pages) and no page is double-mapped across live lanes
        (check_invariants asserts both at every step)."""
        pool = _fresh_pool()
        live = _drive_allocator(pool, seed, n_ops)
        mapped = {p for pages in live.values() for p in pages}
        assert len(mapped) == sum(len(v) for v in live.values())
        assert pool.num_mapped_pages == len(mapped)
        assert pool.num_free_pages == pool.n_pages - len(mapped)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**20), n_ops=st.integers(1, 40))
    def test_drained_pool_is_indistinguishable_from_fresh(self, seed,
                                                          n_ops):
        """Any interleaving that ends with every request retired leaves
        allocator state identical to a fresh pool's (sets of free pages/
        lanes; page tables all-sink)."""
        pool = _fresh_pool()
        live = _drive_allocator(pool, seed, n_ops)
        for lane in list(live):
            pool.release(lane)
        fresh = _fresh_pool()
        assert set(pool._free_pages) == set(fresh._free_pages)
        assert set(pool._free_lanes) == set(fresh._free_lanes)
        assert pool._pages_of == {}
        np.testing.assert_array_equal(pool.page_table, fresh.page_table)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**20), n_lanes=st.integers(1, 4),
           page_len=st.sampled_from([1, 2, 3, 5, 9]))
    def test_alloc_never_exceeds_capacity(self, seed, n_lanes, page_len):
        """Greedy allocation saturates at exactly min(lane, page) capacity;
        the pool never over-grants and page tables never alias."""
        max_len = 9
        n_pages = max(-(-max_len // page_len), 5)
        pool = PagedPool(CFG, n_lanes, n_pages, page_len, max_len,
                         dtype=jnp.float32)
        rng = np.random.default_rng(seed)
        granted = 0
        while True:
            need = int(rng.integers(1, pool.max_pages + 1))
            out = pool.alloc(need)
            if out is None:
                assert (pool.num_free_lanes == 0
                        or pool.num_free_pages < need)
                break
            granted += len(out[1])
            pool.check_invariants()
        assert granted == pool.num_mapped_pages <= n_pages

    @settings(max_examples=25, deadline=None)
    @given(total_len=st.integers(1, 9), page_len=st.sampled_from([1, 2, 3,
                                                                  4, 9]))
    def test_pages_needed_covers_exactly(self, total_len, page_len):
        """pages_needed is the minimal page count covering total_len."""
        pool = PagedPool(CFG, 2, 12, page_len, 9, dtype=jnp.float32)
        need = pool.pages_needed(total_len)
        assert need * page_len >= total_len
        assert (need - 1) * page_len < total_len

    def test_double_release_and_bad_lane_rejected(self):
        pool = _fresh_pool()
        lane, pages = pool.alloc(2)
        assert pool.release(lane) == pages
        with pytest.raises(AssertionError):    # double release
            pool.release(lane)
        with pytest.raises(AssertionError):    # never-allocated lane
            pool.release(pool.n_lanes - 1)

    def test_sink_page_outside_allocator_range(self):
        """The sink page is a physical arena row the allocator never hands
        out — free lanes' garbage writes cannot alias a live mapping."""
        pool = _fresh_pool(n_pages=4)
        assert pool.sink == 4
        assert pool.cache["k"].shape[1] == 5      # n_pages + sink
        seen = set()
        while pool.can_admit(1):
            seen.update(pool.alloc(1)[1])
        assert pool.sink not in seen
        assert (pool.page_table <= pool.sink).all()


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

class TestSchedulerInvariants:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**20), n=st.integers(1, 6),
           gen=st.integers(1, 4), use_eos=st.sampled_from([False, True]))
    def test_every_request_retires_exactly_once(self, seed, n, gen,
                                                use_eos):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(seed)
        completed_before = len(engine.completed)
        handles = [engine.submit(Request(
            prompt=p, max_new_tokens=gen,
            eos_id=int(rng.integers(0, CFG.vocab_size)) if use_eos
            else None)) for p in _prompts(rng, n)]
        order_before = list(engine.admission_order)
        engine.run()

        # Every admitted request retired exactly once.
        new_completed = list(engine.completed)[completed_before:]
        assert sorted(h.request_id for h in new_completed) == \
            sorted(h.request_id for h in handles)
        for h in handles:
            assert h.done and h.finished_at is not None
            assert 1 <= len(h.tokens) <= gen
            if len(h.tokens) < gen:     # early retirement must be EOS
                assert h.eos_hit
            assert all(0 <= t < CFG.vocab_size for t in h.tokens)

        # No lane or page leaked or double-assigned.
        engine.pool.check_invariants()
        assert engine.pool.num_free_lanes == N_SLOTS
        assert engine.pool.num_mapped_pages == 0
        assert engine.num_active == 0 and engine.num_pending == 0

        # FIFO admission fairness: admitted in submission order.
        new_order = list(engine.admission_order)[len(order_before):]
        assert new_order == [h.request_id for h in handles]


# ---------------------------------------------------------------------------
# Byte-identity oracle across page geometries
# ---------------------------------------------------------------------------

class TestGeometryOracle:
    """Engine output must be byte-identical to the lock-step decode for
    EVERY page geometry: paging changes physical addressing only, never
    the positions the softmax sees."""

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**20))
    def test_byte_identical_to_lockstep_beam(self, seed):
        """Engine (2 lanes, mixed admission, page_len 3) == lock-step batch
        decode, token for token, for the same seed/prompts."""
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(seed)
        b, pl, gen = 3, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, BEAM)
        handles = [engine.submit(Request(prompt=p, max_new_tokens=gen))
                   for p in prompts]
        engine.run()
        out = np.stack([h.result() for h in handles])
        np.testing.assert_array_equal(out, ref)

    @pytest.mark.parametrize("page_len", [1, MAX_LEN])
    @pytest.mark.parametrize("batched", [True, False])
    def test_geometry_sweep_beam(self, page_len, batched):
        self._run_geometry(page_len, batched, beam=BEAM)

    @pytest.mark.slow
    @pytest.mark.parametrize("page_len", [3, 7])
    @pytest.mark.parametrize("batched", [True, False])
    def test_geometry_sweep_beam_odd_pages(self, page_len, batched):
        self._run_geometry(page_len, batched, beam=BEAM)

    def _run_geometry(self, page_len, batched, beam):
        rng = np.random.default_rng(1000 * page_len + batched)
        b, gen = 4, 3
        prompts = _prompts(rng, b, lo=2, hi=5)
        refs = [
            _lockstep(p[None], gen, beam)[0] for p in prompts]
        engine = shared_engine(page_len=page_len, batched=batched)
        handles = [engine.submit(Request(prompt=p, max_new_tokens=gen))
                   for p in prompts]
        engine.run()
        for h, ref in zip(handles, refs):
            np.testing.assert_array_equal(h.result(), ref)
        engine.pool.check_invariants()

    def test_byte_identical_to_lockstep_dense(self):
        rng = np.random.default_rng(7)
        b, pl, gen = 3, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, 0)
        for page_len in (1, 3, MAX_LEN):
            eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
                n_slots=2, max_len=MAX_LEN, beam=0, page_len=page_len,
                cache_dtype=jnp.float32))
            handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                       for p in prompts]
            eng.run()
            np.testing.assert_array_equal(
                np.stack([h.result() for h in handles]), ref)

    def test_batched_prefill_one_launch_for_burst(self):
        """A burst admitted together prefills in ONE padded call (vs one
        per request sequentially) and still matches the oracle."""
        rng = np.random.default_rng(41)
        gen = 2
        prompts = _prompts(rng, N_SLOTS, lo=2, hi=4)
        refs = [_lockstep(p[None], gen, BEAM)[0] for p in prompts]
        for batched, expect_calls in ((True, 1), (False, N_SLOTS)):
            eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
                n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM, page_len=3,
                batched_prefill=batched, cache_dtype=jnp.float32))
            handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                       for p in prompts]
            eng.step()          # single admission round for the burst
            assert eng.prefill_calls == expect_calls
            eng.run()
            for h, ref in zip(handles, refs):
                np.testing.assert_array_equal(h.result(), ref)


# ---------------------------------------------------------------------------
# Fragmentation / undersized-pool regression
# ---------------------------------------------------------------------------

class TestFragmentation:
    def test_half_size_paged_pool_serves_mixed_trace(self):
        """Poisson traffic of mixed lengths through a paged pool sized to
        ~half the monolithic pool's bytes: the whole trace completes (no
        deadlock), occupancy never exceeds n_pages, and outputs still
        match the oracle."""
        page_len = 3
        # Monolithic bytes: N_SLOTS * MAX_LEN positions. Half, in pages:
        n_pages = (N_SLOTS * MAX_LEN // 2) // page_len          # 4 pages
        assert n_pages * page_len * 2 == N_SLOTS * MAX_LEN
        tcfg = TrafficConfig(
            n_requests=12, rate=500.0, prompt_len=4, gen_tokens=2,
            prompt_len_choices=(2, 3, 4), gen_tokens_choices=(1, 2, 3),
            vocab_size=CFG.vocab_size, seed=5)
        workload = make_workload(tcfg)
        engine = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM, page_len=page_len,
            n_pages=n_pages, cache_dtype=jnp.float32))
        res = drive(engine, workload, time_scale=0.0)
        assert res["n_requests"] == tcfg.n_requests
        stats = engine.stats()
        assert stats["completed"] >= tcfg.n_requests
        assert 0 < stats["peak_pages_in_use"] <= n_pages
        assert stats["pages_in_use"] == 0       # drained
        engine.pool.check_invariants()
        # Byte-identity survives the undersized pool.
        for h in list(engine.completed)[-tcfg.n_requests:]:
            ref = _lockstep(h.request.prompt[None],
                            h.request.max_new_tokens, BEAM)[0]
            np.testing.assert_array_equal(h.result(), ref)

    def test_internal_fragmentation_reported(self):
        """stats() fragmentation: mapped-but-unwritten positions over
        mapped bytes, in (0, 1) while a short request holds a long page."""
        engine = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=1, max_len=MAX_LEN, beam=0, page_len=MAX_LEN,
            cache_dtype=jnp.float32))
        rng = np.random.default_rng(43)
        prompt = rng.integers(0, CFG.vocab_size, 2).astype(np.int32)
        engine.submit(Request(prompt=prompt, max_new_tokens=6))
        engine.step()       # admitted: 2-3 positions used of a 12-page
        frag = engine.stats()["internal_fragmentation"]
        assert 0.0 < frag < 1.0
        engine.run()
        assert engine.stats()["internal_fragmentation"] == 0.0


# ---------------------------------------------------------------------------
# Candidate cache on the paged path
# ---------------------------------------------------------------------------

class TestCandidateCachePath:
    def test_repeat_prefix_hits_and_identical_outputs(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        h1 = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        engine.run()
        skips_before = engine.descent_skips
        hits_before = engine.candidate_cache.hits
        h2 = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        engine.run()
        assert h2.tokens == h1.tokens
        assert engine.candidate_cache.hits > hits_before
        assert engine.descent_skips > skips_before

    def test_head_swap_invalidates_cached_candidates(self):
        """A generator/head refresh must not serve candidates descended
        under the old tree: swap_head_state bumps the cache version, so
        the same prompt re-descends (no descent skip) and the outputs
        match an engine built with the new head state from scratch."""
        new_head = lm_head.default_head_state(jax.random.PRNGKey(2), CFG,
                                              "adversarial_ns")

        def fresh(head_state):
            return Engine(CFG, HCFG, PARAMS, head_state, ServeConfig(
                n_slots=1, max_len=MAX_LEN, beam=BEAM, page_len=3,
                cache_dtype=jnp.float32))

        rng = np.random.default_rng(19)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        eng = fresh(HEAD_STATE)
        eng.submit(Request(prompt=prompt, max_new_tokens=4))
        eng.run()
        # Sanity: with no swap the repeat skips descents via the cache.
        skips0 = eng.descent_skips
        eng.submit(Request(prompt=prompt, max_new_tokens=4))
        eng.run()
        assert eng.descent_skips > skips0

        eng.swap_head_state(new_head)
        skips1 = eng.descent_skips
        h = eng.submit(Request(prompt=prompt, max_new_tokens=4))
        eng.run()
        # Old entries are unreachable: every step re-descended.
        assert eng.descent_skips == skips1
        stats = eng.candidate_cache.stats()
        assert stats["version"] == 1 and stats["invalidations"] == 1
        # And the decode is what the new head produces, not a stale mix.
        ref_eng = fresh(new_head)
        ref = ref_eng.submit(Request(prompt=prompt, max_new_tokens=4))
        ref_eng.run()
        assert h.tokens == ref.tokens

    def test_cache_disabled_engine_matches(self):
        rng = np.random.default_rng(13)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        outs = []
        for use_cache in (True, False):
            eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
                n_slots=1, max_len=MAX_LEN, beam=BEAM, page_len=3,
                use_candidate_cache=use_cache, cache_dtype=jnp.float32))
            h = eng.submit(Request(prompt=prompt, max_new_tokens=4))
            h2 = eng.submit(Request(prompt=prompt, max_new_tokens=4))
            eng.run()
            outs.append((h.tokens, h2.tokens))
            assert (eng.candidate_cache is not None) == use_cache
        assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Retirement
# ---------------------------------------------------------------------------

class TestRetirement:
    def test_per_request_max_new_tokens(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(17)
        prompts = _prompts(rng, 3)
        lens = [1, 3, 2]
        handles = [engine.submit(Request(prompt=p, max_new_tokens=g))
                   for p, g in zip(prompts, lens)]
        engine.run()
        assert [len(h.tokens) for h in handles] == lens

    def test_eos_stops_early_and_frees_lane_and_pages(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(19)
        prompt = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        h_ref = engine.submit(Request(prompt=prompt, max_new_tokens=5))
        engine.run()
        assert len(h_ref.tokens) == 5
        eos = h_ref.tokens[2]
        first = h_ref.tokens.index(eos)      # eos may repeat earlier
        h = engine.submit(Request(prompt=prompt, max_new_tokens=5,
                                  eos_id=eos))
        engine.run()
        assert h.eos_hit and len(h.tokens) == first + 1
        assert h.tokens == h_ref.tokens[:first + 1]
        assert engine.pool.num_free_lanes == N_SLOTS
        assert engine.pool.num_mapped_pages == 0

    def test_oversized_request_rejected(self):
        engine = shared_engine(page_len=3)
        prompt = np.zeros((MAX_LEN,), np.int32)
        with pytest.raises(ValueError):
            engine.submit(Request(prompt=prompt, max_new_tokens=1))

    def test_zero_budget_request_rejected(self):
        """The engine always decodes >= 1 token; a zero budget would write
        one position past the request's page reservation."""
        engine = shared_engine(page_len=3)
        with pytest.raises(ValueError):
            engine.submit(Request(prompt=np.zeros((2,), np.int32),
                                  max_new_tokens=0))

    def test_streaming_matches_result(self):
        engine = shared_engine(page_len=3)
        rng = np.random.default_rng(23)
        prompt = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        h = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        streamed = list(engine.stream(h))
        assert streamed == list(h.result())


# ---------------------------------------------------------------------------
# Pool / cache / traffic units
# ---------------------------------------------------------------------------

class TestPagedPoolUnit:
    def test_arena_shape(self):
        pool = PagedPool(CFG, 4, 6, 4, 16, dtype=jnp.float32)
        # +1 physical page: the sink.
        assert pool.cache["k"].shape == (
            CFG.num_layers, 7, 4, CFG.num_kv_heads, CFG.resolved_head_dim)
        assert pool.max_pages == 4
        assert pool.page_table.shape == (4, 4)

    def test_lifo_reuse(self):
        pool = _fresh_pool()
        lane, pages = pool.alloc(2)
        pool.release(lane)
        lane2, pages2 = pool.alloc(2)
        assert lane2 == lane                 # LIFO lane reuse
        assert pages2 == pages[::-1]         # LIFO page reuse

    def test_pool_too_small_for_max_len_rejected(self):
        with pytest.raises(AssertionError):
            PagedPool(CFG, 2, 2, 3, MAX_LEN, dtype=jnp.float32)


class TestCandidateCacheUnit:
    def test_lru_eviction_and_stats(self):
        cc = CandidateCache(capacity=2)
        c = np.arange(4, dtype=np.int32)
        lp = np.zeros(4, np.float32)
        cc.put((1,), c, lp)
        cc.put((2,), c, lp)
        assert cc.get((1,)) is not None      # (1,) now most-recent
        cc.put((3,), c, lp)                  # evicts (2,)
        assert cc.get((2,)) is None
        assert cc.get((3,)) is not None
        assert cc.evictions == 1
        assert cc.stats()["hits"] == 2 and cc.stats()["misses"] == 1

    def test_hit_returns_stored_arrays(self):
        cc = CandidateCache(capacity=4)
        c = np.array([5, 7, -1], np.int32)
        lp = np.array([-0.5, -1.5, -np.inf], np.float32)
        cc.put((0, 1, 2), c, lp)
        got_c, got_lp = cc.get((0, 1, 2))
        np.testing.assert_array_equal(got_c, c)
        np.testing.assert_array_equal(got_lp, lp)


class TestTraffic:
    def test_workload_shapes_and_repeats(self):
        tcfg = TrafficConfig(n_requests=32, rate=100.0, prompt_len=5,
                             gen_tokens=3, vocab_size=50, repeat_frac=0.5,
                             n_shared_prompts=1, seed=3)
        wl = make_workload(tcfg)
        assert len(wl) == 32
        arrivals = [t for t, _ in wl]
        assert arrivals == sorted(arrivals) and arrivals[0] == 0.0
        keys = {tuple(r.prompt.tolist()) for _, r in wl}
        assert len(keys) < 32               # shared prompts actually repeat
        for _, r in wl:
            assert r.prompt.shape == (5,) and r.max_new_tokens == 3

    def test_mixed_length_workload(self):
        tcfg = TrafficConfig(n_requests=64, rate=100.0, prompt_len=8,
                             gen_tokens=4, prompt_len_choices=(2, 5, 8),
                             gen_tokens_choices=(1, 4), vocab_size=50,
                             seed=9)
        wl = make_workload(tcfg)
        assert {r.prompt.shape[0] for _, r in wl} == {2, 5, 8}
        assert {r.max_new_tokens for _, r in wl} == {1, 4}


class TestSSMEngine:
    @pytest.mark.slow
    def test_ssm_engine_matches_oracle_mixed_lengths(self):
        """SSM models through the paged engine: recurrent state is NOT
        position-local, so batched prefill must group by exact prompt
        length instead of length-padding (padding tokens would keep
        updating the carried state). Mixed lengths — including one shorter
        than the conv window, the seed bug the left-pad in
        ssm.ssm_block's prefill conv_state fixes — must match the
        per-request oracle byte for byte."""
        import dataclasses
        from repro import configs as cfg_lib
        cfg = dataclasses.replace(cfg_lib.reduced_config("mamba2-370m"),
                                  dtype="float32", remat=False)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        hs = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                        "adversarial_ns")
        hcfg = lm_head.head_config(cfg, "adversarial_ns")
        rng = np.random.default_rng(3)
        # 2 < ssm_conv_width - 1: the short-prompt conv-state case.
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (5, 3, 5, 2)]
        refs = [lockstep_decode(cfg, hcfg, params, hs, p[None], 3,
                                topk_beam=0)[0] for p in prompts]
        eng = Engine(cfg, hcfg, params, hs, ServeConfig(
            n_slots=4, max_len=12, beam=0, page_len=3,
            cache_dtype=jnp.float32))
        # Pure-SSM has no K/V arena: the requested page geometry is pinned
        # to one nominal page per lane so pages never gate admission.
        assert eng.pool.page_len == 12 and eng.pool.n_pages == 4
        handles = [eng.submit(Request(prompt=p, max_new_tokens=3))
                   for p in prompts]
        eng.run()
        for h, ref in zip(handles, refs):
            np.testing.assert_array_equal(h.result(), ref)
        # One prefill launch per distinct prompt length in the burst —
        # but the FIFO audit trail stays in submission order.
        assert eng.prefill_calls == 3
        assert list(eng.admission_order) == [h.request_id for h in handles]
        eng.pool.check_invariants()


class TestMeshScoring:
    def test_sharded_score_fn_matches_plain(self):
        """make_serve_step(mesh=...) (1-shard mesh here; multi-shard runs in
        test_parallel's subprocess launcher test) == plain scoring."""
        from repro.parallel import AxisType, make_mesh
        mesh = make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
        rng = np.random.default_rng(29)
        b, pl, gen = 2, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, BEAM)
        sharded = lockstep_decode(CFG, HCFG, PARAMS, HEAD_STATE, prompts,
                                  gen, topk_beam=BEAM, mesh=mesh)
        np.testing.assert_array_equal(sharded, ref)

    def test_engine_mesh_paged_arena_matches(self):
        """Engine(mesh=...) with the paged arena device_put through
        paged_cache_shardings still reproduces the oracle."""
        from repro.parallel import AxisType, make_mesh
        mesh = make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
        rng = np.random.default_rng(31)
        prompts = rng.integers(0, CFG.vocab_size, (2, 3)).astype(np.int32)
        ref = _lockstep(prompts, 3, BEAM)
        eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=2, max_len=MAX_LEN, beam=BEAM, page_len=3, mesh=mesh,
            cache_dtype=jnp.float32))
        handles = [eng.submit(Request(prompt=p, max_new_tokens=3))
                   for p in prompts]
        eng.run()
        np.testing.assert_array_equal(
            np.stack([h.result() for h in handles]), ref)
