"""Continuous-batching engine: scheduler invariants (property-based), slot
pool + candidate cache units, and byte-identity vs the lock-step decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.serve import (CandidateCache, Engine, Request, ServeConfig,
                         SlotPool, lockstep_decode)
from repro.serve.traffic import TrafficConfig, make_workload

CFG = ModelConfig(
    name="engine-test", num_layers=1, d_model=32, d_ff=64, vocab_size=100,
    num_heads=2, num_kv_heads=2, vocab_pad_multiple=128, gen_feature_dim=8,
    dtype="float32", remat=False)
HCFG = lm_head.head_config(CFG, "adversarial_ns")
PARAMS = transformer.init_params(jax.random.PRNGKey(0), CFG)
HEAD_STATE = lm_head.default_head_state(jax.random.PRNGKey(1), CFG,
                                        "adversarial_ns")
MAX_LEN = 12
BEAM = 8
N_SLOTS = 2


_ENGINE = None


def shared_engine() -> Engine:
    """One shared engine (jit caches stay warm across tests/examples);
    between runs all slots are free and the queues empty, so state
    carry-over is only the candidate cache — which never changes outputs,
    only skips work. (A plain helper, not a pytest fixture: the hypothesis
    fallback shim hides fixture params from pytest's resolver.)"""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, beam=BEAM,
            cache_dtype=jnp.float32))
    return _ENGINE


def _prompts(rng, n, lo=2, hi=4):
    return [rng.integers(0, CFG.vocab_size,
                         rng.integers(lo, hi + 1)).astype(np.int32)
            for _ in range(n)]


def _lockstep(prompts, gen_tokens, beam):
    """Reference decode: the shared fixed-batch oracle from repro.serve."""
    return lockstep_decode(CFG, HCFG, PARAMS, HEAD_STATE, prompts,
                           gen_tokens, topk_beam=beam)


class TestSchedulerInvariants:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**20), n=st.integers(1, 6),
           gen=st.integers(1, 4), use_eos=st.sampled_from([False, True]))
    def test_every_request_retires_exactly_once(self, seed, n, gen,
                                                use_eos):
        engine = shared_engine()
        rng = np.random.default_rng(seed)
        completed_before = len(engine.completed)
        handles = [engine.submit(Request(
            prompt=p, max_new_tokens=gen,
            eos_id=int(rng.integers(0, CFG.vocab_size)) if use_eos
            else None)) for p in _prompts(rng, n)]
        order_before = list(engine.admission_order)
        engine.run()

        # Every admitted request retired exactly once.
        new_completed = list(engine.completed)[completed_before:]
        assert sorted(h.request_id for h in new_completed) == \
            sorted(h.request_id for h in handles)
        for h in handles:
            assert h.done and h.finished_at is not None
            assert 1 <= len(h.tokens) <= gen
            if len(h.tokens) < gen:     # early retirement must be EOS
                assert h.eos_hit
            assert all(0 <= t < CFG.vocab_size for t in h.tokens)

        # No slot leaked or double-assigned.
        engine.pool.check_invariants()
        assert engine.pool.num_free == N_SLOTS
        assert engine.num_active == 0 and engine.num_pending == 0

        # FIFO admission fairness: admitted in submission order.
        new_order = list(engine.admission_order)[len(order_before):]
        assert new_order == [h.request_id for h in handles]

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**20))
    def test_byte_identical_to_lockstep_beam(self, seed):
        """Engine (2 slots, mixed admission) == lock-step batch decode,
        token for token, for the same seed/prompts."""
        engine = shared_engine()
        rng = np.random.default_rng(seed)
        b, pl, gen = 3, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, BEAM)
        handles = [engine.submit(Request(prompt=p, max_new_tokens=gen))
                   for p in prompts]
        engine.run()
        out = np.stack([h.result() for h in handles])
        np.testing.assert_array_equal(out, ref)

    def test_byte_identical_to_lockstep_dense(self):
        rng = np.random.default_rng(7)
        b, pl, gen = 3, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, 0)
        eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=2, max_len=MAX_LEN, beam=0, cache_dtype=jnp.float32))
        handles = [eng.submit(Request(prompt=p, max_new_tokens=gen))
                   for p in prompts]
        eng.run()
        np.testing.assert_array_equal(
            np.stack([h.result() for h in handles]), ref)


class TestCandidateCachePath:
    def test_repeat_prefix_hits_and_identical_outputs(self):
        engine = shared_engine()
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        h1 = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        engine.run()
        skips_before = engine.descent_skips
        hits_before = engine.candidate_cache.hits
        h2 = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        engine.run()
        assert h2.tokens == h1.tokens
        assert engine.candidate_cache.hits > hits_before
        assert engine.descent_skips > skips_before

    def test_cache_disabled_engine_matches(self):
        rng = np.random.default_rng(13)
        prompt = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        outs = []
        for use_cache in (True, False):
            eng = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
                n_slots=1, max_len=MAX_LEN, beam=BEAM,
                use_candidate_cache=use_cache, cache_dtype=jnp.float32))
            h = eng.submit(Request(prompt=prompt, max_new_tokens=4))
            h2 = eng.submit(Request(prompt=prompt, max_new_tokens=4))
            eng.run()
            outs.append((h.tokens, h2.tokens))
            assert (eng.candidate_cache is not None) == use_cache
        assert outs[0] == outs[1]


class TestRetirement:
    def test_per_request_max_new_tokens(self):
        engine = shared_engine()
        rng = np.random.default_rng(17)
        prompts = _prompts(rng, 3)
        lens = [1, 3, 2]
        handles = [engine.submit(Request(prompt=p, max_new_tokens=g))
                   for p, g in zip(prompts, lens)]
        engine.run()
        assert [len(h.tokens) for h in handles] == lens

    def test_eos_stops_early_and_frees_slot(self):
        engine = shared_engine()
        rng = np.random.default_rng(19)
        prompt = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        h_ref = engine.submit(Request(prompt=prompt, max_new_tokens=5))
        engine.run()
        assert len(h_ref.tokens) == 5
        eos = h_ref.tokens[2]
        first = h_ref.tokens.index(eos)      # eos may repeat earlier
        h = engine.submit(Request(prompt=prompt, max_new_tokens=5,
                                  eos_id=eos))
        engine.run()
        assert h.eos_hit and len(h.tokens) == first + 1
        assert h.tokens == h_ref.tokens[:first + 1]
        assert engine.pool.num_free == N_SLOTS

    def test_oversized_request_rejected(self):
        engine = shared_engine()
        prompt = np.zeros((MAX_LEN,), np.int32)
        with pytest.raises(ValueError):
            engine.submit(Request(prompt=prompt, max_new_tokens=1))

    def test_streaming_matches_result(self):
        engine = shared_engine()
        rng = np.random.default_rng(23)
        prompt = rng.integers(0, CFG.vocab_size, 3).astype(np.int32)
        h = engine.submit(Request(prompt=prompt, max_new_tokens=4))
        streamed = list(engine.stream(h))
        assert streamed == list(h.result())


class TestSlotPool:
    def test_alloc_release_invariants(self):
        pool = SlotPool(CFG, 3, 8)
        slots = [pool.alloc() for _ in range(3)]
        assert sorted(slots) == [0, 1, 2]
        assert pool.alloc() is None          # saturated, no double-assign
        pool.check_invariants()
        pool.release(slots[1])
        assert pool.num_free == 1
        assert pool.alloc() == slots[1]      # LIFO reuse
        pool.check_invariants()
        with pytest.raises(AssertionError):  # double release
            pool.release(slots[1])
            pool.release(slots[1])

    def test_cache_shape(self):
        pool = SlotPool(CFG, 4, 16, dtype=jnp.float32)
        assert pool.cache["k"].shape == (
            CFG.num_layers, 4, 16, CFG.num_kv_heads, CFG.resolved_head_dim)


class TestCandidateCacheUnit:
    def test_lru_eviction_and_stats(self):
        cc = CandidateCache(capacity=2)
        c = np.arange(4, dtype=np.int32)
        lp = np.zeros(4, np.float32)
        cc.put((1,), c, lp)
        cc.put((2,), c, lp)
        assert cc.get((1,)) is not None      # (1,) now most-recent
        cc.put((3,), c, lp)                  # evicts (2,)
        assert cc.get((2,)) is None
        assert cc.get((3,)) is not None
        assert cc.evictions == 1
        assert cc.stats()["hits"] == 2 and cc.stats()["misses"] == 1

    def test_hit_returns_stored_arrays(self):
        cc = CandidateCache(capacity=4)
        c = np.array([5, 7, -1], np.int32)
        lp = np.array([-0.5, -1.5, -np.inf], np.float32)
        cc.put((0, 1, 2), c, lp)
        got_c, got_lp = cc.get((0, 1, 2))
        np.testing.assert_array_equal(got_c, c)
        np.testing.assert_array_equal(got_lp, lp)


class TestTraffic:
    def test_workload_shapes_and_repeats(self):
        tcfg = TrafficConfig(n_requests=32, rate=100.0, prompt_len=5,
                             gen_tokens=3, vocab_size=50, repeat_frac=0.5,
                             n_shared_prompts=1, seed=3)
        wl = make_workload(tcfg)
        assert len(wl) == 32
        arrivals = [t for t, _ in wl]
        assert arrivals == sorted(arrivals) and arrivals[0] == 0.0
        keys = {tuple(r.prompt.tolist()) for _, r in wl}
        assert len(keys) < 32               # shared prompts actually repeat
        for _, r in wl:
            assert r.prompt.shape == (5,) and r.max_new_tokens == 3


class TestMeshScoring:
    def test_sharded_score_fn_matches_plain(self):
        """make_serve_step(mesh=...) (1-shard mesh here; multi-shard runs in
        test_parallel's subprocess launcher test) == plain scoring."""
        from repro.parallel import AxisType, make_mesh
        mesh = make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
        rng = np.random.default_rng(29)
        b, pl, gen = 2, 3, 3
        prompts = rng.integers(0, CFG.vocab_size, (b, pl)).astype(np.int32)
        ref = _lockstep(prompts, gen, BEAM)
        sharded = lockstep_decode(CFG, HCFG, PARAMS, HEAD_STATE, prompts,
                                  gen, topk_beam=BEAM, mesh=mesh)
        np.testing.assert_array_equal(sharded, ref)
