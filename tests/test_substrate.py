"""Substrate tests: checkpoint atomicity/restore/reshard, optimizers,
gradient compression + error feedback, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import HostShardedLoader, lm_batch_fn, make_clustered_xc
from repro.data.synthetic import ClusteredXCSpec
from repro.optim import (OptimizerConfig, apply_updates,
                         compress_with_error_feedback, decompress,
                         init_ef_state, init_opt_state)


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"a": jax.random.normal(k, (8, 4)),
                "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)}}

    def test_roundtrip_bit_exact(self, tmp_path):
        t = self._tree()
        save_checkpoint(str(tmp_path), 7, t)
        restored, step = restore_checkpoint(str(tmp_path), t)
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer_and_gc(self, tmp_path):
        t = self._tree()
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, t, keep=2)
        assert latest_step(str(tmp_path)) == 5
        dirs = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert dirs == ["step_00000004", "step_00000005"]

    def test_no_partial_checkpoint_visible(self, tmp_path):
        """A crash mid-save must not be restorable: simulate by writing a
        stray temp dir and confirming LATEST ignores it."""
        t = self._tree()
        save_checkpoint(str(tmp_path), 1, t)
        os.makedirs(tmp_path / ".tmp_ckpt_dead", exist_ok=True)
        (tmp_path / ".tmp_ckpt_dead" / "arr_00000.npy").write_bytes(b"junk")
        assert latest_step(str(tmp_path)) == 1
        restored, _ = restore_checkpoint(str(tmp_path), t)
        assert len(jax.tree.leaves(restored)) == 3

    def test_restore_with_different_sharding(self, tmp_path):
        """Elastic restart path: restore with explicit shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = self._tree()
        save_checkpoint(str(tmp_path), 2, t)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        restored, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adagrad", "adamw", "sgd"])
    def test_quadratic_converges(self, name):
        cfg = OptimizerConfig(name=name, learning_rate=0.3, clip_norm=10.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(cfg, params)
        for _ in range(300):
            grads = {"w": 2 * params["w"]}
            params, state, _ = apply_updates(cfg, params, grads, state)
        # Adagrad's 1/sqrt(sum g^2) step decay gives sublinear convergence.
        tol = 0.1 if name == "adagrad" else 0.05
        assert float(jnp.abs(params["w"]).max()) < tol, name

    def test_clip_norm_applied(self):
        cfg = OptimizerConfig(name="sgd", learning_rate=1.0, clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(cfg, params)
        new, _, m = apply_updates(cfg, params, {"w": jnp.full(4, 100.0)},
                                  state)
        np.testing.assert_allclose(float(jnp.linalg.norm(new["w"])), 1.0,
                                   rtol=1e-4)

    def test_warmup_schedule(self):
        from repro.optim import schedule
        cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10)
        assert float(schedule(cfg, jnp.int32(0))) == pytest.approx(0.1)
        assert float(schedule(cfg, jnp.int32(9))) == pytest.approx(1.0)


class TestCompression:
    def test_quantize_roundtrip_small_error(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,))}
        ef = init_ef_state(g)
        q, s, ef = compress_with_error_feedback(g, ef)
        deq = decompress(q, s)
        err = float(jnp.abs(deq["w"] - g["w"]).max())
        assert err <= float(s["w"]) * 0.5 + 1e-6

    def test_error_feedback_preserves_mean_signal(self):
        """Sum over steps of dequantized grads ~ sum of true grads:
        residuals cannot accumulate unboundedly with error feedback."""
        key = jax.random.PRNGKey(1)
        g_true, g_sent = jnp.zeros(64), jnp.zeros(64)
        ef = init_ef_state({"w": jnp.zeros(64)})
        for i in range(50):
            key, sub = jax.random.split(key)
            g = {"w": 0.01 * jax.random.normal(sub, (64,))}
            q, s, ef = compress_with_error_feedback(g, ef)
            g_true = g_true + g["w"]
            g_sent = g_sent + decompress(q, s)["w"]
        # Residual is bounded by one quantization step, not O(n_steps).
        resid = float(jnp.abs(g_true - g_sent).max())
        assert resid < 5e-4

    def test_ef_sgd_converges_like_sgd(self):
        """EF-quantized SGD reaches the same optimum on a quadratic."""
        w = jnp.array([4.0, -2.0, 1.0])
        ef = init_ef_state({"w": w})
        for _ in range(400):
            g = {"w": 2 * w}
            q, s, ef = compress_with_error_feedback(g, ef)
            w = w - 0.1 * decompress(q, s)["w"]
        assert float(jnp.abs(w).max()) < 1e-2


class TestData:
    def test_clustered_xc_shapes_and_determinism(self):
        spec = ClusteredXCSpec(num_labels=64, feature_dim=16, seed=3)
        x1, y1, xt, yt = make_clustered_xc(spec, 500, 100)
        x2, y2, _, _ = make_clustered_xc(spec, 500, 100)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
        assert x1.shape == (500, 16) and y1.max() < 64

    def test_cluster_structure_is_learnable(self):
        """Nearest-centroid on train centers beats chance on test."""
        spec = ClusteredXCSpec(num_labels=32, feature_dim=16, seed=1,
                               noise=0.2)
        x, y, xt, yt = make_clustered_xc(spec, 4000, 500)
        centers = np.zeros((32, 16))
        for c in range(32):
            m = y == c
            if m.any():
                centers[c] = x[m].mean(0)
        pred = np.argmin(
            ((xt[:, None, :] - centers[None]) ** 2).sum(-1), axis=1)
        assert (pred == yt).mean() > 0.5

    def test_host_sharded_loader_slices_and_seeks(self):
        fn = lm_batch_fn(vocab_size=101, global_batch=8, seq_len=16, seed=0)
        loaders = [HostShardedLoader(fn, 8, num_hosts=2, host_id=h,
                                     prefetch=0) for h in (0, 1)]
        its = [iter(ld) for ld in loaders]
        s0, b0 = next(its[0])
        s1, b1 = next(its[1])
        assert s0 == s1 == 0
        assert b0["tokens"].shape == (4, 16)
        full = fn(0)["tokens"]
        np.testing.assert_array_equal(
            np.concatenate([b0["tokens"], b1["tokens"]]), full)
        # seek = deterministic restart
        loaders[0].seek(5)
        s, b = next(iter(loaders[0]))
        assert s == 5
        np.testing.assert_array_equal(b["tokens"], fn(5)["tokens"][:4])
        for ld in loaders:
            ld.close()
