"""repro.obs: histogram quantile fidelity vs numpy, the disabled-mode
zero-overhead contract, JSONL schema round-trips, span nesting, and the
documented metric names actually emitted by an instrumented train loop
and serving engine (DESIGN.md §10)."""
import dataclasses
import gc
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm_head
from repro.obs import (Counter, Gauge, Histogram, JsonlExporter,
                       NULL_COUNTER, NULL_EWMA, NULL_GAUGE, NULL_HISTOGRAM,
                       NULL_REGISTRY, ProfileWindow, Registry,
                       console_summary, current_spans, exp_buckets,
                       linear_buckets, prometheus_text, read_jsonl, span,
                       validate_events)
from repro.obs.trace import _NULL_SPAN


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------

def test_counter_gauge_ewma_basics():
    c = Counter("c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(AssertionError):
        c.inc(-1)                       # counters are monotone

    g = Gauge("g")
    assert g.value is None              # unset until first write
    g.set(2)
    g.set(1.5)
    assert g.value == 1.5

    r = Registry()
    e = r.ewma("e", alpha=0.5)
    e.update(1.0)
    assert e.value == 1.0               # first update seeds
    e.update(3.0)
    assert e.value == 2.0 and e.count == 2


def test_registry_get_or_create_and_type_guard():
    r = Registry()
    assert r.counter("x") is r.counter("x")
    h = r.histogram("h", bounds=[1.0, 2.0])
    assert r.histogram("h") is h        # buckets fixed by first call
    with pytest.raises(AssertionError):
        r.gauge("x")                    # same name, different type
    assert r.names() == ["h", "x"]


def test_bucket_builders():
    b = exp_buckets(1e-3, 1.0, per_decade=10)
    assert b == sorted(b) and b[0] == pytest.approx(1e-3)
    assert b[-1] >= 1.0
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** 0.1) for r in ratios)
    assert linear_buckets(0.0, 1.0, 4) == [0.25, 0.5, 0.75, 1.0]


def test_histogram_quantiles_vs_numpy():
    """Interpolated bucket quantiles track numpy.quantile within one
    bucket ratio of relative error (the exp_buckets guarantee)."""
    rng = np.random.default_rng(0)
    vals = rng.uniform(0.011, 0.9, size=5000)
    per_decade = 50                     # ratio 10^(1/50) ~ 4.7%
    h = Histogram("h", bounds=exp_buckets(1e-2, 1.0, per_decade))
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.mean == pytest.approx(vals.mean())
    for q in (0.05, 0.25, 0.5, 0.9, 0.95, 0.99):
        ref = float(np.quantile(vals, q))
        got = h.quantile(q)
        assert abs(got - ref) / ref < 10 ** (1 / per_decade) - 1 + 0.01, \
            (q, got, ref)
    snap = h.snapshot()
    assert snap["min"] == vals.min() and snap["max"] == vals.max()


def test_histogram_edge_cases():
    h = Histogram("h", bounds=[1.0, 2.0, 4.0])
    assert h.quantile(0.5) is None and h.mean is None   # empty
    h.observe(1.7)
    for q in (0.0, 0.5, 1.0):           # single value: exact everywhere
        assert h.quantile(q) == 1.7
    h2 = Histogram("h2", bounds=[1.0])
    h2.observe(5.0)                     # overflow bucket
    h2.observe(7.0)
    for q in (0.1, 0.5, 0.9):           # clamped to the observed range
        assert 5.0 <= h2.quantile(q) <= 7.0
    assert h2.quantile(1.0) == 7.0
    h3 = Histogram("h3", bounds=[1.0, 2.0])
    for _ in range(10):
        h3.observe(1.5)                 # constant stream
    assert h3.quantile(0.99) == 1.5


# ---------------------------------------------------------------------------
# Disabled mode: the hot-path contract
# ---------------------------------------------------------------------------

def test_disabled_registry_hands_out_shared_singletons():
    r = Registry(enabled=False)
    assert r.counter("a") is NULL_COUNTER is r.counter("b")
    assert r.gauge("a") is NULL_GAUGE
    assert r.ewma("a") is NULL_EWMA
    assert r.histogram("a") is NULL_HISTOGRAM
    assert span("x", r) is _NULL_SPAN is span("y", None)
    NULL_COUNTER.inc()
    NULL_GAUGE.set(3.0)
    NULL_HISTOGRAM.observe(1.0)
    assert NULL_COUNTER.value == 0 and NULL_GAUGE.value is None
    assert r.snapshot() == {} and r.names() == []
    assert NULL_REGISTRY.enabled is False


def test_disabled_mode_allocates_nothing():
    """The instrumented-every-step train loop relies on disabled mode
    being allocation-free: no instrument objects, no span objects."""
    r = Registry(enabled=False)

    def loop():
        for _ in range(200):
            r.counter("train/steps").inc()
            r.gauge("train/loss").set(1.0)
            r.histogram("train/step_time_s").observe(0.01)
            with span("train/phase/step", r):
                pass

    loop()                              # warm caches outside the window
    gc.collect()
    tracemalloc.start()
    t0 = tracemalloc.get_traced_memory()[0]
    loop()
    gc.collect()
    t1 = tracemalloc.get_traced_memory()[0]
    tracemalloc.stop()
    assert t1 - t0 < 512, f"disabled-mode loop retained {t1 - t0} bytes"


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

def test_span_nesting_and_timing():
    r = Registry()
    assert current_spans() == ()
    with span("outer", r) as outer:
        assert current_spans() == ("outer",)
        with span("inner", r):
            assert current_spans() == ("outer", "inner")
        assert current_spans() == ("outer",)
    assert current_spans() == ()
    inner_h = r.histogram("inner")
    assert inner_h.count == 1
    assert outer.seconds >= inner_h.vmax    # parent encloses child


def test_span_stack_restored_on_exception():
    r = Registry()
    with pytest.raises(RuntimeError):
        with span("outer", r):
            with span("inner", r):
                raise RuntimeError("boom")
    assert current_spans() == ()            # both frames popped
    assert r.histogram("inner").count == 1  # duration still recorded
    assert r.histogram("outer").count == 1


def test_profile_window_inert_without_dir():
    p = ProfileWindow(None, n_steps=2)
    for s in range(5):
        p.tick(s)
    p.stop()
    p.stop()                                # idempotent


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def test_jsonl_round_trip_and_schema(tmp_path):
    path = str(tmp_path / "m.jsonl")
    events = [
        {"event": "compile", "step": 0, "compile_time_s": 1.2},
        {"event": "step", "step": 1, "loss": 3.5, "step_time_s": 0.01,
         "snr_proxy": 0.4, "snr_ewma": 0.41, "straggler": False},
        {"event": "gen_submit", "step": 3},
        {"event": "gen_swap", "step": 5, "old_fit_step": -1,
         "new_fit_step": 3, "fit_wall_s": 0.7, "steps_stale_at_swap": 2},
        {"event": "snr_trigger", "step": 9},
        {"event": "request", "request_id": 0, "tokens": 8,
         "admission_wait_s": 0.001, "ttft_s": 0.02, "latency_s": 0.09},
        {"event": "serve_step", "engine_step": 4, "queue_depth": 1,
         "active": 2, "page_occupancy": 0.5},
        {"event": "summary", "metrics": {}},
    ]
    with JsonlExporter(path) as ex:
        for ev in events:
            ex.emit(ev)
    assert ex.n_events == len(events)
    ex.emit({"event": "step"})              # closed: silent no-op
    back = read_jsonl(path)
    assert back == events
    validate_events(back)

    with pytest.raises(AssertionError):     # unknown type
        validate_events([{"event": "bogus"}])
    with pytest.raises(AssertionError):     # missing required field
        validate_events([{"event": "step", "step": 1, "loss": 2.0}])
    with pytest.raises(AssertionError):     # non-numeric timing
        validate_events([{"event": "compile", "step": 0,
                          "compile_time_s": "fast"}])
    with pytest.raises(AssertionError):
        validate_events([])


def test_pathless_exporter_is_noop(tmp_path):
    ex = JsonlExporter(None)
    ex.emit({"event": "step", "step": 0})
    assert ex.n_events == 0
    ex.close()


def test_prometheus_text_and_console_summary():
    r = Registry()
    r.counter("train/steps").inc(7)
    r.gauge("snr/ewma").set(0.43)
    h = r.histogram("train/step_time_s", bounds=[0.01, 0.1, 1.0])
    h.observe(0.05)
    h.observe(0.06)
    text = prometheus_text(r)
    assert "# TYPE train_steps counter" in text
    assert "train_steps 7" in text
    assert "# TYPE snr_ewma gauge" in text
    assert "# TYPE train_step_time_s summary" in text
    assert 'train_step_time_s{quantile="0.5"}' in text
    assert "train_step_time_s_count 2" in text

    out = console_summary(r, title="t")
    assert out.startswith("== t ==")
    assert "train/steps" in out and "n=2" in out
    assert console_summary(Registry()) == "== metrics: (empty) =="


def test_metrics_server_scrape_round_trip():
    """The pull endpoint serves a LIVE registry: scrape, mutate, re-scrape
    sees the new value; unknown paths 404; ephemeral port on port=0."""
    import urllib.error
    import urllib.request

    from repro.obs import start_metrics_server

    r = Registry()
    c = r.counter("serve/requests")
    c.inc(3)
    with start_metrics_server(r, port=0, host="127.0.0.1") as srv:
        assert srv.port > 0
        base = f"http://127.0.0.1:{srv.port}"
        body = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert body == prometheus_text(r)
        assert "serve_requests 3" in body
        c.inc(2)            # live registry, not a snapshot at bind time
        body = urllib.request.urlopen(base + "/").read().decode()
        assert "serve_requests 5" in body
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(f"{base}/nope")
        assert e.value.code == 404
    # Server is down after close().
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(f"{base}/metrics", timeout=0.5)


# ---------------------------------------------------------------------------
# Integration: the documented metric names are what the systems emit
# ---------------------------------------------------------------------------

def test_train_loop_emits_documented_metrics(tmp_path):
    """One instrumented run covers the acceptance contract: per-step SNR
    + step-time samples and genfit lifecycle events parse back from the
    JSONL log, and the registry carries the DESIGN.md §10 names."""
    from repro import configs as cfg_lib
    from repro.data import lm_batch_fn
    from repro.models import lm_head
    from repro.optim import OptimizerConfig
    from repro.train import (LoopConfig, init_train_state,
                             make_train_step, run_loop)
    from repro.train.generator_fit import make_gen_fit_fn

    cfg = dataclasses.replace(cfg_lib.reduced_config("stablelm-3b"),
                              num_layers=1, dtype="float32")
    hcfg = lm_head.head_config(cfg, "adversarial_ns", reg=1e-4)
    opt = OptimizerConfig(name="adagrad", learning_rate=0.05,
                          clip_norm=1.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt,
                             "adversarial_ns")
    step_fn = jax.jit(make_train_step(cfg, hcfg, opt))
    make = lm_batch_fn(cfg.vocab_size, global_batch=4, seq_len=16, seed=1)
    batch_fn = lambda s: {k: jnp.asarray(v)               # noqa: E731
                          for k, v in make(s).items()}
    gen_fit = make_gen_fit_fn(cfg, batch_fn, kind="adversarial_ns",
                              max_tokens=128, n_batches=2)

    path = str(tmp_path / "train.jsonl")
    total = 6
    loop = LoopConfig(total_steps=total, gen_warmup_steps=2,
                      gen_async=True, gen_swap_delay=2,
                      metrics_jsonl=path, metrics_interval=1)
    reg = Registry()
    _, hist = run_loop(state, step_fn, batch_fn, loop,
                       jax.random.PRNGKey(2), gen_fit_fn=gen_fit,
                       registry=reg)

    # Compile separated from steady state.
    assert hist["compile_time_s"] > 0
    assert len(hist["step_times"]) == total - 1
    assert hist["compile_time_s"] not in hist["step_times"]

    snap = hist["metrics"]
    for name in ("train/steps", "train/loss", "train/step_time_s",
                 "train/compile_time_s", "train/phase/data",
                 "train/phase/step", "snr/proxy", "snr/ewma",
                 "genfit/submits", "genfit/swaps", "genfit/fit_wall_s",
                 "genfit/staleness_at_swap"):
        assert name in snap, f"missing documented metric {name}"
    assert snap["train/steps"]["value"] == total
    assert snap["train/step_time_s"]["count"] == total - 1
    assert snap["genfit/swaps"]["value"] == 1
    assert snap == reg.snapshot()

    events = read_jsonl(path)
    validate_events(events)
    by = {}
    for ev in events:
        by.setdefault(ev["event"], []).append(ev)
    assert [e["step"] for e in by["step"]] == list(range(1, total))
    assert all("snr_proxy" in e and "snr_ewma" in e for e in by["step"])
    assert [e["step"] for e in by["gen_submit"]] == [2]
    swap, = by["gen_swap"]
    assert (swap["step"], swap["new_fit_step"],
            swap["steps_stale_at_swap"]) == (4, 2, 2)
    assert by["summary"][-1]["metrics"] == snap


@pytest.mark.serve
def test_engine_emits_latency_histograms_and_events(tmp_path):
    from repro.models import transformer
    from repro.models.config import ModelConfig
    from repro.serve import Engine, Request, ServeConfig

    cfg = ModelConfig(
        name="obs-engine", num_layers=1, d_model=32, d_ff=64,
        vocab_size=100, num_heads=2, num_kv_heads=2,
        vocab_pad_multiple=128, gen_feature_dim=8, dtype="float32",
        remat=False)
    hcfg = lm_head.head_config(cfg, "adversarial_ns")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    head_state = lm_head.default_head_state(jax.random.PRNGKey(1), cfg,
                                            "adversarial_ns")
    path = str(tmp_path / "serve.jsonl")
    ex = JsonlExporter(path)
    engine = Engine(cfg, hcfg, params, head_state,
                    ServeConfig(n_slots=2, max_len=12, beam=8,
                                cache_dtype=jnp.float32),
                    exporter=ex, metrics_interval=1)
    rng = np.random.default_rng(3)
    n_req, gen = 3, 4
    handles = [engine.submit(Request(
        prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
        max_new_tokens=gen)) for _ in range(n_req)]
    engine.run()
    ex.close()
    assert all(len(h.tokens) == gen for h in handles)

    stats = engine.stats()
    lat = stats["latency"]
    for key in ("admission_wait", "ttft", "total"):
        assert lat[key]["count"] == n_req, (key, lat[key])
        assert lat[key]["p50"] is not None
    assert lat["total"]["min"] >= lat["ttft"]["min"]
    assert stats["tokens"] == n_req * gen
    snap = stats["metrics"]
    for name in ("serve/admission_wait_s", "serve/ttft_s",
                 "serve/latency_s", "serve/tokens", "serve/queue_depth",
                 "serve/active", "serve/page_occupancy",
                 "serve/phase/prefill", "serve/phase/decode",
                 "serve/decode_steps", "serve/completed"):
        assert name in snap, f"missing documented metric {name}"
    assert snap["serve/tokens"]["value"] == n_req * gen

    events = read_jsonl(path)
    validate_events(events)
    by = {}
    for ev in events:
        by.setdefault(ev["event"], []).append(ev)
    assert len(by["request"]) == n_req
    for ev in by["request"]:
        assert 0 <= ev["ttft_s"] <= ev["latency_s"]
        assert ev["tokens"] == gen
    assert by["serve_step"], "no serve_step samples"
    assert all(ev["queue_depth"] >= 0 and 0 <= ev["page_occupancy"] <= 1
               for ev in by["serve_step"])
