"""Theorem 2: adversarial noise (p_n = p_D) maximizes the gradient SNR."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import snr as snr_lib


def _random_dist(seed, n, c, temp=1.0):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((n, c)) * temp
    p = np.exp(logits)
    return jnp.asarray(p / p.sum(-1, keepdims=True), jnp.float32)


def test_empirical_matches_closed_form():
    p_d = _random_dist(0, 4, 12, temp=1.2)
    p_n = _random_dist(1, 4, 12, temp=0.8)
    eta_cf = float(snr_lib.snr_closed_form(p_d, p_n))
    eta_mc = float(snr_lib.snr_empirical(p_d, p_n, jax.random.PRNGKey(2),
                                         n_samples=400_000))
    np.testing.assert_allclose(eta_mc, eta_cf, rtol=0.05)


def test_adversarial_noise_maximizes_snr():
    """eta(p_n = p_D) > eta(uniform), eta(marginal), eta(mixtures)."""
    n, c = 8, 32
    p_d = _random_dist(3, n, c, temp=2.0)
    eta_adv = float(snr_lib.snr_closed_form(p_d, p_d))
    uniform = jnp.full((n, c), 1.0 / c)
    marginal = jnp.tile(jnp.mean(p_d, 0, keepdims=True), (n, 1))
    assert eta_adv > float(snr_lib.snr_closed_form(p_d, uniform))
    assert eta_adv > float(snr_lib.snr_closed_form(p_d, marginal))
    for lam in (0.25, 0.5, 0.75):
        mix = lam * p_d + (1 - lam) * uniform
        assert eta_adv >= float(snr_lib.snr_closed_form(p_d, mix))


def test_snr_upper_bound_is_half_per_xy():
    """At p_n = p_D: sum_y alpha = 1/2 exactly (Jensen bound attained),
    so 1/eta = N * X * (C - 1)."""
    n, c = 5, 16
    p_d = _random_dist(4, n, c)
    eta = float(snr_lib.snr_closed_form(p_d, p_d))
    np.testing.assert_allclose(eta, 1.0 / (n * n * (c - 1.0)), rtol=1e-5)


def test_streamed_agrees_with_dense_reference():
    """The streamed accumulator and the small-C dense-scatter reference
    are the same estimator up to float32 re-association: both must land
    within Monte-Carlo tolerance of the closed form and of each other.
    (Bit-level agreement is impossible by construction — the dense path
    sums per-(x, y) cell then divides by alpha once, the streamed path
    adds g^2/alpha per draw — which is exactly why the docstring promises
    tolerance, not bits.)"""
    p_d = _random_dist(5, 4, 12, temp=1.2)
    p_n = _random_dist(6, 4, 12, temp=0.8)
    rng = jax.random.PRNGKey(7)
    eta_cf = float(snr_lib.snr_closed_form(p_d, p_n))
    eta_stream = float(snr_lib.snr_empirical(p_d, p_n, rng,
                                             n_samples=400_000))
    eta_dense = float(snr_lib.snr_empirical_dense(p_d, p_n, rng,
                                                  n_samples=400_000))
    np.testing.assert_allclose(eta_stream, eta_cf, rtol=0.05)
    np.testing.assert_allclose(eta_dense, eta_cf, rtol=0.05)
    np.testing.assert_allclose(eta_stream, eta_dense, rtol=0.05)


def test_streamed_is_bitwise_deterministic():
    """Identical (rng, n_samples, chunk) -> identical bits, including a
    ragged final chunk; changing the chunking changes the re-association
    order (different bits allowed) but not the value beyond tolerance."""
    p_d = _random_dist(8, 3, 10)
    p_n = _random_dist(9, 3, 10)
    rng = jax.random.PRNGKey(11)
    a = snr_lib.snr_empirical(p_d, p_n, rng, n_samples=50_001, chunk=256)
    b = snr_lib.snr_empirical(p_d, p_n, rng, n_samples=50_001, chunk=256)
    assert (jnp.asarray(a).view(jnp.uint32)
            == jnp.asarray(b).view(jnp.uint32)).item()
    c = snr_lib.snr_empirical(p_d, p_n, rng, n_samples=50_001, chunk=128)
    np.testing.assert_allclose(float(a), float(c), rtol=0.05)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20), n=st.integers(2, 6), c=st.integers(3, 40),
       temp=st.floats(0.2, 3.0))
def test_property_pd_is_global_max(seed, n, c, temp):
    p_d = _random_dist(seed, n, c, temp)
    p_other = _random_dist(seed + 1, n, c, temp)
    eta_adv = float(snr_lib.snr_closed_form(p_d, p_d))
    eta_other = float(snr_lib.snr_closed_form(p_d, p_other))
    assert eta_adv >= eta_other - 1e-9
