"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.kernels import ref as ref_lib
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gather_scores import gather_scores
from repro.kernels.sampled_loss import SAMPLED_KINDS, sampled_head_loss
from repro.kernels.segment_scores import segment_stats
from repro.kernels.tree_logprob import tree_logprob_all

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    def _inputs(self, b, h, sq, skv, hd, dtype, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (b, h, sq, hd), dtype)
        k = jax.random.normal(ks[1], (b, h, skv, hd), dtype)
        v = jax.random.normal(ks[2], (b, h, skv, hd), dtype)
        return q, k, v

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("sq,skv,hd", [
        (64, 64, 32), (128, 128, 64), (64, 256, 32), (32, 32, 16),
    ])
    def test_causal_sweep(self, sq, skv, hd, dtype):
        q, k, v = self._inputs(2, 3, sq, skv, hd, dtype)
        out = flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32,
                              interpret=True)
        ref = ref_lib.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        q, k, v = self._inputs(1, 2, 128, 128, 32, jnp.float32, seed=1)
        out = flash_attention(q, k, v, causal=True, window=window,
                              blk_q=32, blk_k=32, interpret=True)
        ref = ref_lib.flash_attention_ref(q, k, v, causal=True,
                                          window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        q, k, v = self._inputs(1, 2, 64, 64, 32, jnp.float32, seed=2)
        out = flash_attention(q, k, v, causal=True, softcap=50.0,
                              blk_q=32, blk_k=32, interpret=True)
        ref = ref_lib.flash_attention_ref(q, k, v, causal=True,
                                          softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_decode_shape(self):
        """Sq=1 against a long KV (end-aligned positions)."""
        q, k, v = self._inputs(2, 2, 1, 256, 32, jnp.float32, seed=3)
        out = flash_attention(q, k, v, causal=True, blk_q=1, blk_k=64,
                              interpret=True)
        ref = ref_lib.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_model_attention_semantics(self):
        """Kernel mask semantics == the model's einsum attention."""
        from repro.models.layers import _softcap
        q, k, v = self._inputs(1, 2, 64, 64, 16, jnp.float32, seed=4)
        out = flash_attention(q, k, v, causal=True, window=24,
                              blk_q=16, blk_k=16, interpret=True)
        # direct reference with the model's mask construction
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(16.0)
        pos = jnp.arange(64)
        delta = pos[:, None] - pos[None, :]
        valid = (delta >= 0) & (delta < 24)
        probs = jax.nn.softmax(jnp.where(valid, logits, -1e30), -1)
        ref = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestTreeLogprob:
    @pytest.mark.parametrize("c,k,blk_c", [(64, 8, 16), (256, 16, 64),
                                           (1024, 4, 256), (128, 8, 128)])
    def test_sweep_vs_ref(self, c, k, blk_c):
        t = tree_lib.init_tree(jax.random.PRNGKey(0), c, k, scale=0.8)
        x = jax.random.normal(jax.random.PRNGKey(1), (32, k))
        out = tree_logprob_all(t.w, t.b, x, blk_b=16, blk_c=blk_c,
                               interpret=True)
        ref = ref_lib.tree_logprob_all_ref(t.w, t.b, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_core_tree_path(self):
        """Kernel output (leaf order) == core log_prob_all (label order)."""
        c, k = 37, 6
        t = tree_lib.init_tree(jax.random.PRNGKey(2), c, k, scale=0.5)
        x = jax.random.normal(jax.random.PRNGKey(3), (16, k))
        out = tree_logprob_all(t.w, t.b, x, blk_b=16, blk_c=16,
                               interpret=True)
        core = tree_lib.log_prob_all(t, x)           # (B, C) label order
        out_labels = jnp.take(out, t.label_to_leaf, axis=-1)
        np.testing.assert_allclose(np.asarray(out_labels), np.asarray(core),
                                   rtol=1e-5, atol=1e-5)

    def test_bfloat16_inputs(self):
        c, k = 128, 8
        t = tree_lib.init_tree(jax.random.PRNGKey(4), c, k, scale=0.5)
        x = jax.random.normal(jax.random.PRNGKey(5), (16, k), jnp.bfloat16)
        out = tree_logprob_all(t.w, t.b, x, blk_b=16, blk_c=32,
                               interpret=True)
        ref = ref_lib.tree_logprob_all_ref(t.w, t.b,
                                           x.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-2, atol=5e-2)


class TestGatherScores:
    @pytest.mark.parametrize("c,kdim,t,n", [(64, 16, 32, 2), (512, 32, 64, 4),
                                            (128, 8, 256, 1)])
    def test_sweep_vs_ref(self, c, kdim, t, n):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        w = jax.random.normal(ks[0], (c, kdim))
        b = jax.random.normal(ks[1], (c,))
        h = jax.random.normal(ks[2], (t, kdim))
        ids = jax.random.randint(ks[3], (t, n), 0, c)
        out = gather_scores(w, b, h, ids, blk_t=16, interpret=True)
        ref = ref_lib.gather_scores_ref(w, b, h, ids)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_bfloat16_table(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        w = jax.random.normal(ks[0], (128, 16), jnp.bfloat16)
        b = jnp.zeros((128,), jnp.bfloat16)
        h = jax.random.normal(ks[2], (32, 16), jnp.bfloat16)
        ids = jax.random.randint(ks[3], (32, 2), 0, 128)
        out = gather_scores(w, b, h, ids, blk_t=16, interpret=True)
        ref = ref_lib.gather_scores_ref(w, b, h, ids)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-2, atol=3e-2)


class TestSampledLoss:
    """The fused sampled-head loss kernel (fwd + bwd in one row pass) vs
    the unfused gather→einsum→loss→coefficient oracle."""

    def _inputs(self, c, kdim, t, m, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        w = jax.random.normal(ks[0], (c, kdim))
        b = jax.random.normal(ks[1], (c,))
        h = jax.random.normal(ks[2], (t, kdim))
        ids = jax.random.randint(ks[3], (t, m), 0, c)
        lp = -jnp.abs(jax.random.normal(ks[4], (t, m)))
        return w, b, h, ids, lp

    @pytest.mark.parametrize("kind", SAMPLED_KINDS)
    def test_all_kinds_vs_ref(self, kind):
        c, kdim, t, m = 64, 16, 32, 3
        w, b, h, ids, lp = self._inputs(c, kdim, t, m)
        kw = dict(kind=kind, num_labels=c, reg=1e-3, softcap=25.0)
        out = sampled_head_loss(w, b, h, ids, lp, blk_t=8, interpret=True,
                                **kw)
        ref = ref_lib.sampled_head_loss_ref(w, b, h, ids, lp, **kw)
        for o, r, name in zip(out, ref, ["loss", "coeff", "xi", "dh"]):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{kind}/{name}")

    @pytest.mark.parametrize("t,blk_t", [(30, 8), (7, 16), (64, 64)])
    def test_ragged_t_padding(self, t, blk_t):
        """T not divisible by blk_t: padded rows must not leak into the
        sliced outputs."""
        c, kdim, m = 32, 8, 2
        w, b, h, ids, lp = self._inputs(c, kdim, t, m, seed=1)
        kw = dict(kind="adversarial_ns", num_labels=c)
        out = sampled_head_loss(w, b, h, ids, lp, blk_t=blk_t,
                                interpret=True, **kw)
        ref = ref_lib.sampled_head_loss_ref(w, b, h, ids, lp, **kw)
        for o, r in zip(out, ref):
            assert o.shape == r.shape
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=1e-5, atol=1e-5)

    def test_accidental_hit_masking(self):
        """sampled_softmax: a negative equal to the positive is masked out
        of the candidate set — zero coefficient in kernel and ref."""
        c, kdim, t, m = 16, 8, 8, 3
        w, b, h, ids, lp = self._inputs(c, kdim, t, m, seed=2)
        ids = ids.at[:, 1].set(ids[:, 0])           # force collisions
        kw = dict(kind="sampled_softmax", num_labels=c)
        out = sampled_head_loss(w, b, h, ids, lp, blk_t=8, interpret=True,
                                **kw)
        ref = ref_lib.sampled_head_loss_ref(w, b, h, ids, lp, **kw)
        np.testing.assert_allclose(np.asarray(out[1][:, 1]), 0.0)
        for o, r in zip(out, ref):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=1e-5, atol=1e-5)

    def test_sparse_head_loss_kernel_routing(self):
        """heads.sparse_head_loss(use_kernel=True) == the jnp path, and
        ops.use_pallas(False) A/B routes to the reference."""
        from repro.core import heads as heads_lib
        from repro.core.heads import Generator, HeadConfig
        from repro.kernels import ops

        c, kdim, kg, bsz = 32, 16, 4, 24
        tr = tree_lib.init_tree(jax.random.PRNGKey(0), c, kg, scale=0.5)
        cfg = HeadConfig(num_labels=c, kind="adversarial_ns", n_neg=3,
                         reg=1e-3)
        params = heads_lib.init_head_params(jax.random.PRNGKey(1), c,
                                            kdim, scale=0.3)
        h = jax.random.normal(jax.random.PRNGKey(2), (bsz, kdim))
        xg = jax.random.normal(jax.random.PRNGKey(3), (bsz, kg))
        y = jax.random.randint(jax.random.PRNGKey(4), (bsz,), 0, c)
        rng = jax.random.PRNGKey(6)
        args = (cfg, params, Generator(tree=tr), h, xg, y, rng)
        jnp_path = heads_lib.sparse_head_loss(*args, softcap=30.0)
        ker_path = heads_lib.sparse_head_loss(*args, softcap=30.0,
                                              use_kernel=True)
        ops.use_pallas(False)
        try:
            ref_path = heads_lib.sparse_head_loss(*args, softcap=30.0,
                                                  use_kernel=True)
        finally:
            ops.use_pallas(True)
        for a, b2 in ((jnp_path, ker_path), (ref_path, ker_path)):
            np.testing.assert_allclose(float(a[0]), float(b2[0]),
                                       rtol=1e-5)
            np.testing.assert_allclose(np.asarray(a[2].dw),
                                       np.asarray(b2[2].dw),
                                       rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b2[3]),
                                       rtol=1e-4, atol=1e-5)


class TestSegmentStats:
    """The genfit segment-reduction kernel vs jax.ops.segment_sum."""

    @pytest.mark.parametrize("n,d,s", [(300, 17, 8), (1024, 4, 64),
                                       (37, 1, 5), (513, 32, 128)])
    def test_sweep_vs_ref(self, n, d, s):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        vals = jax.random.normal(ks[0], (n, d))
        seg = jax.random.randint(ks[1], (n,), 0, s)
        out = segment_stats(vals, seg, s, blk_n=128, interpret=True)
        ref = ref_lib.segment_stats_ref(vals, seg, s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_out_of_range_ids_dropped(self):
        """Padding rows carry id == S and must contribute nothing."""
        vals = jnp.ones((16, 3))
        seg = jnp.concatenate([jnp.zeros((8,), jnp.int32),
                               jnp.full((8,), 4, jnp.int32)])
        out = segment_stats(vals, seg, 4, blk_n=8, interpret=True)
        expect = np.zeros((4, 3))
        expect[0] = 8.0
        np.testing.assert_allclose(np.asarray(out), expect)

    def test_levelwise_fit_with_kernel_matches_default(self):
        """FitConfig(use_kernel=True) routes the fit's reductions through
        the kernel; the fitted tree must match the jnp path bit-for-bit
        in interpret mode."""
        from repro.core.tree_fit import FitConfig
        from repro.genfit import fit_tree_levelwise
        rng = np.random.default_rng(0)
        c, k, n = 8, 4, 400
        centers = rng.standard_normal((c, k)) * 3.0
        y = rng.integers(0, c, n)
        x = (centers[y] + rng.standard_normal((n, k))).astype(np.float32)
        t_jnp = fit_tree_levelwise(x, y, c, config=FitConfig(seed=0))
        t_ker = fit_tree_levelwise(x, y, c,
                                   config=FitConfig(seed=0,
                                                    use_kernel=True))
        np.testing.assert_allclose(np.asarray(t_jnp.w),
                                   np.asarray(t_ker.w),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(t_jnp.label_to_leaf),
                                      np.asarray(t_ker.label_to_leaf))
