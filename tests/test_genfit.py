"""repro.genfit: level-parallel fit parity with the sequential oracle,
tree invariants, incremental/sharded refits, and refresh determinism."""
import dataclasses
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import tree as tree_lib
from repro.core.tree_fit import FitConfig, fit_tree, tree_log_likelihood
from repro.genfit import (fit_tree_levelwise, fit_tree_sharded,
                          label_counts, refit_params, refresh_tree,
                          subtree_drift)
from repro.genfit.incremental import perm_from_tree, real_leaf_mask

jax.config.update("jax_enable_x64", False)


def _clustered(seed=0, n=3000, c=16, k=6, spread=3.0, n_held=1000,
               observed=None):
    """Labels live in feature clusters; optional cap on observed labels."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((c, k)) * spread
    y = rng.integers(0, observed or c, n)
    x = (centers[y] + rng.standard_normal((n, k))).astype(np.float32)
    yh = rng.integers(0, observed or c, n_held)
    xh = (centers[yh] + rng.standard_normal((n_held, k))).astype(
        np.float32)
    return x, y, xh, yh


def _check_invariants(tree, num_labels, x):
    """Leaf<->label bijection, padded mass ~ 0, path == dense log-probs."""
    l2l = np.asarray(tree.label_to_leaf)
    assert len(np.unique(l2l)) == num_labels
    inv = np.asarray(tree.leaf_to_label)[l2l]
    np.testing.assert_array_equal(inv, np.arange(num_labels))
    xs = jnp.asarray(x[:64])
    mass = np.asarray(tree_lib.prob_mass_real(tree, xs))
    np.testing.assert_allclose(mass, 1.0, atol=1e-4)
    y = jnp.asarray(np.arange(min(num_labels, 32)) % num_labels)
    lp = np.asarray(tree_lib.log_prob(tree, xs[:len(y)], y))
    lp_all = np.asarray(tree_lib.log_prob_all(tree, xs[:len(y)]))
    np.testing.assert_allclose(
        lp, np.take_along_axis(lp_all, np.asarray(y)[:, None], -1)[:, 0],
        rtol=1e-4, atol=1e-4)


class TestLevelwiseParity:
    @pytest.mark.parametrize("c", [13, 16, 64])
    def test_heldout_ll_matches_sequential(self, c):
        """The acceptance property: level-parallel == sequential-reference
        held-out log-likelihood within tolerance (both fits are local
        optima from different inits; 5% relative covers that spread, and
        both must clearly beat uniform)."""
        x, y, xh, yh = _clustered(seed=c, c=c, n=4000)
        cfg = FitConfig(seed=0)
        ll_seq = tree_log_likelihood(fit_tree(x, y, c, config=cfg), xh, yh)
        ll_lvl = tree_log_likelihood(
            fit_tree_levelwise(x, y, c, config=cfg), xh, yh)
        assert ll_lvl > -np.log(c) + 0.5, "must clearly beat uniform"
        assert abs(ll_lvl - ll_seq) <= 0.05 * abs(ll_seq) + 0.02, (
            f"levelwise {ll_lvl:.4f} vs sequential {ll_seq:.4f}")

    def test_weighted_matches_expanded(self):
        rng = np.random.default_rng(3)
        x_u = rng.standard_normal((40, 4)).astype(np.float32)
        y_u = rng.integers(0, 8, 40)
        w = rng.integers(1, 4, 40)
        cfg = FitConfig(seed=5)
        t_w = fit_tree_levelwise(x_u, y_u, 8,
                                 sample_weight=w.astype(np.float64),
                                 config=cfg)
        t_e = fit_tree_levelwise(np.repeat(x_u, w, axis=0),
                                 np.repeat(y_u, w, axis=0), 8, config=cfg)
        np.testing.assert_allclose(np.asarray(t_w.w), np.asarray(t_e.w),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(t_w.label_to_leaf),
                                      np.asarray(t_e.label_to_leaf))

    def test_zero_weight_points_are_invisible(self):
        """The subtree fitters pad point counts with weight-0 rows; those
        must not change the fit at all."""
        x, y, _, _ = _clustered(seed=1, c=16, n=1500)
        cfg = FitConfig(seed=0)
        t0 = fit_tree_levelwise(x, y, 16, config=cfg)
        x2 = np.concatenate([x, np.zeros((64, x.shape[1]), np.float32)])
        y2 = np.concatenate([y, np.zeros(64, y.dtype)])
        w2 = np.concatenate([np.ones(len(y), np.float32),
                             np.zeros(64, np.float32)])
        t1 = fit_tree_levelwise(x2, y2, 16, sample_weight=w2, config=cfg)
        np.testing.assert_array_equal(np.asarray(t0.w), np.asarray(t1.w))
        np.testing.assert_array_equal(np.asarray(t0.label_to_leaf),
                                      np.asarray(t1.label_to_leaf))

    def test_deterministic(self):
        x, y, _, _ = _clustered(seed=2, c=32, n=2000)
        cfg = FitConfig(seed=7)
        t0 = fit_tree_levelwise(x, y, 32, config=cfg)
        t1 = fit_tree_levelwise(x, y, 32, config=cfg)
        np.testing.assert_array_equal(np.asarray(t0.w), np.asarray(t1.w))
        np.testing.assert_array_equal(np.asarray(t0.b), np.asarray(t1.b))
        np.testing.assert_array_equal(np.asarray(t0.label_to_leaf),
                                      np.asarray(t1.label_to_leaf))

    def test_unobserved_labels_and_padding(self):
        """Non-power-of-two C with never-observed labels: bijection holds,
        padding mass ~ 0, sampling never returns >= C."""
        x, y, _, _ = _clustered(seed=4, c=13, n=900, observed=11)
        t = fit_tree_levelwise(x, y, 13, config=FitConfig(seed=1))
        _check_invariants(t, 13, x)
        ids, _ = tree_lib.sample(t, jnp.asarray(x[:2000]),
                                 jax.random.PRNGKey(0))
        assert int(jnp.max(ids)) < 13


@settings(max_examples=10, deadline=None)
@given(c=st.integers(2, 40), k=st.integers(1, 8),
       seed=st.integers(0, 2**20))
def test_property_levelwise_invariants(c, k, seed):
    """Property: for any clustered problem, the level-parallel fit yields
    a bijective, normalized tree whose path log-probs match the dense
    evaluation."""
    rng = np.random.default_rng(seed)
    n = 300
    centers = rng.standard_normal((c, k)) * 2.0
    y = rng.integers(0, c, n)
    x = (centers[y] + rng.standard_normal((n, k))).astype(np.float32)
    t = fit_tree_levelwise(x, y, c, config=FitConfig(seed=seed % 17))
    _check_invariants(t, c, x)


class TestIncremental:
    def test_refit_preserves_structure_and_recovers_ll(self):
        x, y, _, _ = _clustered(seed=0, c=32, n=4000, k=8)
        cfg = FitConfig(seed=0)
        t0 = fit_tree_levelwise(x, y, 32, config=cfg)
        rng = np.random.default_rng(9)
        x2 = x + 0.3 * rng.standard_normal(x.shape).astype(np.float32)
        t1 = refit_params(t0, x2, y, 32, config=cfg)
        np.testing.assert_array_equal(np.asarray(t1.label_to_leaf),
                                      np.asarray(t0.label_to_leaf))
        _check_invariants(t1, 32, x2)
        ll_warm = tree_log_likelihood(t1, x2, y)
        ll_cold = tree_log_likelihood(
            fit_tree_levelwise(x2, y, 32, config=cfg), x2, y)
        ll_stale = tree_log_likelihood(t0, x2, y)
        assert ll_warm >= ll_stale - 1e-6
        assert ll_warm > ll_cold - 0.1 * abs(ll_cold), (
            f"warm {ll_warm:.4f} vs cold {ll_cold:.4f}")

    def test_refit_deterministic(self):
        x, y, _, _ = _clustered(seed=1, c=16, n=1200)
        cfg = FitConfig(seed=0)
        t0 = fit_tree_levelwise(x, y, 16, config=cfg)
        a = refit_params(t0, x, y, 16, config=cfg)
        b = refit_params(t0, x, y, 16, config=cfg)
        np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
        np.testing.assert_array_equal(np.asarray(a.b), np.asarray(b.b))

    def test_drift_detection_and_subtree_refresh(self):
        """Kill the data of half the labels: the subtrees owning them
        drift (TV -> large), a refresh refits them locally, and the
        result stays a valid tree."""
        x, y, _, _ = _clustered(seed=5, c=32, n=4000, k=8)
        cfg = FitConfig(seed=0)
        t0 = fit_tree_levelwise(x, y, 32, config=cfg)
        cnt0 = label_counts(y, 32)
        keep = y < 16                 # labels 16.. vanish from the stream
        x2, y2 = x[keep], y[keep]
        drifts = subtree_drift(cnt0, label_counts(y2, 32), t0,
                               split_depth=2)
        assert drifts.max() > 0.1, drifts
        t1, cnt1 = refresh_tree(t0, x2, y2, 32, config=cfg,
                                prev_counts=cnt0, drift_threshold=0.1,
                                split_depth=2)
        _check_invariants(t1, 32, x2)
        np.testing.assert_allclose(cnt1, label_counts(y2, 32))

    def test_perm_roundtrip(self):
        x, y, _, _ = _clustered(seed=6, c=13, n=600)
        t = fit_tree_levelwise(x, y, 13, config=FitConfig(seed=2))
        perm = perm_from_tree(t, 13)
        assert sorted(perm.tolist()) == list(range(16))
        real = real_leaf_mask(t, 13)
        assert int(real.sum()) == 13
        np.testing.assert_array_equal(
            perm[real], np.asarray(t.leaf_to_label)[real])


class TestSharded:
    def test_sharded_matches_serial_and_threaded(self):
        """Subtree fan-out is deterministic: serial and threaded executors
        produce bit-identical trees, and the result keeps the invariants
        and the quality of the unsharded fit."""
        x, y, xh, yh = _clustered(seed=0, c=64, n=6000, k=8)
        cfg = FitConfig(seed=0)
        t_serial = fit_tree_sharded(x, y, 64, config=cfg, split_depth=2)
        with ThreadPoolExecutor(2) as ex:
            t_thread = fit_tree_sharded(x, y, 64, config=cfg,
                                        split_depth=2, executor=ex)
        np.testing.assert_array_equal(np.asarray(t_serial.w),
                                      np.asarray(t_thread.w))
        np.testing.assert_array_equal(
            np.asarray(t_serial.label_to_leaf),
            np.asarray(t_thread.label_to_leaf))
        _check_invariants(t_serial, 64, x)
        ll_sharded = tree_log_likelihood(t_serial, xh, yh)
        ll_lvl = tree_log_likelihood(
            fit_tree_levelwise(x, y, 64, config=cfg), xh, yh)
        assert abs(ll_sharded - ll_lvl) <= 0.1 * abs(ll_lvl) + 0.02

    def test_split_depth_edges(self):
        x, y, _, _ = _clustered(seed=2, c=8, n=500, k=4)
        cfg = FitConfig(seed=0)
        # split at the full depth = plain levelwise fit
        t_full = fit_tree_sharded(x, y, 8, config=cfg, split_depth=10)
        t_lvl = fit_tree_levelwise(x, y, 8, config=cfg)
        np.testing.assert_array_equal(np.asarray(t_full.w),
                                      np.asarray(t_lvl.w))
        t0 = fit_tree_sharded(x, y, 8, config=cfg, split_depth=0)
        _check_invariants(t0, 8, x)

    def test_round_robin_shard(self):
        from repro.parallel import round_robin_shard
        all_items = sorted(round_robin_shard(10, 0, 3)
                           + round_robin_shard(10, 1, 3)
                           + round_robin_shard(10, 2, 3))
        assert all_items == list(range(10))
