"""End-to-end behaviour of the paper's system (§2.2 steps 1-3 in miniature):
fit generator -> train discriminator with adversarial negatives ->
debiased predictions beat biased ones and uniform sampling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heads as heads_lib
from repro.core.heads import Generator, HeadConfig
from repro.core.tree_fit import FitConfig, fit_tree, pca_projection
from repro.core.xc_train import train_linear_head
from repro.data.synthetic import ClusteredXCSpec, make_clustered_xc


def test_paper_pipeline_end_to_end():
    c, kdim, k_gen = 256, 32, 8
    spec = ClusteredXCSpec(num_labels=c, feature_dim=kdim, seed=0)
    x_tr, y_tr, x_te, y_te = make_clustered_xc(spec, 6000, 1500)

    # Step 1: generator (paper §3).
    proj, mean = pca_projection(x_tr, k_gen)
    tree = fit_tree((x_tr - mean) @ proj, y_tr, c,
                    config=FitConfig(reg=0.1, seed=0))

    x = jnp.asarray(x_tr)
    y = jnp.asarray(y_tr, jnp.int32)
    xg = jnp.asarray((x_tr - mean) @ proj, jnp.float32)
    xte = jnp.asarray(x_te)
    yte = jnp.asarray(y_te, jnp.int32)
    xgte = jnp.asarray((x_te - mean) @ proj, jnp.float32)

    # Step 2: adversarial negative sampling (Eq. 6) vs uniform, equal
    # budget, minibatch Adagrad (paper regime).
    accs = {}
    for kind, gen in [("adversarial_ns", Generator(tree=tree)),
                      ("uniform_ns", Generator())]:
        cfg = HeadConfig(num_labels=c, kind=kind, n_neg=1, reg=1e-4)
        params = train_linear_head(cfg, gen, x, xg, y, lr=0.1, steps=150,
                                   batch_size=256)
        accs[kind] = float(heads_lib.predictive_accuracy(
            cfg, params, gen, xte, xgte, yte))
        if kind == "adversarial_ns":
            # Step 3: bias removal must matter.
            cfg_b = HeadConfig(num_labels=c, kind=kind, debias=False)
            acc_biased = float(heads_lib.predictive_accuracy(
                cfg_b, params, gen, xte, xgte, yte))
            assert accs[kind] > acc_biased + 0.05, (
                "Eq. 5 debiasing should improve accuracy materially",
                accs[kind], acc_biased)

    assert accs["adversarial_ns"] > accs["uniform_ns"], accs
    assert accs["adversarial_ns"] > 0.3, accs
