"""NegativeSampler protocol properties (repro.core.samplers).

Every proposal must (a) be a distribution — exp(log_prob_all) sums to 1,
(b) report the exact probability of what it actually draws — sample
frequencies match log_prob (chi-square), and (c) satisfy Eq. 5: at the
nonparametric optimum xi = log p_D - log p_n, the debiased predictions
recover p_D regardless of which proposal trained the head. Plus the
regression tests for the freq-path CDF bug this PR fixed (boundary draws
resolving to the wrong bucket; zero-count labels drawn from smoothing
mass).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heads as heads_lib
from repro.core import samplers as samplers_lib
from repro.core.heads import Generator, HeadConfig, HeadParams
from repro.core.samplers import SAMPLER_KINDS
from repro.core.xc_train import train_linear_head

C, KDIM, N_X = 24, 4, 6


def _problem(seed=0):
    """Conditional testbed: N_X context vectors, known p_D(.|x), and a
    fitting snapshot of (x_gen, y ~ p_D) pairs."""
    rng = np.random.default_rng(seed)
    ctx = rng.standard_normal((N_X, KDIM)).astype(np.float32)
    emb = rng.standard_normal((C, KDIM)).astype(np.float32)
    logits = 1.5 * ctx @ emb.T
    p_d = np.exp(logits - logits.max(-1, keepdims=True))
    p_d /= p_d.sum(-1, keepdims=True)
    xs = rng.integers(0, N_X, 4000)
    u = rng.random((4000, 1))
    ys = (p_d[xs].cumsum(-1) < u).sum(-1).clip(0, C - 1)
    return (jnp.asarray(ctx), jnp.asarray(p_d, jnp.float32),
            jnp.asarray(ctx[xs]), jnp.asarray(ys, jnp.int32))


@pytest.fixture(scope="module")
def fitted():
    ctx, p_d, x_fit, y_fit = _problem()
    samplers = {k: samplers_lib.fit_sampler(k, x_fit, y_fit, C, seed=0)
                for k in SAMPLER_KINDS}
    return ctx, p_d, samplers


class TestProtocolProperties:

    def test_log_prob_all_normalizes(self, fitted):
        ctx, _, samplers = fitted
        for kind, s in samplers.items():
            lse = np.asarray(jax.nn.logsumexp(s.log_prob_all(ctx), -1))
            assert np.abs(lse).max() < 1e-3, (kind, lse)

    def test_log_prob_matches_log_prob_all(self, fitted):
        ctx, _, samplers = fitted
        y = jnp.asarray(np.arange(N_X) % C, jnp.int32)
        for kind, s in samplers.items():
            dense = jnp.take_along_axis(s.log_prob_all(ctx),
                                        y[:, None], -1)[:, 0]
            single = s.log_prob(ctx, y)
            np.testing.assert_allclose(np.asarray(single),
                                       np.asarray(dense),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=kind)

    def test_sample_reports_its_own_log_prob(self, fitted):
        ctx, _, samplers = fitted
        for kind, s in samplers.items():
            ids, lp = s.sample(jax.random.PRNGKey(7), ctx, (N_X, 5))
            ref = s.log_prob(ctx, ids)
            np.testing.assert_allclose(np.asarray(lp), np.asarray(ref),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=kind)
            assert np.asarray(ids).min() >= 0
            assert np.asarray(ids).max() < C

    def test_sample_frequencies_match_log_prob(self, fitted):
        """Chi-square GOF of draws against exp(log_prob_all), one context
        per sampler, deterministic keys (no flake)."""
        ctx, _, samplers = fitted
        n = 40_000
        for i, (kind, s) in enumerate(samplers.items()):
            x = ctx[i % N_X][None, :]
            p = np.asarray(jnp.exp(s.log_prob_all(x)))[0].astype(np.float64)
            p /= p.sum()
            ids = np.asarray(s.sample(jax.random.PRNGKey(100 + i),
                                      x, (1, n))[0])[0]
            obs = np.bincount(ids, minlength=C).astype(np.float64)
            exp = n * p
            keep = exp >= 5.0          # classic chi-square validity rule
            chi2 = float((((obs - exp) ** 2 / np.maximum(exp, 1e-12))
                          [keep]).sum())
            # Zero-probability bins must be literally unsampled.
            assert obs[exp < 1e-6].sum() == 0, kind
            dof = int(keep.sum()) - 1
            # P(chi2 > dof + 5*sqrt(2*dof)) is ~1e-6 — generous but real.
            assert chi2 < dof + 5.0 * np.sqrt(2.0 * dof), \
                (kind, chi2, dof)


class TestUnigramCdfBugfix:
    """The freq path used searchsorted(side='left') over a CDF built from
    1e-12-smoothed counts: a draw landing exactly on a boundary resolved
    to the bucket *below* it, and count-0 labels carried smoothing mass so
    they could be drawn. Both are fixed in unigram_from_counts."""

    COUNTS = np.array([5, 0, 3, 0, 0, 2, 0, 0, 0, 0], np.float32)

    def test_zero_count_labels_never_sampled(self):
        s = samplers_lib.unigram_from_counts(self.COUNTS)
        x = jnp.zeros((1, 2))
        ids = np.asarray(s.sample(jax.random.PRNGKey(0), x, (1, 50_000))[0])
        drawn = set(np.unique(ids))
        assert drawn <= {0, 2, 5}, drawn

    def test_boundary_draws_map_to_positive_count_labels(self):
        """u exactly ON a CDF boundary belongs to the bucket above it —
        the one whose probability interval starts there."""
        s = samplers_lib.unigram_from_counts(self.COUNTS)
        cdf = np.asarray(s.freq_cdf)
        # Interior edges only: draws come from [0, 1), so u == 1.0 can
        # never occur and edges sitting at 1.0 are out of scope.
        boundaries = jnp.asarray(cdf[:-1][cdf[:-1] < 1.0])
        ids = np.asarray(jnp.clip(
            jnp.searchsorted(s.freq_cdf, boundaries, side="right"),
            0, len(self.COUNTS) - 1))
        assert (self.COUNTS[ids] > 0).all(), ids

    def test_cdf_last_entry_exactly_one(self):
        s = samplers_lib.unigram_from_counts(self.COUNTS)
        assert float(s.freq_cdf[-1]) == 1.0

    def test_heads_freq_generator_delegates(self):
        """make_freq_generator and the protocol path share one definition:
        zero-count labels are unreachable through the heads shim too."""
        gen = heads_lib.make_freq_generator(jnp.asarray(self.COUNTS))
        cfg = HeadConfig(num_labels=len(self.COUNTS), kind="freq_ns",
                         n_neg=4)
        ids, _ = heads_lib.sample_negatives(
            cfg, gen, jnp.zeros((2000, 2)), jax.random.PRNGKey(3),
            (2000,))
        drawn = set(np.unique(np.asarray(ids)))
        assert drawn <= {0, 2, 5}, drawn


class TestEq5DebiasInvariance:
    """At the optimum xi = log p_D - log p_n(sampler), the debiased
    predictions are p_D for EVERY sampler: proposal choice moves the
    training signal (Theorem 2), never the answer (Theorem 1)."""

    def test_predictive_topk_invariant_to_sampler(self, fitted):
        ctx, p_d, samplers = fitted
        h = jnp.eye(N_X, dtype=jnp.float32)          # one-hot contexts
        cfg = HeadConfig(num_labels=C, kind="adversarial_ns", n_neg=1)
        log_pd = jnp.log(p_d)
        ref_labels = None
        for kind, s in samplers.items():
            # Free score table at the Eq. 5 optimum for THIS proposal.
            w = (log_pd - s.log_prob_all(ctx)).T      # (C, N_X)
            params = HeadParams(w=w, b=jnp.zeros((C,)))
            # beam >= C_pad makes the tree path exhaustive, so the tree
            # sampler's beam result must equal the dense fallback of the
            # non-tree samplers exactly.
            top, labels = heads_lib.predictive_topk(
                cfg, params, Generator(), h, ctx, topk=3, beam=64,
                sampler=s)
            np.testing.assert_allclose(
                np.asarray(top),
                np.sort(np.asarray(log_pd), -1)[:, ::-1][:, :3],
                rtol=1e-4, atol=1e-4, err_msg=kind)
            if ref_labels is None:
                ref_labels = np.asarray(labels)
            else:
                np.testing.assert_array_equal(np.asarray(labels),
                                              ref_labels, err_msg=kind)

    def test_predictive_accuracy_recovers_p_d_argmax(self, fitted):
        ctx, p_d, samplers = fitted
        h = jnp.eye(N_X, dtype=jnp.float32)
        cfg = HeadConfig(num_labels=C, kind="adversarial_ns", n_neg=1)
        y_star = jnp.argmax(p_d, -1)
        for kind, s in samplers.items():
            w = (jnp.log(p_d) - s.log_prob_all(ctx)).T
            params = HeadParams(w=w, b=jnp.zeros((C,)))
            acc = heads_lib.predictive_accuracy(cfg, params, Generator(),
                                                h, ctx, y_star, sampler=s)
            assert float(acc) == 1.0, kind


class TestSamplerMatrixTrains:
    """test-fast lane matrix: every sampler drives a few real training
    steps of the ns objective (sparse AND dense head updates) to a finite
    loss and sane predictions."""

    @pytest.mark.parametrize("kind", SAMPLER_KINDS)
    @pytest.mark.parametrize("head_update", ("sparse", "dense"))
    def test_trains_finite(self, fitted, kind, head_update):
        ctx, p_d, samplers = fitted
        s = samplers[kind]
        rng = np.random.default_rng(5)
        xs = rng.integers(0, N_X, 1024)
        u = rng.random((1024, 1))
        ys = (np.asarray(p_d)[xs].cumsum(-1) < u).sum(-1).clip(0, C - 1)
        x = jnp.asarray(np.eye(N_X, dtype=np.float32)[xs])
        xg = ctx[jnp.asarray(xs)]
        y = jnp.asarray(ys, jnp.int32)
        cfg = HeadConfig(num_labels=C, kind="adversarial_ns", n_neg=2)
        params = train_linear_head(cfg, Generator(), x, xg, y, lr=0.2,
                                   steps=25, batch_size=128,
                                   head_update=head_update, sampler=s)
        ll = heads_lib.predictive_log_likelihood(
            cfg, params, Generator(), x, xg, y, sampler=s)
        assert np.isfinite(float(ll)), (kind, head_update)
        assert float(ll) > -np.log(C), (kind, head_update, float(ll))
