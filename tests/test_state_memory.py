"""Memory-cheap head state (DESIGN.md §11): SM3 factored covers, bf16 /
int8 accumulator storage, exact lazy AdamW, and the sparse embedding
gather.

Pins the PR's guarantees:
  * sm3 sparse touched-rows == sm3 dense, everywhere (monotone-max covers
    make the factored update exactly sparse-safe),
  * bf16-stored accumulators track the fp32 trajectory within tolerance;
    int8 + per-row scale stays finite and converges,
  * lazy AdamW with per-row catch-up == dense AdamW under *random* touch
    patterns (hypothesis property), not just the all-touched case,
  * the input-embedding SparseRows gather == dense embedding grads,
  * global_norm is fp32-correct over Sm3Cover / QuantizedRows leaves,
  * checkpoints round-trip the new state bit-stably (bf16 view save) and
    a mid-run resume replays bit-exactly,
  * the sharded (mesh) sm3/adamw row update == the unsharded one,
  * _fit_snapshot decouples the background generator fit from donation,
  * head_state_bytes shows the >= 4x adamw/fp32 -> sm3/bf16 reduction.
"""
import functools
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.core import heads as heads_lib
from repro.core import tree as tree_lib
from repro.core.heads import Generator, HeadConfig, HeadParams
from repro.optim import (OptimizerConfig, QuantizedRows, Sm3Cover,
                         apply_updates, dequantize_rows, global_norm,
                         head_state_bytes, init_opt_state, load_rows,
                         quantize_rows, store_rows)
from repro.optim import sparse as sparse_lib
from repro.optim.sparse import SparseRows, accumulate_embed_rows

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
C, K, KG = 16, 12, 4


def run_py(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=ROOT)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\n" \
                                 f"STDERR:\n{proc.stderr[-4000:]}"
    return proc.stdout


def _gen(c=C, seed=0):
    return Generator(tree=tree_lib.init_tree(jax.random.PRNGKey(seed), c,
                                             KG, scale=0.5))


def _problem(batch=48, seed=0, c=C):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    h = jax.random.normal(ks[0], (batch, K))
    xg = jax.random.normal(ks[1], (batch, KG))
    y = jax.random.randint(ks[2], (batch,), 0, c)
    params = heads_lib.init_head_params(ks[3], c, K, scale=0.3)
    return params, h, xg, y


def _dense_grads(cfg, params, gen, h, xg, y, rng):
    return jax.grad(lambda pp: heads_lib.head_loss(
        cfg, pp, gen, h, xg, y, rng)[0])(params)


def _random_sparse(rng, c, k, touch_all=False, sentinel=True):
    """A SparseRows grad over a random unique subset of rows (optionally
    all rows), with a zero-valued sentinel slot (id == c, the dedupe
    fill) riding along as the head path always produces one."""
    if touch_all:
        ids = np.arange(c)
    else:
        ids = rng.choice(c, size=int(rng.integers(1, c)), replace=False)
    u = len(ids)
    dw = rng.standard_normal((u, k)).astype(np.float32)
    db = rng.standard_normal((u,)).astype(np.float32)
    if sentinel:
        ids = np.append(ids, c)
        dw = np.concatenate([dw, np.zeros((1, k), np.float32)])
        db = np.append(db, np.float32(0.0))
    sp = SparseRows(ids=jnp.asarray(ids, jnp.int32), dw=jnp.asarray(dw),
                    db=jnp.asarray(db))
    dwd = np.zeros((c, k), np.float32)
    dbd = np.zeros((c,), np.float32)
    dwd[ids[:u]] = dw[:u]
    dbd[ids[:u]] = db[:u]
    gd = HeadParams(w=jnp.asarray(dwd), b=jnp.asarray(dbd))
    return sp, gd


class TestSm3Parity:
    """SM3's monotone-max covers make the sparse path exact: a zero-grad
    row has nu' = min(row, col) <= row everywhere, so neither its param
    nor either cover can move — dense == sparse on ALL rows."""

    @pytest.mark.parametrize("state_dtype", ["fp32", "bf16"])
    def test_sparse_equals_dense_n_steps(self, state_dtype):
        cfg = HeadConfig(num_labels=C, kind="adversarial_ns", n_neg=2,
                         reg=1e-3)
        gen = _gen()
        params, h, xg, y = _problem()
        ocfg = OptimizerConfig(name="sm3", learning_rate=0.1,
                               clip_norm=1.0, state_dtype=state_dtype)
        pd = ps = params
        sd = ss = init_opt_state(ocfg, params)
        for s in range(5):
            r = jax.random.fold_in(jax.random.PRNGKey(11), s)
            gd = _dense_grads(cfg, pd, gen, h, xg, y, r)
            pd, sd, _ = apply_updates(ocfg, pd, gd, sd)
            _, _, srows, _ = heads_lib.sparse_head_loss(cfg, ps, gen, h,
                                                        xg, y, r)
            ps, ss, _ = apply_updates(ocfg, ps, srows, ss)
        np.testing.assert_allclose(np.asarray(ps.w), np.asarray(pd.w),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ps.b), np.asarray(pd.b),
                                   rtol=1e-5, atol=1e-6)
        # the factored state matches too (row cover in storage dtype)
        assert isinstance(ss.nu.w, Sm3Cover) and isinstance(sd.nu.w,
                                                            Sm3Cover)
        np.testing.assert_allclose(
            np.asarray(load_rows(ss.nu.w.row)),
            np.asarray(load_rows(sd.nu.w.row)), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ss.nu.w.col),
                                   np.asarray(sd.nu.w.col),
                                   rtol=1e-5, atol=1e-6)

    def test_untouched_rows_are_bitwise_frozen(self):
        rng = np.random.default_rng(3)
        ocfg = OptimizerConfig(name="sm3", learning_rate=0.1)
        params, _, _, _ = _problem(c=64)
        sp, _ = _random_sparse(rng, 64, K)
        p2, _, _ = apply_updates(ocfg, params, sp,
                                 init_opt_state(ocfg, params))
        touched = np.asarray(sp.ids)
        untouched = np.setdiff1d(np.arange(64), touched[touched < 64])
        np.testing.assert_array_equal(np.asarray(p2.w)[untouched],
                                      np.asarray(params.w)[untouched])


class TestStateDtype:
    def _trajectory(self, state_dtype, steps=12):
        cfg = HeadConfig(num_labels=C, kind="adversarial_ns", n_neg=2,
                         reg=1e-3)
        gen = _gen()
        params, h, xg, y = _problem()
        ocfg = OptimizerConfig(name="sm3", learning_rate=0.1,
                               state_dtype=state_dtype)
        opt = init_opt_state(ocfg, params)
        losses = []
        for s in range(steps):
            r = jax.random.fold_in(jax.random.PRNGKey(21), s)
            loss, _, srows, _ = heads_lib.sparse_head_loss(cfg, params,
                                                           gen, h, xg, y,
                                                           r)
            losses.append(float(loss))
            params, opt, _ = apply_updates(ocfg, params, srows, opt)
        return params, losses

    def test_bf16_storage_tracks_fp32(self):
        p32, l32 = self._trajectory("fp32")
        p16, l16 = self._trajectory("bf16")
        assert l32[-1] < l32[0] and l16[-1] < l16[0]
        assert abs(l16[-1] - l32[-1]) < 0.05, (l16[-1], l32[-1])
        np.testing.assert_allclose(np.asarray(p16.w), np.asarray(p32.w),
                                   rtol=5e-2, atol=5e-3)

    def test_int8_storage_runs_and_converges(self):
        # adamw exercises QuantizedRows mu (+ the 1-D bf16 fallback for
        # b and the int32 last rows) through the sparse gather. nu is
        # NEVER int8: linear per-row int8 zeroes entries below
        # rowmax/127 and 1/(sqrt(nu)+eps) then diverges (_nu_sd).
        cfg = HeadConfig(num_labels=C, kind="adversarial_ns", n_neg=2,
                         reg=1e-3)
        gen = _gen()
        params, h, xg, y = _problem()
        ocfg = OptimizerConfig(name="adamw", learning_rate=0.05,
                               state_dtype="int8")
        opt = init_opt_state(ocfg, params)
        assert isinstance(opt.mu.w, QuantizedRows)
        assert opt.mu.b.dtype == jnp.bfloat16        # 1-D int8 fallback
        assert opt.nu.w.dtype == jnp.bfloat16        # int8 degrades (nu)
        assert opt.nu.b.dtype == jnp.bfloat16
        losses = []
        for s in range(15):
            r = jax.random.fold_in(jax.random.PRNGKey(31), s)
            loss, _, srows, _ = heads_lib.sparse_head_loss(cfg, params,
                                                           gen, h, xg, y,
                                                           r)
            losses.append(float(loss))
            params, opt, _ = apply_updates(ocfg, params, srows, opt)
        assert np.isfinite(np.asarray(params.w)).all()
        assert np.mean(losses[-3:]) < np.mean(losses[:3])

    def test_store_load_rows_round_trip(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((6, 4)), jnp.float32)
        assert store_rows(x, "fp32") is x
        b16 = store_rows(x, "bf16")
        assert b16.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(load_rows(b16)),
                                      np.asarray(x.astype(jnp.bfloat16)
                                                 .astype(jnp.float32)))
        qr = store_rows(x, "int8")
        assert isinstance(qr, QuantizedRows)
        assert qr.q.dtype == jnp.int8 and qr.scale.shape == (6,)
        # per-row scale: worst-case error is amax/254 per row
        err = np.abs(np.asarray(dequantize_rows(qr)) - np.asarray(x))
        bound = np.abs(np.asarray(x)).max(axis=1, keepdims=True) / 127
        assert (err <= bound + 1e-7).all()
        # 1-D int8 falls back to bf16; zero rows dequantize to zero
        v = store_rows(jnp.ones((5,), jnp.float32), "int8")
        assert v.dtype == jnp.bfloat16
        z = quantize_rows(jnp.zeros((3, 2)))
        np.testing.assert_array_equal(np.asarray(dequantize_rows(z)), 0.0)
        np.testing.assert_array_equal(np.asarray(z.scale), 1.0)


class TestLazyAdamW:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_random_touch_patterns_match_dense(self, seed):
        """The exact-lazy catch-up (ROADMAP item (d)): rows idle for a
        random number of steps replay their missed momentum decay, bias
        correction, and weight decay on next touch. A final all-rows
        touch forces every row through catch-up; params must then equal
        dense AdamW's."""
        c, k, steps = 24, 6, 9
        rng = np.random.default_rng(seed)
        ocfg = OptimizerConfig(name="adamw", learning_rate=0.03,
                               weight_decay=0.2)
        params = HeadParams(
            w=jnp.asarray(rng.standard_normal((c, k)), jnp.float32),
            b=jnp.asarray(rng.standard_normal((c,)), jnp.float32))
        pd = ps = params
        sd = ss = init_opt_state(ocfg, params)
        for s in range(steps):
            sp, gd = _random_sparse(rng, c, k, touch_all=(s == steps - 1))
            pd, sd, _ = apply_updates(ocfg, pd, gd, sd)
            ps, ss, _ = apply_updates(ocfg, ps, sp, ss)
        np.testing.assert_allclose(np.asarray(ps.w), np.asarray(pd.w),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ps.b), np.asarray(pd.b),
                                   rtol=1e-5, atol=1e-6)

    def test_mixed_sparse_dense_steps_stay_exact(self):
        """Alternating sparse and dense grads on the SAME state: the
        dense branch must also run the catch-up (and stamp ``last``) or
        the alternation diverges."""
        c, k = 12, 5
        rng = np.random.default_rng(7)
        ocfg = OptimizerConfig(name="adamw", learning_rate=0.05,
                               weight_decay=0.1, warmup_steps=3)
        params = HeadParams(
            w=jnp.asarray(rng.standard_normal((c, k)), jnp.float32),
            b=jnp.zeros((c,), jnp.float32))
        pd = ps = params
        sd = ss = init_opt_state(ocfg, params)
        for s in range(6):
            sp, gd = _random_sparse(rng, c, k, touch_all=(s == 5))
            pd, sd, _ = apply_updates(ocfg, pd, gd, sd)
            g = gd if s % 2 else sp           # alternate carriers
            ps, ss, _ = apply_updates(ocfg, ps, g, ss)
        np.testing.assert_allclose(np.asarray(ps.w), np.asarray(pd.w),
                                   rtol=1e-5, atol=1e-6)

    def test_long_gap_uses_closed_form_tail(self):
        """A 399-step gap exceeds the auto horizon (197 at beta1=0.9, the
        depth at which the momentum term is < 1e-9 of its start): the
        replay covers the first 197 missed steps and the closed-form
        pure-decay tail the remaining 202. Params must match the fully
        replayed reference to ~1e-6."""
        c, k = 4, 3
        rng = np.random.default_rng(1)
        mk = lambda horizon: OptimizerConfig(          # noqa: E731
            name="adamw", learning_rate=0.01, weight_decay=0.3,
            lazy_horizon=horizon)
        params = HeadParams(
            w=jnp.asarray(rng.standard_normal((c, k)), jnp.float32),
            b=jnp.asarray(rng.standard_normal((c,)), jnp.float32))
        sp0, _ = _random_sparse(rng, c, k, touch_all=True)
        sp1 = SparseRows(ids=jnp.asarray([0, c], jnp.int32),
                         dw=jnp.asarray(rng.standard_normal((2, k)),
                                        jnp.float32).at[-1].set(0.0),
                         db=jnp.zeros((2,), jnp.float32))
        outs = []
        for horizon in (1024, 0):   # full replay vs auto horizon + tail
            ocfg = mk(horizon)
            p, o, _ = apply_updates(ocfg, params, sp0,
                                    init_opt_state(ocfg, params))
            o = o._replace(step=jnp.asarray(400, jnp.int32))  # 399 idle
            p, o, _ = apply_updates(ocfg, p, sp1, o)
            outs.append(p)
        np.testing.assert_allclose(np.asarray(outs[1].w),
                                   np.asarray(outs[0].w),
                                   rtol=1e-5, atol=1e-6)


class TestEmbedSparse:
    def test_accumulate_embed_rows_matches_dense_scatter(self):
        rng = np.random.default_rng(0)
        v, d, t = 32, 8, 50
        ids = jnp.asarray(rng.integers(0, v, t), jnp.int32)  # duplicates
        dh = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
        sp = accumulate_embed_rows(ids, dh, v)
        assert sp.db is None and sp.ids.shape == (t,)
        live = np.asarray(sp.ids)
        live = live[live < v]
        assert len(np.unique(live)) == len(live)
        dw, db = sparse_lib.to_dense(sp, (v, d))
        assert db is None
        want = jnp.zeros((v, d)).at[ids].add(dh)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_train_step_sparse_embed_matches_dense_embed(self):
        from repro.data import lm_batch_fn
        from repro.models import lm_head
        from repro.models.config import ModelConfig
        from repro.train.step import init_train_state, make_train_step

        cfg = ModelConfig(name="t", num_layers=2, d_model=32, d_ff=64,
                          vocab_size=128, num_heads=2, num_kv_heads=2,
                          vocab_pad_multiple=64, gen_feature_dim=8,
                          dtype="float32", remat=False)
        hcfg = lm_head.head_config(cfg, "adversarial_ns", n_neg=2,
                                   reg=1e-4)
        opt = OptimizerConfig(name="adagrad", learning_rate=0.05,
                              clip_norm=1.0)
        make = lm_batch_fn(cfg.vocab_size, 4, 16, seed=0)
        st_d = init_train_state(jax.random.PRNGKey(0), cfg, opt,
                                "adversarial_ns")
        st_s = init_train_state(jax.random.PRNGKey(0), cfg, opt,
                                "adversarial_ns")
        step_d = jax.jit(make_train_step(cfg, hcfg, opt,
                                         head_update="sparse",
                                         embed_update="dense"))
        step_s = jax.jit(make_train_step(cfg, hcfg, opt,
                                         head_update="sparse",
                                         embed_update="sparse"))
        for s in range(3):
            r = jax.random.fold_in(jax.random.PRNGKey(1), s)
            b = {k: jnp.asarray(v) for k, v in make(s).items()}
            st_d, md = step_d(st_d, b, r)
            st_s, ms = step_s(st_s, b, r)
            np.testing.assert_allclose(float(ms["loss"]),
                                       float(md["loss"]), rtol=1e-5)
        for (pa, da), (pb, db_) in zip(
                jax.tree_util.tree_flatten_with_path(st_d.params)[0],
                jax.tree_util.tree_flatten_with_path(st_s.params)[0]):
            assert pa == pb
            np.testing.assert_allclose(np.asarray(db_), np.asarray(da),
                                       rtol=5e-3, atol=5e-5,
                                       err_msg=str(pa))


class TestGlobalNormStateLeaves:
    def test_fp32_norm_over_boxes(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)
        qr = quantize_rows(x)
        cov = Sm3Cover(row=jnp.asarray([1.0, 2.0], jnp.bfloat16),
                       col=jnp.asarray([3.0], jnp.float32))
        dense = jnp.full((2, 2), 0.5, jnp.bfloat16)
        tree = {"a": qr, "b": cov, "c": dense}
        want = np.sqrt(
            float(jnp.sum(jnp.square(dequantize_rows(qr))))
            + (1.0 + 4.0 + 9.0) + 4 * 0.25)
        got = global_norm(tree)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(float(got), want, rtol=1e-6)


class TestCheckpoint:
    def _fitted(self, ocfg, steps=3):
        cfg = HeadConfig(num_labels=C, kind="adversarial_ns", n_neg=2,
                         reg=1e-3)
        gen = _gen()
        params, h, xg, y = _problem()
        if ocfg.state_dtype != "fp32":
            params = HeadParams(w=params.w.astype(jnp.bfloat16),
                                b=params.b.astype(jnp.bfloat16))
        opt = init_opt_state(ocfg, params)

        def more(params, opt, n, base):
            for s in range(n):
                r = jax.random.fold_in(jax.random.PRNGKey(41), base + s)
                _, _, srows, _ = heads_lib.sparse_head_loss(
                    cfg, params, gen, h, xg, y, r)
                params, opt, _ = apply_updates(ocfg, params, srows, opt)
            return params, opt

        params, opt = more(params, opt, steps, 0)
        return params, opt, more

    @pytest.mark.parametrize("name,sd", [("sm3", "bf16"),
                                         ("adamw", "int8")])
    def test_round_trip_bit_stable(self, tmp_path, name, sd):
        from repro.checkpoint import restore_checkpoint, save_checkpoint
        ocfg = OptimizerConfig(name=name, learning_rate=0.05,
                               state_dtype=sd)
        params, opt, _ = self._fitted(ocfg)
        tree = {"params": params, "opt": opt}
        save_checkpoint(str(tmp_path), 3, tree)
        got, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 3
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_flatten_with_path(tree)[0],
                jax.tree_util.tree_flatten_with_path(got)[0]):
            assert pa == pb
            assert np.asarray(a).dtype == np.asarray(b).dtype, pa
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=str(pa))

    def test_resume_mid_run_replays_exactly(self, tmp_path):
        from repro.checkpoint import restore_checkpoint, save_checkpoint
        ocfg = OptimizerConfig(name="sm3", learning_rate=0.05,
                               state_dtype="bf16")
        params, opt, more = self._fitted(ocfg, steps=3)
        save_checkpoint(str(tmp_path), 3, {"params": params, "opt": opt})
        pa, oa = more(params, opt, 2, 3)          # straight through
        got, _ = restore_checkpoint(str(tmp_path),
                                    {"params": params, "opt": opt})
        rp = jax.tree.map(jnp.asarray, got["params"])
        ro = jax.tree.map(jnp.asarray, got["opt"])
        pb, ob = more(rp, ro, 2, 3)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(oa), jax.tree.leaves(ob)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestStateBytes:
    def test_sm3_bf16_is_4x_smaller_than_adamw_fp32(self):
        c, k = 4096, 64
        key = jax.random.PRNGKey(0)
        ref_p = heads_lib.init_head_params(key, c, k)
        ref = init_opt_state(OptimizerConfig(name="adamw"), ref_p)
        sm_p = heads_lib.init_head_params(key, c, k, dtype=jnp.bfloat16)
        sm = init_opt_state(OptimizerConfig(name="sm3",
                                            state_dtype="bf16"), sm_p)
        big = head_state_bytes(ref_p, ref)
        small = head_state_bytes(sm_p, sm)
        # adamw/fp32: 12K+20 B/label; sm3/bf16: ~2K+6 B/label -> ~5.8x
        assert big / small >= 4.0, (big, small)
        # abstract (eval_shape) and concrete trees agree
        ap, ao = jax.eval_shape(lambda: (sm_p, sm))
        assert head_state_bytes(ap, ao) == small

    def test_head_leaves_only_in_full_param_tree(self):
        tree = {"trunk": jnp.zeros((8, 8)),
                "head": {"w": jnp.zeros((4, 2)), "b": jnp.zeros((4,))}}
        assert head_state_bytes(tree, None) == (4 * 2 + 4) * 4


class TestShardedState:
    @pytest.mark.slow
    def test_sharded_sm3_and_adamw_match_unsharded(self):
        run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel import AxisType, make_mesh
        from repro.core.heads import HeadParams
        from repro.optim import OptimizerConfig, apply_updates, \\
            init_opt_state
        from repro.optim.sparse import SparseRows

        mesh = make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        c, k = 64, 8
        rng = np.random.default_rng(0)
        params = HeadParams(
            w=jnp.asarray(rng.standard_normal((c, k)), jnp.float32),
            b=jnp.asarray(rng.standard_normal((c,)), jnp.float32))

        def sweep(ocfg, steps=4):
            p1 = p2 = params
            s1 = s2 = init_opt_state(ocfg, params)
            for t in range(steps):
                n = 7 if t < steps - 1 else c
                ids = (rng.choice(c, n, replace=False) if n < c
                       else np.arange(c))
                ids = jnp.asarray(np.append(ids, c), jnp.int32)
                dw = jnp.asarray(rng.standard_normal((n + 1, k)),
                                 jnp.float32).at[-1].set(0.0)
                db = jnp.asarray(rng.standard_normal((n + 1,)),
                                 jnp.float32).at[-1].set(0.0)
                g = SparseRows(ids=ids, dw=dw, db=db)
                p1, s1, _ = apply_updates(ocfg, p1, g, s1)
                p2, s2, _ = apply_updates(ocfg, p2, g, s2, mesh=mesh)
            np.testing.assert_allclose(np.asarray(p2.w),
                                       np.asarray(p1.w),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(p2.b),
                                       np.asarray(p1.b),
                                       rtol=1e-5, atol=1e-6)

        # sm3/bf16: the col cover is recombined by pmax across shards;
        # adamw/fp32: the per-row last + catch-up must mask non-owned
        # (clamped-garbage) gathered rows.
        sweep(OptimizerConfig(name="sm3", learning_rate=0.1,
                              state_dtype="bf16"))
        sweep(OptimizerConfig(name="adamw", learning_rate=0.05,
                              weight_decay=0.2))
        print("sharded state OK")
        """)


class TestSnapshotThenDonate:
    def test_fit_snapshot_survives_donated_step(self):
        from repro.models.config import ModelConfig
        from repro.train.loop import _fit_snapshot
        from repro.train.step import init_train_state

        cfg = ModelConfig(name="t", num_layers=1, d_model=16, d_ff=32,
                          vocab_size=64, num_heads=2, num_kv_heads=2,
                          vocab_pad_multiple=64, gen_feature_dim=4,
                          dtype="float32", remat=False)
        opt = OptimizerConfig(name="adagrad", learning_rate=0.05)
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt,
                                 "adversarial_ns")
        want = np.asarray(state.params["embed"]).copy()
        snap = _fit_snapshot(state)
        # distinct buffers: donation of `state` cannot alias the snapshot
        assert (snap.params["embed"].unsafe_buffer_pointer()
                != state.params["embed"].unsafe_buffer_pointer())

        @functools.partial(jax.jit, donate_argnums=(0,))
        def bump(s):
            return s._replace(
                step=s.step + 1,
                params=jax.tree.map(lambda x: x * 2.0, s.params))

        bump(state)
        # the snapshot still reads the pre-step values even though the
        # submitted state's buffers were donated away
        np.testing.assert_array_equal(np.asarray(snap.params["embed"]),
                                      want)
        np.testing.assert_array_equal(
            np.asarray(snap.gen_fit_step),
            np.asarray(-1, snap.gen_fit_step.dtype))
