"""Fallback for `hypothesis` so the suite collects where the dep is absent.

The real hypothesis is used whenever it is importable (pin it via
``requirements-test.txt`` for full shrinking/coverage). Otherwise a tiny
deterministic stand-in reruns each ``@given`` test body over
``max_examples`` pseudo-random draws from a fixed seed — no shrinking, no
database, but the same property gets exercised and the suite collects
everywhere.

Only the surface this repo uses is implemented: ``given`` (kwargs form),
``settings(max_examples=, deadline=)``, and ``strategies.integers/floats/
sampled_from``.
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_MAX_EXAMPLES = 20
    _SEED = 0xC0FFEE

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class strategies:  # noqa: N801  (module-like namespace)
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        del deadline  # no deadline enforcement in the fallback

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = random.Random(_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # Hide the drawn parameters from pytest's fixture resolution:
            # drop the wraps-installed __wrapped__ (pytest follows it to the
            # original signature) and advertise only `self`, if present.
            del runner.__wrapped__
            keep = [p for p in inspect.signature(fn).parameters.values()
                    if p.name == "self"]
            runner.__signature__ = inspect.Signature(keep)
            return runner

        return deco
