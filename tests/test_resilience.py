"""Chaos suite (DESIGN.md §13): deterministic fault injection and the
graceful-degradation ladder across train / genfit / serve / checkpoint.

The load-bearing invariants:

* recoverable fault schedules leave training BIT-EQUAL to a fault-free
  run (rollback-replay advances the injection counters, so a replayed
  region is clean by construction);
* the serving engine never leaks lanes or pages, whatever combination of
  poison prefills, sheds, and deadline aborts a schedule throws at it;
* checkpoint restore never returns corrupt state — damage degrades the
  restore point, it never silently feeds back bad bytes;
* disabled injection is free enough to leave in hot paths permanently.
"""
import dataclasses
import json
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import configs as cfg_lib
from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, verify_checkpoint)
from repro.data import lm_batch_fn
from repro.data.pipeline import HostShardedLoader, ProducerError
from repro.genfit.refresh import AsyncRefresher, RefreshTimeout
from repro.models import lm_head, transformer
from repro.models.config import ModelConfig
from repro.obs import Registry, start_metrics_server
from repro.obs.export import read_jsonl, validate_events
from repro.optim import OptimizerConfig
from repro.resilience import faults
from repro.resilience.faults import Fault, FaultPlan, InjectedFault
from repro.serve import Engine, Request, ServeConfig
from repro.train import (LoopConfig, init_train_state, make_train_step,
                         run_loop)

pytestmark = pytest.mark.resilience


# ---------------------------------------------------------------------------
# faults core: plans, counters, scoping, cost
# ---------------------------------------------------------------------------

def test_plan_fires_at_exact_nth():
    plan = FaultPlan([Fault("a/site", 2, "raise")])
    with faults.install(plan) as reg:
        faults.fire("a/site")
        faults.fire("a/site")
        with pytest.raises(InjectedFault) as exc:
            faults.fire("a/site")
        assert (exc.value.site, exc.value.nth) == ("a/site", 2)
        faults.fire("a/site")          # nth=3: past the schedule, clean
        assert reg.count("a/site") == 4
        assert reg.fired == [plan.get("a/site", 2)]
    assert faults.active() is None


def test_install_is_scoped_and_nests():
    assert faults.active() is None
    with faults.install(FaultPlan()) as outer:
        assert faults.active() is outer
        with faults.install(FaultPlan()) as inner:
            assert faults.active() is inner
        assert faults.active() is outer
    assert faults.active() is None


def test_install_restored_on_exception():
    with pytest.raises(ValueError):
        with faults.install(FaultPlan()):
            raise ValueError("boom")
    assert faults.active() is None


def test_corrupt_poisons_copy_not_original():
    batch = {"tokens": np.arange(6, dtype=np.int32),
             "mask": np.ones(6, np.float32)}
    with faults.install(FaultPlan([Fault("t/b", 0, "corrupt")])):
        out = faults.inject("t/b", batch)
    assert np.isnan(out["mask"]).any()
    assert not np.isnan(batch["mask"]).any(), "original must be untouched"
    np.testing.assert_array_equal(out["tokens"], batch["tokens"])


def test_delay_sleeps_roughly_requested():
    t0 = time.perf_counter()
    with faults.install(FaultPlan([Fault("d", 0, "delay", seconds=0.05)])):
        faults.fire("d")
    assert time.perf_counter() - t0 >= 0.04


def test_plan_json_roundtrip_and_random_plan_determinism():
    plan = faults.random_plan(7, ["x", "y"], 5)
    again = faults.random_plan(7, ["x", "y"], 5)
    assert plan.to_json() == again.to_json()
    back = FaultPlan.from_json(plan.to_json())
    assert sorted(back.faults, key=str) == sorted(plan.faults, key=str)


def test_env_var_plan_installs_in_subprocess():
    plan = FaultPlan([Fault("sub/site", 0, "raise")])
    code = ("from repro.resilience import faults\n"
            "assert faults.active() is not None\n"
            "try:\n"
            "    faults.fire('sub/site')\n"
            "except faults.InjectedFault:\n"
            "    print('FIRED')\n")
    env = dict(os.environ, REPRO_FAULT_PLAN=plan.to_json(),
               PYTHONPATH=_src_path())
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "FIRED" in out.stdout


def test_disabled_injection_is_cheap():
    """Loose ceiling, not a benchmark: 200k disabled fire() calls must
    stay well under a second — one attribute load + compare each."""
    assert faults.active() is None
    t0 = time.perf_counter()
    for _ in range(200_000):
        faults.fire("hot/site")
    assert time.perf_counter() - t0 < 1.0


# ---------------------------------------------------------------------------
# AsyncRefresher: retries, exhaustion, hang watchdog
# ---------------------------------------------------------------------------

def test_refresher_retries_absorb_transient_failure():
    calls = []

    def flaky(state):
        calls.append(state)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "fitted"

    r = AsyncRefresher(flaky, retries=2, backoff_s=0.001)
    r.submit("snap", 5)
    out, step = r.result()
    assert (out, step) == ("fitted", 5)
    assert len(calls) == 3


def test_refresher_exhausted_retries_raise_last_error():
    def always(state):
        raise RuntimeError("permanent")

    r = AsyncRefresher(always, retries=1, backoff_s=0.001)
    r.submit("snap", 5)
    with pytest.raises(RuntimeError, match="permanent"):
        r.result()
    assert not r.in_flight
    assert r.submit_step == 5          # survives for the failure handler


def test_refresher_watchdog_abandons_hung_fit():
    release = []

    def hung(state):
        while not release:             # daemon thread; freed at test end
            time.sleep(0.01)
        return "late"

    r = AsyncRefresher(hung, timeout_s=0.2)
    r.submit("snap", 3)
    with pytest.raises(RefreshTimeout):
        r.result()
    assert not r.in_flight             # a new submit is immediately legal
    r.submit("snap2", 9)

    def ok(state):
        return "fresh"

    r._fit_fn = ok                     # the hung thread keeps the old fn
    release.append(True)
    out, step = r.result()
    # Whichever thread finished this job, the result belongs to submit 9.
    assert step == 9


# ---------------------------------------------------------------------------
# checkpoint integrity: verify / fallback / never-corrupt-restore
# ---------------------------------------------------------------------------

def _tiny_tree(seed):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 3)).astype(np.float32),
            "b": rng.normal(size=(3,)).astype(np.float32)}


_DAMAGE = ["flip_byte", "truncate_arr", "delete_manifest",
           "garbage_manifest", "delete_arr"]


def _damage(path, mode):
    arr = os.path.join(path, "arr_00000.npy")
    man = os.path.join(path, "manifest.json")
    if mode == "flip_byte":
        with open(arr, "r+b") as f:
            f.seek(-1, 2)
            last = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([last[0] ^ 0xFF]))
    elif mode == "truncate_arr":
        with open(arr, "r+b") as f:
            f.truncate(max(os.path.getsize(arr) // 2, 1))
    elif mode == "delete_manifest":
        os.remove(man)
    elif mode == "garbage_manifest":
        with open(man, "w") as f:
            f.write("{not json")
    elif mode == "delete_arr":
        os.remove(arr)


@settings(max_examples=len(_DAMAGE), deadline=None)
@given(mode=st.sampled_from(_DAMAGE))
def test_restore_never_returns_corrupt_state(mode):
    import tempfile
    d = tempfile.mkdtemp(prefix=f"ck_{mode.replace('/', '_')}_")
    for step in (1, 2, 3):
        save_checkpoint(d, step, _tiny_tree(step), keep=0)
    newest = os.path.join(d, "step_00000003")
    assert verify_checkpoint(newest)
    _damage(newest, mode)
    assert not verify_checkpoint(newest)
    # Fallback: the damaged newest entry degrades the restore point.
    assert latest_step(d) == 2
    tree, got = restore_checkpoint(d, _tiny_tree(0))
    assert got == 2
    np.testing.assert_array_equal(tree["w"], _tiny_tree(2)["w"])
    # An explicit request for the damaged step must raise, never return.
    with pytest.raises((IOError, FileNotFoundError)):
        restore_checkpoint(d, _tiny_tree(0), step=3)


def test_latest_step_ignores_stale_tmp_dirs(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 5, _tiny_tree(5), keep=0)
    os.makedirs(os.path.join(d, ".tmp_ckpt_dead"))
    with open(os.path.join(d, ".tmp_ckpt_dead", "arr_00000.npy"),
              "wb") as f:
        f.write(b"\x00" * 16)
    assert latest_step(d) == 5


def test_injected_raise_mid_save_leaves_no_tmp(tmp_path):
    d = str(tmp_path)
    with faults.install(FaultPlan([Fault("checkpoint/write", 0, "raise")])):
        with pytest.raises(InjectedFault):
            save_checkpoint(d, 1, _tiny_tree(1), keep=0)
    assert not any(n.startswith(".tmp") for n in os.listdir(d))
    assert latest_step(d) is None
    save_checkpoint(d, 1, _tiny_tree(1), keep=0)   # clean retry succeeds
    assert latest_step(d) == 1


# ---------------------------------------------------------------------------
# train loop: skip / rollback / genfit degradation — bit-equality
# ---------------------------------------------------------------------------

def _setup(seed=0):
    cfg = dataclasses.replace(cfg_lib.reduced_config("stablelm-3b"),
                              num_layers=1, dtype="float32")
    hcfg = lm_head.head_config(cfg, "adversarial_ns", reg=1e-4)
    opt = OptimizerConfig(name="adagrad", learning_rate=0.05, clip_norm=1.0)
    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt,
                             "adversarial_ns")
    step_fn = jax.jit(make_train_step(cfg, hcfg, opt, skip_nonfinite=True))
    make = lm_batch_fn(cfg.vocab_size, global_batch=4, seq_len=16, seed=1)
    batch_fn = lambda s: {k: jnp.asarray(v)                 # noqa: E731
                          for k, v in make(s).items()}
    return cfg, state, step_fn, batch_fn


def _gen_fit_fn(cfg):
    from repro.train.generator_fit import make_gen_fit_fn
    make = lm_batch_fn(cfg.vocab_size, global_batch=4, seq_len=16, seed=9)
    batch_fn = lambda s: {k: jnp.asarray(v)                  # noqa: E731
                          for k, v in make(s).items()}
    return make_gen_fit_fn(cfg, batch_fn, kind="adversarial_ns",
                           max_tokens=128, n_batches=2)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_nonfinite_skip_counts_and_completes(tmp_path):
    """A transiently poisoned batch is skipped in-graph: the run finishes
    with a finite loss, the skip is counted, and the event log validates
    against the schema (incl. the new resilience event types)."""
    cfg, state, step_fn, batch_fn = _setup(seed=2)
    jsonl = str(tmp_path / "ev.jsonl")
    loop = LoopConfig(total_steps=8, checkpoint_dir=None, log_every=100,
                      metrics_jsonl=jsonl)
    plan = FaultPlan([Fault("train/batch", 3, "corrupt")])
    with faults.install(plan) as reg:
        state, hist = run_loop(state, step_fn, batch_fn, loop,
                               jax.random.PRNGKey(2),
                               registry=Registry())
    assert reg.count("train/batch") == 8
    assert hist["nonfinite_steps"] == [3]
    assert hist["metrics"]["train/nonfinite_skipped"]["value"] == 1
    assert np.isfinite(hist["loss"][-1])
    events = read_jsonl(jsonl)
    validate_events(events)
    assert [e["step"] for e in events
            if e["event"] == "nonfinite_skip"] == [3]


def test_rollback_replay_is_bit_equal_to_fault_free(tmp_path):
    """THE tentpole invariant: a corrupt batch that escalates to
    rollback-restore leaves the final parameters bit-identical to an
    uninterrupted run — the replayed region sees fresh injection indices,
    so the fault does not re-fire."""
    n = 10
    ref_loop = LoopConfig(total_steps=n, checkpoint_dir=None, log_every=100)
    cfg, ref_state, step_fn, batch_fn = _setup(seed=3)
    ref_state, _ = run_loop(ref_state, step_fn, batch_fn, ref_loop,
                            jax.random.PRNGKey(7))

    loop = LoopConfig(total_steps=n, checkpoint_every=2,
                      checkpoint_dir=str(tmp_path / "ck"), log_every=100,
                      max_consecutive_nonfinite=1, max_rollbacks=2)
    _, state, _, _ = _setup(seed=3)
    plan = FaultPlan([Fault("train/batch", 5, "corrupt")])
    with faults.install(plan):
        state, hist = run_loop(state, step_fn, batch_fn, loop,
                               jax.random.PRNGKey(7), registry=Registry())
    assert hist["rollback_steps"] == [[5, 4]]
    assert hist["metrics"]["train/rollbacks"]["value"] == 1
    _assert_trees_equal(ref_state.params, state.params)
    _assert_trees_equal(ref_state.opt_state, state.opt_state)


def test_unguarded_nonfinite_rolls_back_immediately(tmp_path):
    """Without the in-graph guard the state is already poisoned when the
    host sees the NaN — the ladder must go straight to rollback, and the
    replay still ends bit-equal to fault-free."""
    n = 8
    cfg, _, _, batch_fn = _setup(seed=4)
    hcfg = lm_head.head_config(cfg, "adversarial_ns", reg=1e-4)
    opt = OptimizerConfig(name="adagrad", learning_rate=0.05, clip_norm=1.0)
    unguarded = jax.jit(make_train_step(cfg, hcfg, opt))   # no guard

    def fresh():
        return init_train_state(jax.random.PRNGKey(4), cfg, opt,
                                "adversarial_ns")

    ref_loop = LoopConfig(total_steps=n, checkpoint_dir=None, log_every=100)
    ref_state, _ = run_loop(fresh(), unguarded, batch_fn, ref_loop,
                            jax.random.PRNGKey(5))

    loop = LoopConfig(total_steps=n, checkpoint_every=2,
                      checkpoint_dir=str(tmp_path / "ck"), log_every=100)
    with faults.install(FaultPlan([Fault("train/batch", 5, "corrupt")])):
        state, hist = run_loop(fresh(), unguarded, batch_fn, loop,
                               jax.random.PRNGKey(5))
    assert hist["rollback_steps"] == [[5, 4]]
    _assert_trees_equal(ref_state.params, state.params)


def test_rollback_budget_exhaustion_raises(tmp_path):
    """A persistent cause (every batch poisoned) re-fires after every
    rollback; the budget converts it into the legacy crash."""
    cfg, state, step_fn, batch_fn = _setup(seed=5)
    # Poison every batch from step 2 on (after the first checkpoint
    # exists, so the ladder gets to roll back before giving up).
    plan = FaultPlan([Fault("train/batch", n, "corrupt")
                      for n in range(2, 64)])
    loop = LoopConfig(total_steps=8, checkpoint_every=2,
                      checkpoint_dir=str(tmp_path / "ck"), log_every=100,
                      max_consecutive_nonfinite=1, max_rollbacks=2)
    with faults.install(plan):
        with pytest.raises(FloatingPointError, match="budget"):
            run_loop(state, step_fn, batch_fn, loop, jax.random.PRNGKey(2))


def test_nonfinite_policy_raise_fails_fast():
    cfg, state, step_fn, batch_fn = _setup(seed=6)
    loop = LoopConfig(total_steps=6, checkpoint_dir=None, log_every=100,
                      nonfinite_policy="raise")
    with faults.install(FaultPlan([Fault("train/batch", 2, "corrupt")])):
        with pytest.raises(FloatingPointError):
            run_loop(state, step_fn, batch_fn, loop, jax.random.PRNGKey(2))


def test_genfit_transient_failure_retried_bit_equal():
    """A generator fit that fails once and succeeds on retry installs the
    identical head state (fits are deterministic in (state, config)) —
    the whole run stays bit-equal to fault-free."""
    n = 6
    cfg, state, step_fn, batch_fn = _setup(seed=7)
    gen_fit = _gen_fit_fn(cfg)
    loop = LoopConfig(total_steps=n, gen_warmup_steps=2, log_every=100,
                      gen_fit_retries=2, gen_fit_backoff_s=0.001)
    ref_state, ref_hist = run_loop(state, step_fn, batch_fn, loop,
                                   jax.random.PRNGKey(3),
                                   gen_fit_fn=gen_fit)

    _, state2, _, _ = _setup(seed=7)
    with faults.install(FaultPlan([Fault("genfit/fit", 0, "raise")])) as r:
        state2, hist = run_loop(state2, step_fn, batch_fn, loop,
                                jax.random.PRNGKey(3), gen_fit_fn=gen_fit)
    assert r.count("genfit/fit") == 2          # attempt 0 raised, 1 fit
    assert "gen_refresh_failed_steps" not in hist
    assert hist["gen_swap_steps"] == ref_hist["gen_swap_steps"]
    _assert_trees_equal(ref_state.params, state2.params)
    _assert_trees_equal(ref_state.head_state, state2.head_state)


def test_genfit_permanent_failure_keeps_stale_generator():
    """Retries exhausted: the loop records gen_refresh_failed, keeps the
    stale generator, and the NEXT scheduled refresh succeeds."""
    cfg, state, step_fn, batch_fn = _setup(seed=8)
    gen_fit = _gen_fit_fn(cfg)
    loop = LoopConfig(total_steps=10, gen_warmup_steps=2,
                      gen_refresh_steps=3, log_every=100,
                      gen_fit_retries=1, gen_fit_backoff_s=0.001)
    # Blocking fits: warmup at 2 (attempts nth 0,1 — both raise), next
    # refresh at 5 (nth 2 — clean).
    plan = FaultPlan([Fault("genfit/fit", 0, "raise"),
                      Fault("genfit/fit", 1, "raise")])
    with faults.install(plan):
        state, hist = run_loop(state, step_fn, batch_fn, loop,
                               jax.random.PRNGKey(3), gen_fit_fn=gen_fit,
                               registry=Registry())
    assert hist["gen_refresh_failed_steps"] == [2]
    assert 5 in hist["gen_swap_steps"]
    assert hist["metrics"]["genfit/refresh_failed"]["value"] == 1
    assert np.isfinite(hist["loss"][-1])


def test_genfit_async_hang_watchdog_keeps_training(tmp_path):
    """A hung background fit trips the watchdog at the swap step; the run
    keeps the stale generator, completes, and a later refresh installs."""
    cfg, state, step_fn, batch_fn = _setup(seed=9)
    gen_fit = _gen_fit_fn(cfg)
    loop = LoopConfig(total_steps=10, gen_warmup_steps=2,
                      gen_refresh_steps=4, gen_async=True,
                      gen_swap_delay=2, log_every=100,
                      checkpoint_dir=str(tmp_path / "ck"),
                      checkpoint_every=4,
                      gen_fit_retries=0, gen_fit_timeout_s=5.0)
    # Submits at 2 (hang: worker sleeps past the watchdog — and past
    # process exit, so it never wakes into a dying interpreter) and 6
    # (clean; the 5s watchdog is generous against a warm ~1s fit).
    plan = FaultPlan([Fault("genfit/fit", 0, "hang", seconds=1200.0)])
    with faults.install(plan):
        state, hist = run_loop(state, step_fn, batch_fn, loop,
                               jax.random.PRNGKey(3), gen_fit_fn=gen_fit,
                               registry=Registry())
    assert hist["gen_refresh_failed_steps"] == [4]      # swap step 2+2
    assert hist["gen_swap_steps"] == [8]                # submit 6 + 2
    assert int(jax.device_get(state.gen_fit_step)) == 6
    assert np.isfinite(hist["loss"][-1])


def test_checkpoint_delay_schedule_is_bit_equal(tmp_path):
    """Pure-delay faults on the checkpoint writer are invisible to the
    training trajectory."""
    n = 8
    ref_loop = LoopConfig(total_steps=n, checkpoint_dir=None, log_every=100)
    cfg, ref_state, step_fn, batch_fn = _setup(seed=10)
    ref_state, _ = run_loop(ref_state, step_fn, batch_fn, ref_loop,
                            jax.random.PRNGKey(4))
    loop = LoopConfig(total_steps=n, checkpoint_every=2, log_every=100,
                      checkpoint_dir=str(tmp_path / "ck"))
    _, state, _, _ = _setup(seed=10)
    plan = FaultPlan([Fault("checkpoint/write", 0, "delay", seconds=0.02),
                      Fault("checkpoint/commit", 1, "delay", seconds=0.02)])
    with faults.install(plan):
        state, _ = run_loop(state, step_fn, batch_fn, loop,
                            jax.random.PRNGKey(4))
    _assert_trees_equal(ref_state.params, state.params)
    assert latest_step(str(tmp_path / "ck")) == n


# ---------------------------------------------------------------------------
# serving engine: shed / deadline / poison — no lane or page leaks
# ---------------------------------------------------------------------------

CFG = ModelConfig(
    name="resilience-test", num_layers=1, d_model=32, d_ff=64,
    vocab_size=100, num_heads=2, num_kv_heads=2, vocab_pad_multiple=128,
    gen_feature_dim=8, dtype="float32", remat=False)
HCFG = lm_head.head_config(CFG, "adversarial_ns")
PARAMS = transformer.init_params(jax.random.PRNGKey(0), CFG)
HEAD_STATE = lm_head.default_head_state(jax.random.PRNGKey(1), CFG,
                                        "adversarial_ns")
MAX_LEN = 12
N_SLOTS = 2

_ENGINES = {}


def shared_engine(max_queue=0, enforce_deadlines=False) -> Engine:
    """One engine per resilience config (jit caches stay warm); between
    runs all lanes/pages are free and the queues empty."""
    key = (max_queue, enforce_deadlines)
    if key not in _ENGINES:
        _ENGINES[key] = Engine(CFG, HCFG, PARAMS, HEAD_STATE, ServeConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, beam=8, page_len=3,
            n_pages=8, cache_dtype=jnp.float32, max_queue=max_queue,
            enforce_deadlines=enforce_deadlines))
    return _ENGINES[key]


def _prompts(seed, n, lo=2, hi=4):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size,
                         rng.integers(lo, hi + 1)).astype(np.int32)
            for _ in range(n)]


def _assert_drained(eng):
    eng.pool.check_invariants()
    assert eng.pool.num_free_lanes == N_SLOTS
    assert eng.pool.num_free_pages == eng.pool.n_pages
    assert eng.num_pending == 0 and eng.num_active == 0


def test_engine_shed_on_bounded_queue():
    eng = shared_engine(max_queue=2)
    before = eng.shed_count
    handles = [eng.submit(Request(prompt=p, max_new_tokens=3))
               for p in _prompts(0, 5)]
    shed = [h for h in handles if h.status == "shed"]
    assert len(shed) == 3 and eng.shed_count - before == 3
    assert all(h.done and not h.tokens for h in shed)
    eng.run()
    assert all(h.done for h in handles)
    kept = [h for h in handles if h.status == "ok"]
    assert len(kept) == 2 and all(len(h.tokens) == 3 for h in kept)
    _assert_drained(eng)
    assert eng.health()["ready"]       # queue drained: ready again


def test_engine_deadline_abort_reclaims_resources():
    eng = shared_engine(enforce_deadlines=True)
    expired = [eng.submit(Request(prompt=p, max_new_tokens=3,
                                  deadline_s=0.0))
               for p in _prompts(1, 3)]
    alive = eng.submit(Request(prompt=_prompts(2, 1)[0], max_new_tokens=3))
    eng.run()
    assert all(h.done and h.status == "deadline" for h in expired)
    assert alive.status == "ok" and len(alive.tokens) == 3
    assert eng.deadline_aborts >= 3
    _assert_drained(eng)


def test_engine_poisoned_prefill_is_isolated():
    """A request whose prefill raises is failed alone; the rest of the
    batch completes with byte-identical tokens to a fault-free run."""
    eng = shared_engine()
    prompts = _prompts(3, 4)
    ref = [eng.submit(Request(prompt=p, max_new_tokens=3))
           for p in prompts]
    eng.run()
    _assert_drained(eng)

    plan = FaultPlan([Fault("serve/prefill", 1, "raise")])
    with faults.install(plan):
        handles = [eng.submit(Request(prompt=p, max_new_tokens=3))
                   for p in prompts]
        eng.run()
    assert all(h.done for h in handles)
    errored = [i for i, h in enumerate(handles) if h.status == "error"]
    assert len(errored) == 1
    for i, h in enumerate(handles):
        if h.status == "ok":
            assert h.tokens == ref[i].tokens, f"request {i} diverged"
    _assert_drained(eng)
    assert eng.poisoned_count >= 1


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_engine_never_leaks_under_chaos(seed):
    """Seeded chaos: random raise/delay faults on serve/prefill plus
    delays on serve/step, random deadline mix — every request reaches a
    terminal state and the pool drains back to empty."""
    rng = np.random.default_rng(seed)
    plan_faults = []
    for _ in range(int(rng.integers(1, 5))):
        plan_faults.append(Fault("serve/prefill", int(rng.integers(0, 6)),
                                 str(rng.choice(["raise", "delay"])),
                                 seconds=0.002))
    for _ in range(int(rng.integers(0, 3))):
        plan_faults.append(Fault("serve/step", int(rng.integers(0, 8)),
                                 "delay", seconds=0.002))
    eng = shared_engine(enforce_deadlines=True)
    prompts = _prompts(seed, int(rng.integers(2, 6)))
    with faults.install(FaultPlan(plan_faults)):
        handles = []
        for p in prompts:
            ddl = (0.0 if rng.random() < 0.3 else None)
            handles.append(eng.submit(Request(
                prompt=p, max_new_tokens=int(rng.integers(1, 4)),
                deadline_s=ddl)))
        eng.run()
    assert all(h.done for h in handles)
    assert all(h.status in ("ok", "error", "deadline", "shed")
               for h in handles)
    _assert_drained(eng)


def test_engine_health_snapshot_in_stats():
    eng = shared_engine()
    h = eng.stats()["health"]
    for k in ("ready", "compiled", "queue_depth", "active", "lanes_free",
              "pages_free", "shed", "poisoned", "deadline_aborts"):
        assert k in h, k
    assert h["queue_depth"] == 0 and h["active"] == 0


# ---------------------------------------------------------------------------
# /healthz + /readyz on the metrics server
# ---------------------------------------------------------------------------

def _get(port, path):
    import urllib.error
    import urllib.request
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=5) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def test_health_endpoints():
    snap = {"ready": False, "queue_depth": 0}
    reg = Registry()
    reg.counter("x").inc()
    with start_metrics_server(reg, 0, host="127.0.0.1",
                              health_fn=lambda: dict(snap)) as srv:
        code, body = _get(srv.port, "/healthz")
        assert code == 200 and json.loads(body)["ready"] is False
        code, _ = _get(srv.port, "/readyz")
        assert code == 503                      # alive but not ready
        snap["ready"] = True
        code, body = _get(srv.port, "/readyz")
        assert code == 200 and json.loads(body)["ready"] is True
        code, body = _get(srv.port, "/metrics")
        assert code == 200 and "x" in body      # scrape path untouched


def test_health_endpoints_404_without_health_fn():
    with start_metrics_server(Registry(), 0, host="127.0.0.1") as srv:
        assert _get(srv.port, "/healthz")[0] == 404
        assert _get(srv.port, "/readyz")[0] == 404


def test_engine_readyz_flips_after_compile():
    eng = shared_engine()
    eng._compiled = False              # fresh-process readiness gate
    with start_metrics_server(eng.registry, 0, host="127.0.0.1",
                              health_fn=eng.health) as srv:
        assert _get(srv.port, "/readyz")[0] == 503
        h = eng.submit(Request(prompt=_prompts(9, 1)[0], max_new_tokens=2))
        eng.run()
        assert h.status == "ok"
        assert _get(srv.port, "/readyz")[0] == 200


# ---------------------------------------------------------------------------
# data pipeline: producer failure propagation
# ---------------------------------------------------------------------------

def test_pipeline_producer_exception_propagates():
    def boom(step):
        if step >= 2:
            raise ValueError("bad shard")
        return {"tokens": np.zeros((4, 4), np.int32)}

    ld = HostShardedLoader(boom, 4, prefetch=2)
    seen = []
    with pytest.raises(ProducerError, match="bad shard"):
        for s, b in ld:
            seen.append(s)
            if len(seen) > 10:          # must not loop forever
                break
    assert seen == [0, 1]
    assert not ld.failed               # producer exited; nothing leaked


def test_pipeline_injected_producer_fault():
    make = lm_batch_fn(64, 4, 8)
    ld = HostShardedLoader(make, 4, prefetch=2)
    with faults.install(FaultPlan([Fault("data/produce", 2, "raise")])):
        with pytest.raises(ProducerError) as exc:
            for s, b in ld:
                pass
    assert isinstance(exc.value.__cause__, InjectedFault)


def test_pipeline_wedged_producer_marks_failed():
    started = []

    def slow(step):
        started.append(step)
        if step == 0:
            return {"tokens": np.zeros((4, 4), np.int32)}
        time.sleep(1200)               # daemon thread; dies with pytest

    ld = HostShardedLoader(slow, 4, prefetch=1)
    it = iter(ld)
    next(it)                           # step 0 flows; step 1 wedges
    deadline = time.perf_counter() + 5
    while len(started) < 2 and time.perf_counter() < deadline:
        time.sleep(0.01)
    ld.close()
    assert ld.failed                   # join timed out: loudly poisoned
    with pytest.raises(AssertionError):
        next(iter(ld))                 # refuses to restart


# ---------------------------------------------------------------------------
# kill -9 mid-checkpoint / mid-gensnap: resume replays bit-exact
# ---------------------------------------------------------------------------

_VICTIM = """
import dataclasses, sys
import jax, jax.numpy as jnp
from repro import configs as cfg_lib
from repro.data import lm_batch_fn
from repro.models import lm_head
from repro.optim import OptimizerConfig
from repro.train import (LoopConfig, init_train_state, make_train_step,
                         run_loop)

ckpt, variant = sys.argv[1], sys.argv[2]
gen = variant == "gen"
cfg = dataclasses.replace(cfg_lib.reduced_config("stablelm-3b"),
                          num_layers=1, dtype="float32")
hcfg = lm_head.head_config(cfg, "adversarial_ns", reg=1e-4)
opt = OptimizerConfig(name="adagrad", learning_rate=0.05, clip_norm=1.0)
state = init_train_state(jax.random.PRNGKey(0), cfg, opt, "adversarial_ns")
step_fn = jax.jit(make_train_step(cfg, hcfg, opt, skip_nonfinite=True))
make = lm_batch_fn(cfg.vocab_size, global_batch=4, seq_len=16, seed=1)
batch_fn = lambda s: {k: jnp.asarray(v) for k, v in make(s).items()}
gen_fit = None
if gen:
    from repro.train.generator_fit import make_gen_fit_fn
    gen_fit = make_gen_fit_fn(cfg, batch_fn, kind="adversarial_ns",
                              max_tokens=128, n_batches=2)
loop = LoopConfig(total_steps=12, checkpoint_every=4, checkpoint_dir=ckpt,
                  log_every=100,
                  gen_warmup_steps=2 if gen else 0,
                  gen_refresh_steps=4 if gen else 0,
                  gen_async=gen, gen_swap_delay=2 if gen else 0)
state, hist = run_loop(state, step_fn, batch_fn, loop,
                       jax.random.PRNGKey(7), gen_fit_fn=gen_fit)
print("DONE", int(jax.device_get(state.step)), flush=True)
"""


def _src_path():
    return os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "src"))


def _run_victim(script, ckpt, variant, extra_env=None, wait=True):
    env = dict(os.environ, PYTHONPATH=_src_path())
    env.pop("REPRO_FAULT_PLAN", None)
    if extra_env:
        env.update(extra_env)
    proc = subprocess.Popen([sys.executable, script, ckpt, variant],
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    if not wait:
        return proc
    out, err = proc.communicate(timeout=420)
    assert proc.returncode == 0, err
    assert "DONE 12" in out, (out, err)
    return proc


def _final_crcs(ckpt):
    with open(os.path.join(ckpt, "step_00000012", "manifest.json")) as f:
        meta = json.load(f)
    return [leaf["crc32"] for leaf in meta["leaves"]]


@pytest.mark.slow
@pytest.mark.parametrize("variant,site,nth", [
    ("plain", "checkpoint/commit", 1),     # kill mid-commit of ckpt 8
    ("gen", "checkpoint/write", 2),        # kill mid-write of gensnap 6
])
def test_sigkill_mid_save_resumes_bit_exact(tmp_path, variant, site, nth):
    """SIGKILL a training process while a checkpoint (or gensnap) is
    mid-write: the interrupted artifact must be invisible to resume, and
    the resumed run must replay to a bit-identical final state."""
    script = str(tmp_path / "victim.py")
    with open(script, "w") as f:
        f.write(_VICTIM)

    ref = str(tmp_path / "ref")
    _run_victim(script, ref, variant)
    ref_crcs = _final_crcs(ref)

    kill_dir = str(tmp_path / "kill")
    plan = FaultPlan([Fault(site, nth, "delay", seconds=600.0)])
    proc = _run_victim(script, kill_dir, variant, wait=False,
                       extra_env={"REPRO_FAULT_PLAN": plan.to_json()})
    try:
        # The delayed save begins only after step_00000004 is committed;
        # a .tmp_ckpt_* dir appearing after that means the writer is
        # parked inside the injected delay.
        deadline = time.perf_counter() + 360
        while time.perf_counter() < deadline:
            if proc.poll() is not None:
                out, err = proc.communicate()
                raise AssertionError(f"victim exited early: {err}")
            names = (os.listdir(kill_dir) if os.path.isdir(kill_dir)
                     else [])
            if ("step_00000004" in names
                    and any(n.startswith(".tmp_ckpt_") for n in names)):
                break
            time.sleep(0.05)
        else:
            raise AssertionError("victim never reached the delayed save")
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()

    # The torn artifact is on disk but must not be a restore candidate.
    leftovers = [n for n in os.listdir(kill_dir)
                 if n.startswith(".tmp_ckpt_")]
    assert leftovers, "kill landed outside the save window"
    assert latest_step(kill_dir) == 4

    _run_victim(script, kill_dir, variant)      # fresh process: auto-resume
    assert latest_step(kill_dir) == 12
    assert _final_crcs(kill_dir) == ref_crcs
